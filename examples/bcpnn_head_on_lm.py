"""BCPNNHead on an LM trunk: the paper's technique as a framework feature.

    PYTHONPATH=src python examples/bcpnn_head_on_lm.py

A small gemma2-family trunk embeds token sequences; a BCPNN head learns —
online, with the local Hebbian-Bayesian rule, no backprop through the
head — to classify which synthetic 'dialect' generated each sequence.
This is the integration point that applies to all ten assigned archs
(DESIGN.md §4).
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke
from repro.core.head import (BCPNNHeadConfig, head_predict, head_supervised,
                             head_unsupervised, init_head)
from repro.models import lm


def make_dialect_batches(vocab, n_classes=4, batch=64, seq=32, steps=30, seed=0):
    """Each 'dialect' draws tokens from its own narrow vocabulary band."""
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        y = rng.integers(0, n_classes, batch)
        lo = (y * (vocab // n_classes))[:, None]
        toks = lo + rng.integers(0, vocab // (2 * n_classes), (batch, seq))
        yield toks.astype(np.int32), y.astype(np.int32)


def main():
    cfg = smoke(get_config("gemma2-2b")).with_(dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    @jax.jit
    def features(toks):
        h = lm.forward(params, cfg, toks)
        return h.mean(axis=1)  # pooled trunk features (B, d)

    hcfg = BCPNNHeadConfig(feature_dim=cfg.d_model, hidden_hc=16,
                           hidden_mc=16, n_classes=4, alpha=5e-2,
                           noise_steps=30)
    state = init_head(hcfg, jax.random.PRNGKey(1))

    unsup = jax.jit(lambda s, f: head_unsupervised(s, hcfg, f))
    sup = jax.jit(lambda s, f, y: head_supervised(s, hcfg, f, y))
    pred = jax.jit(lambda s, f: head_predict(s, hcfg, f)[1])

    # online semi-supervised stream: unsupervised on every batch,
    # supervised on every fourth (sparse labels)
    for i, (toks, y) in enumerate(make_dialect_batches(cfg.vocab, steps=120)):
        f = features(jnp.asarray(toks))
        state = unsup(state, f)
        if i % 4 == 0:
            state = sup(state, f, jnp.asarray(y))

    correct = total = 0
    for toks, y in make_dialect_batches(cfg.vocab, steps=10, seed=777):
        p = np.asarray(pred(state, features(jnp.asarray(toks))))
        correct += int((p == y).sum())
        total += len(y)
    acc = correct / total
    print(f"[bcpnn-head] online semi-supervised accuracy on LM features: "
          f"{acc*100:.1f}%")
    assert acc > 0.7, acc


if __name__ == "__main__":
    main()
