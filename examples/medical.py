"""The paper's new medical use-cases: Pneumonia + Breast (MedMNIST-shaped).

    PYTHONPATH=src python examples/medical.py

First application of BCPNN to these tasks in the paper (§5); here on
offline surrogates with the exact Table 1 configurations (drop real
pneumonia.npz / breast.npz under data/ to use MedMNIST).
"""
import sys
import time

sys.path.insert(0, "src")

from repro.configs.bcpnn_models import BCPNN_MODELS
from repro.core import Trainer
from repro.data.synthetic import encode_images, load_or_synthesize


def run(model_name: str, epochs: int):
    cfg, dataset, paper_epochs = BCPNN_MODELS[model_name]
    ds = load_or_synthesize(dataset)
    xt, yt = encode_images(ds.x_train), ds.y_train
    xe, ye = encode_images(ds.x_test), ds.y_test
    print(f"[medical] {model_name}: {len(xt)} train / {len(xe)} test, "
          f"{epochs} epochs (paper: {paper_epochs})")
    tr = Trainer(cfg, seed=0)
    t0 = time.time()
    stats = tr.fit(xt, yt, epochs=epochs, batch=64)
    acc = tr.evaluate(xe, ye, batch=52 if dataset == "breast" else 64)
    print(f"[medical] {model_name}: test acc {acc*100:.1f}% "
          f"({time.time()-t0:.1f}s, {stats['train_ms_per_img']:.3f} ms/img)")
    return acc


def main():
    acc_p = run("model2-pneumonia", epochs=20)
    acc_b = run("model3-breast", epochs=30)
    # paper reports 85.3% / 80.1% on the real MedMNIST sets
    assert acc_p > 0.8 and acc_b > 0.7, (acc_p, acc_b)


if __name__ == "__main__":
    main()
