"""Fig. 5 analogue: watch a hypercolumn's receptive field refine itself.

    PYTHONPATH=src python examples/structural_plasticity.py

Trains Model-1 with structural plasticity enabled and prints an ASCII
rendering of one hidden HC's receptive field (active input pixels) as it
evolves from random to information-driven, plus the captured-MI curve.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BCPNNConfig, init_deep, mutual_information, unsupervised_layer_step,
)
from repro.data.synthetic import encode_images, load_or_synthesize


def render_rf(mask_col: np.ndarray, side: int) -> str:
    rf = mask_col.reshape(side, side)
    return "\n".join("".join("#" if v else "." for v in row) for row in rf)


def main():
    ds = load_or_synthesize("mnist")
    side = 28
    cfg = BCPNNConfig(input_hc=side * side, input_mc=2, hidden_hc=16,
                      hidden_mc=32, n_classes=10, nact_hi=196, alpha=5e-3,
                      support_noise=3.0, noise_steps=200, struct_every=16)
    x = encode_images(ds.x_train[:8192])
    spec = cfg.network_spec()
    state = init_deep(spec, jax.random.PRNGKey(0))
    step = jax.jit(lambda s, xb: unsupervised_layer_step(s, spec, xb, 0))

    snapshots, mi_curve = [], []
    for i in range(0, 8192 * 3, 128):
        xb = jnp.asarray(x[(i % 8192):(i % 8192) + 128])
        state = step(state, xb)
        if (i // 128) % 48 == 0:
            proj = state.projs[0]
            mi = mutual_information(proj.traces, side * side, 2,
                                    cfg.hidden_hc, cfg.hidden_mc)
            captured = float(jnp.sum(mi * proj.mask))
            mi_curve.append(captured)
            snapshots.append(np.asarray(proj.mask[:, 0]))

    print("[struct] receptive field of hidden HC 0, early vs late:")
    print(render_rf(snapshots[0], side))
    print("   ...   ")
    print(render_rf(snapshots[-1], side))
    print(f"[struct] captured MI over time: "
          f"{[f'{v:.2f}' for v in mi_curve]}")
    changed = int(np.sum(snapshots[0] != snapshots[-1]))
    print(f"[struct] rewired {changed} connections for HC 0")
    assert mi_curve[-1] >= mi_curve[0], "rewiring should not lose MI"


if __name__ == "__main__":
    main()
