"""Quickstart: train the paper's Model-1 BCPNN (MNIST-shaped) end to end.

    PYTHONPATH=src python examples/quickstart.py [--small]

Runs the full protocol of the paper's §5: unsupervised epochs on the
input-hidden projection, one supervised pass on the readout, then
inference — and reports per-image latencies and accuracy like Table 2.
(Offline container: data is a class-structured synthetic surrogate with
MNIST's shapes; drop a real mnist.npz under data/ to use actual MNIST.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.configs.bcpnn_models import MODEL1_MNIST
from repro.core import Trainer
from repro.data.synthetic import encode_images, load_or_synthesize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="subset + fewer epochs (CI-speed)")
    args = ap.parse_args()

    ds = load_or_synthesize("mnist")
    n_train = 4096 if args.small else 16384
    epochs = 3 if args.small else 5
    cfg = MODEL1_MNIST
    if args.small:
        cfg = cfg.__class__(**{**cfg.__dict__, "hidden_mc": 64,
                               "noise_steps": 60})

    xt = encode_images(ds.x_train[:n_train])
    yt = ds.y_train[:n_train]
    xe = encode_images(ds.x_test[:2048])
    ye = ds.y_test[:2048]

    print(f"[quickstart] model1-mnist: input 784x2, hidden "
          f"{cfg.hidden_hc}x{cfg.hidden_mc}, {epochs} unsupervised epochs")
    tr = Trainer(cfg, seed=0)
    t0 = time.time()
    stats = tr.fit(xt, yt, epochs=epochs, batch=128, log=True)
    acc_train = tr.evaluate(xt, yt)
    acc_test = tr.evaluate(xe, ye)
    print(f"[quickstart] total time {time.time()-t0:.1f}s; "
          f"train latency {stats['train_ms_per_img']:.3f} ms/img")
    print(f"[quickstart] train acc {acc_train*100:.1f}%  "
          f"test acc {acc_test*100:.1f}%")
    assert acc_test > 0.85, "quickstart should learn the surrogate task"


if __name__ == "__main__":
    main()
