"""Quickstart: train a BCPNN (MNIST-shaped) end to end, at any depth.

    PYTHONPATH=src python examples/quickstart.py [--small]
    PYTHONPATH=src python examples/quickstart.py --depth 2 --backend pallas

Runs the full protocol of the paper's §5, generalized to arbitrary-depth
stacks (DESIGN.md §1): layerwise unsupervised epochs on each stack
projection, one supervised pass on the readout, then inference — and
reports per-image latencies and accuracy like Table 2.  ``--backend
pallas`` routes every projection through the fused stream-dataflow
kernels (Mosaic on TPU, interpret mode here).
(Offline container: data is a class-structured synthetic surrogate with
MNIST's shapes; drop a real mnist.npz under data/ to use actual MNIST.)
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import dataclasses

from repro.configs.bcpnn_models import MODEL1_MNIST, deep_mnist_spec
from repro.core import Trainer
from repro.data.synthetic import encode_images, load_or_synthesize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true",
                    help="subset + fewer epochs (CI-speed)")
    ap.add_argument("--depth", type=int, default=1,
                    help="number of hidden layers (1 = the paper's Model 1)")
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="jnp",
                    help="execution backend for every projection")
    args = ap.parse_args()

    ds = load_or_synthesize("mnist")
    n_train = 4096 if args.small else 16384
    epochs = 3 if args.small else 5

    if args.depth == 1:
        cfg = MODEL1_MNIST
        if args.small:
            cfg = dataclasses.replace(cfg, hidden_mc=64, noise_steps=60)
        spec = dataclasses.replace(cfg, backend=args.backend).network_spec()
        desc = f"input 784x2, hidden {cfg.hidden_hc}x{cfg.hidden_mc}"
    else:
        spec = deep_mnist_spec(
            depth=args.depth, backend=args.backend,
            hidden_mc=32 if args.small else 64)
        desc = " -> ".join(f"{p.post.H}x{p.post.M}" for p in spec.projs)
        desc = f"input 784x2, hidden {desc}"

    xt = encode_images(ds.x_train[:n_train])
    yt = ds.y_train[:n_train]
    xe = encode_images(ds.x_test[:2048])
    ye = ds.y_test[:2048]

    print(f"[quickstart] depth={args.depth} backend={args.backend}: {desc}, "
          f"{epochs} unsupervised epochs/layer")
    tr = Trainer(spec, seed=0)
    t0 = time.time()
    stats = tr.fit(xt, yt, epochs=epochs, batch=128, log=True)
    acc_train = tr.evaluate(xt, yt)
    acc_test = tr.evaluate(xe, ye)
    print(f"[quickstart] total time {time.time()-t0:.1f}s; "
          f"train latency {stats['train_ms_per_img']:.3f} ms/img")
    print(f"[quickstart] train acc {acc_train*100:.1f}%  "
          f"test acc {acc_test*100:.1f}%")
    # The 0.85 bar is calibrated to the paper's depth-1 Model 1; greedy
    # deep stacks trade accuracy on this small surrogate for the layered
    # representation, so deeper runs only have to beat chance clearly.
    floor = 0.85 if args.depth == 1 else 0.5
    assert acc_test > floor, \
        f"quickstart should learn the surrogate task ({acc_test:.3f} <= {floor})"


if __name__ == "__main__":
    main()
