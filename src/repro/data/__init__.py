from .synthetic import Dataset, encode_images, load_or_synthesize, make_synthetic
from .pipeline import Prefetcher, TokenStream, batch_indices
