"""Synthetic datasets with the paper's shapes (offline surrogate for
MNIST / MedMNIST-Pneumonia / MedMNIST-Breast — see DESIGN.md §5 data note).

Each class is a smooth random prototype image; samples are prototypes +
pixel noise + random translation, giving a class-structured, linearly
non-trivial task that BCPNN must actually learn.  Loaders accept real
``.npz`` files (keys: x_train, y_train, x_test, y_test) when present.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dataset:
    x_train: np.ndarray  # (N, H, W) float32 in [0,1]
    y_train: np.ndarray  # (N,) int32
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def image_shape(self) -> Tuple[int, int]:
        return self.x_train.shape[1:]


def _smooth(img: np.ndarray, iters: int = 2) -> np.ndarray:
    for _ in range(iters):
        img = (
            img
            + np.roll(img, 1, 0) + np.roll(img, -1, 0)
            + np.roll(img, 1, 1) + np.roll(img, -1, 1)
        ) / 5.0
    return img


def make_synthetic(
    n_train: int,
    n_test: int,
    side: int,
    n_classes: int,
    seed: int = 0,
    noise: float = 0.15,
    max_shift: int = 2,
) -> Dataset:
    rng = np.random.default_rng(seed)
    protos = _smooth(rng.random((n_classes, side, side)).astype(np.float32), 3)
    # contrast-stretch each prototype so classes are well separated even
    # after smoothing (smoothing alone can leave near-identical fields)
    mu = protos.mean(axis=(1, 2), keepdims=True)
    sd = protos.std(axis=(1, 2), keepdims=True) + 1e-9
    protos = np.clip(0.5 + 0.35 * (protos - mu) / sd, 0.0, 1.0)

    def sample(n: int) -> Tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, n_classes, size=n).astype(np.int32)
        x = protos[y].copy()
        if max_shift > 0:
            sh = rng.integers(-max_shift, max_shift + 1, size=(n, 2))
            for i in range(n):  # small n; fine on host
                x[i] = np.roll(x[i], sh[i], axis=(0, 1))
        x += rng.normal(0, noise, x.shape).astype(np.float32)
        return np.clip(x, 0, 1), y

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    return Dataset(xtr, ytr, xte, yte)


def load_or_synthesize(name: str, path_hint: str = "data") -> Dataset:
    """Load real data if an .npz is present, else synthesize paper shapes."""
    spec = {
        # name: (train, test, side, classes)  — paper Table 1
        "mnist": (60000, 10000, 28, 10),
        "pneumonia": (4708, 624, 28, 2),
        "breast": (546, 156, 64, 2),
    }[name]
    fp = os.path.join(path_hint, f"{name}.npz")
    if os.path.exists(fp):
        z = np.load(fp)
        return Dataset(
            z["x_train"].astype(np.float32), z["y_train"].astype(np.int32),
            z["x_test"].astype(np.float32), z["y_test"].astype(np.int32),
        )
    n_train, n_test, side, ncls = spec
    return make_synthetic(n_train, n_test, side, ncls, seed=hash(name) % 2**31)


def encode_images(x: np.ndarray) -> np.ndarray:
    """(N, H, W) images -> (N, 2*H*W) complement-pair HC rates (host side)."""
    flat = x.reshape(x.shape[0], -1)
    return np.stack([flat, 1.0 - flat], axis=-1).reshape(x.shape[0], -1)
