"""Host data pipeline: deterministic, seekable, prefetching.

Determinism/seekability is the fault-tolerance property: batch indices are
a pure function of (seed, step), so a restarted job resumes mid-epoch on
exactly the batch it would have seen — no replayed or skipped data after
an elastic restart, even at a different data-parallel size.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional, Tuple

import numpy as np


def batch_indices(n_items: int, batch: int, step: int, seed: int) -> np.ndarray:
    """Indices of global batch `step` under per-epoch shuffling."""
    steps_per_epoch = n_items // batch
    epoch = step // steps_per_epoch
    within = step % steps_per_epoch
    rng = np.random.default_rng(np.random.SeedSequence([seed, epoch]))
    perm = rng.permutation(n_items)
    return perm[within * batch: (within + 1) * batch]


class TokenStream:
    """Synthetic LM token stream (offline surrogate for a real corpus).

    Tokens follow a deterministic mixture of  zipfian unigrams and a
    repeated-ngram process, so models have actual structure to learn.
    """

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, step: int, batch: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        toks = rng.choice(self.vocab, size=(batch, seq_len), p=self._probs)
        # overlay repeated n-grams (learnable bigram structure)
        ngram = rng.choice(self.vocab, size=16, p=self._probs)
        pos = rng.integers(0, max(1, seq_len - 16), size=batch)
        for b in range(batch):
            if rng.random() < 0.5:
                toks[b, pos[b]: pos[b] + 16] = ngram
        return toks.astype(np.int32)


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded queue)."""

    def __init__(self, make_batch: Callable[[int], object], start_step: int,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def next(self) -> Tuple[int, object]:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=1.0)
