"""EngineHandle — the process-boundary-shaped seam between the router
and its engines.

``BCPNNRouter`` never touches a ``BCPNNService`` directly; it talks to
``EngineHandle``s.  The interface is deliberately shaped like an RPC
surface so a multiprocess/multihost transport can slot in later without
touching the router:

* every argument and return value is host data — numpy arrays, plain
  scalars, state pytrees of arrays (what a checkpoint codec would
  serialize), never jax tracers, futures, or engine-internal objects;
* requests are identified by plain integer ids scoped to the engine;
* liveness is an explicit probe (``alive``), not an exception side
  channel — a remote handle would back it with a heartbeat;
* state reads for reconciliation go through ``model_state_sync`` (a
  fold-boundary-consistent snapshot), because "read the live object"
  does not exist across a process boundary.

``LocalEngineHandle`` is the in-process implementation: a thin
delegation wrapper over one ``BCPNNService``.  It adds no behavior —
which is the point: everything the router needs must already be
expressible through this surface.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

from .engine import BCPNNService


class EngineHandle:
    """Abstract router-facing engine surface (see module docstring).

    Implementations must guarantee: ``submit`` either returns an
    engine-scoped request id or raises a typed admission error
    (``Overloaded``/``WorkerDied``); ``result`` resolves every admitted
    id exactly once (success or typed error, never a hang on a dead
    engine); ``kill`` is abrupt (pending futures complete
    ``WorkerDied``)."""

    name: str

    # -- placement / lifecycle
    def models(self) -> Tuple[str, ...]:
        raise NotImplementedError

    def add_model(self, model: str, state: Any, spec: Any,
                  weight: float = 1.0, live: bool = False) -> None:
        raise NotImplementedError

    def start(self, warmup: bool = True) -> None:
        raise NotImplementedError

    def stop(self, timeout_s: float = 60.0) -> None:
        raise NotImplementedError

    def kill(self, reason: str = "killed") -> None:
        raise NotImplementedError

    def alive(self) -> bool:
        raise NotImplementedError

    # -- data plane
    def submit(self, x: np.ndarray, model: str,
               deadline_t: Optional[float] = None) -> int:
        raise NotImplementedError

    def result(self, request_id: int, timeout: Optional[float] = None) -> Any:
        raise NotImplementedError

    def feedback(self, x: np.ndarray, label: int, model: str) -> None:
        raise NotImplementedError

    # -- telemetry / control plane
    def queue_depth(self, model: Optional[str] = None) -> int:
        raise NotImplementedError

    def feedback_depth(self, model: Optional[str] = None) -> int:
        raise NotImplementedError

    def quarantined(self, model: str) -> bool:
        raise NotImplementedError

    def snapshot(self, model: Optional[str] = None) -> Dict[str, Any]:
        raise NotImplementedError

    def model_state_sync(self, model: str, timeout_s: float = 60.0) -> Any:
        raise NotImplementedError

    def model_spec(self, model: str) -> Any:
        raise NotImplementedError

    def set_model_state(self, model: str, state: Any,
                        timeout_s: float = 60.0) -> None:
        raise NotImplementedError

    def revalidate(self) -> None:
        raise NotImplementedError


class LocalEngineHandle(EngineHandle):
    """In-process ``EngineHandle`` over one ``BCPNNService``."""

    def __init__(self, service: BCPNNService, name: Optional[str] = None):
        self.service = service
        self.name = name if name is not None else f"engine@{id(service):x}"

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"LocalEngineHandle({self.name!r})"

    # -- placement / lifecycle
    def models(self) -> Tuple[str, ...]:
        return self.service.models()

    def add_model(self, model: str, state: Any, spec: Any,
                  weight: float = 1.0, live: bool = False) -> None:
        self.service.add_model(model, state, spec, weight=weight, live=live)

    def start(self, warmup: bool = True) -> None:
        self.service.start(warmup=warmup)

    def stop(self, timeout_s: float = 60.0) -> None:
        self.service.stop(timeout_s=timeout_s)

    def kill(self, reason: str = "killed") -> None:
        self.service.kill(reason)

    def alive(self) -> bool:
        return self.service.alive()

    # -- data plane
    def submit(self, x: np.ndarray, model: str,
               deadline_t: Optional[float] = None) -> int:
        return self.service.submit(x, model=model, deadline_t=deadline_t)

    def result(self, request_id: int, timeout: Optional[float] = None) -> Any:
        return self.service.result(request_id, timeout=timeout)

    def feedback(self, x: np.ndarray, label: int, model: str) -> None:
        self.service.feedback(x, label, model=model)

    # -- telemetry / control plane
    def queue_depth(self, model: Optional[str] = None) -> int:
        return self.service.queue_depth(model)

    def feedback_depth(self, model: Optional[str] = None) -> int:
        return self.service.feedback_depth(model)

    def quarantined(self, model: str) -> bool:
        return self.service.quarantined(model)

    def snapshot(self, model: Optional[str] = None) -> Dict[str, Any]:
        return self.service.snapshot(model=model)

    def model_state_sync(self, model: str, timeout_s: float = 60.0) -> Any:
        return self.service.model_state_sync(model, timeout_s=timeout_s)

    def model_spec(self, model: str) -> Any:
        return self.service.model_spec(model)

    def set_model_state(self, model: str, state: Any,
                        timeout_s: float = 60.0) -> None:
        self.service.set_model_state(model, state, timeout_s=timeout_s)

    def revalidate(self) -> None:
        self.service.revalidate()
