"""Serving metrics: per-request latency records + aggregate snapshots.

The registry is written from two sides — the front-end thread records
admissions, the engine worker records batch executions, completions and
learn steps — so every mutation takes the lock.  Latencies are kept in a
bounded ring (last ``window`` requests); percentiles are computed on
demand from that ring, which is the usual serving-telemetry trade-off
(exact recent-window percentiles, O(window) memory).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Optional

import numpy as np


class ServeMetrics:
    """Thread-safe aggregate metrics for one serving engine."""

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self._lat_s = collections.deque(maxlen=window)
        self.submitted = 0
        self.completed = 0
        self.batches = 0
        self.occupied_slots = 0   # genuine samples across all batches
        self.padded_slots = 0     # pad slots across all batches
        self.learn_steps = 0
        self.learn_samples = 0
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------ record --
    def record_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n
            if self._t_start is None:
                self._t_start = time.perf_counter()

    def record_batch(self, n_valid: int, bucket: int) -> None:
        with self._lock:
            self.batches += 1
            self.occupied_slots += n_valid
            self.padded_slots += bucket - n_valid

    def record_complete(self, latency_s: float) -> None:
        with self._lock:
            self.completed += 1
            self._lat_s.append(latency_s)
            self._t_last = time.perf_counter()

    def record_learn(self, n_samples: int) -> None:
        with self._lock:
            self.learn_steps += 1
            self.learn_samples += n_samples

    # ---------------------------------------------------------- snapshot --
    def snapshot(self, queue_depth: int = 0) -> Dict[str, float]:
        """Aggregate view: throughput over the active window, latency
        percentiles over the recent ring, batching efficiency."""
        with self._lock:
            lat = np.asarray(self._lat_s, np.float64)
            elapsed = ((self._t_last - self._t_start)
                       if self._t_start is not None and self._t_last is not None
                       else 0.0)
            slots = self.occupied_slots + self.padded_slots
            out = {
                "submitted": float(self.submitted),
                "completed": float(self.completed),
                "queue_depth": float(queue_depth),
                "batches": float(self.batches),
                "batch_occupancy": (self.occupied_slots / slots
                                    if slots else 0.0),
                "learn_steps": float(self.learn_steps),
                "learn_samples": float(self.learn_samples),
                "images_per_s": (self.completed / elapsed
                                 if elapsed > 0 else 0.0),
            }
        for name, q in (("p50_ms", 50), ("p90_ms", 90), ("p99_ms", 99)):
            out[name] = float(np.percentile(lat, q) * 1e3) if lat.size else 0.0
        out["mean_ms"] = float(lat.mean() * 1e3) if lat.size else 0.0
        return out
