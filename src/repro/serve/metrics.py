"""Serving metrics: per-request latency records + aggregate snapshots.

The registry is written from two sides — the front-end thread records
admissions, the engine worker records batch executions, completions and
learn steps — so every mutation takes the lock.  Latencies are kept in a
bounded ring (last ``window`` requests); percentiles are computed on
demand from that ring, which is the usual serving-telemetry trade-off
(exact recent-window percentiles, O(window) memory).

Two more bounded windows feed the engine's adaptive bucket selection
(DESIGN.md §6): recent arrival timestamps (``arrival_rate_hz``) and
recent microbatch group sizes (``group_p90``).  Every record method takes
an optional explicit ``now`` so tests can drive a deterministic clock.

In a multi-model engine each model owns one ``ServeMetrics``;
``ServeMetrics.aggregate`` merges a set of them into one engine-wide
snapshot (counters summed, percentiles over the concatenated latency
rings).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Iterable, Optional

import numpy as np


def _percentile_keys(lat: np.ndarray) -> Dict[str, float]:
    out = {}
    for name, q in (("p50_ms", 50), ("p90_ms", 90), ("p99_ms", 99)):
        out[name] = float(np.percentile(lat, q) * 1e3) if lat.size else 0.0
    out["mean_ms"] = float(lat.mean() * 1e3) if lat.size else 0.0
    return out


class ServeMetrics:
    """Thread-safe aggregate metrics for one served model."""

    def __init__(self, window: int = 4096, rate_window: int = 256):
        self._lock = threading.Lock()
        self._lat_s = collections.deque(maxlen=window)
        self._arrivals = collections.deque(maxlen=rate_window)
        self._groups = collections.deque(maxlen=rate_window)
        self.submitted = 0
        self.completed = 0
        self.batches = 0
        self.occupied_slots = 0   # genuine samples across all batches
        self.padded_slots = 0     # pad slots across all batches
        self.learn_steps = 0
        self.learn_samples = 0
        # Robustness ladder (DESIGN.md §10).  Request accounting closes:
        # submitted == completed + shed + failed + still-pending.
        self.rejected = 0         # Overloaded at admission (never admitted)
        self.shed = 0             # deadline-expired, shed at dequeue
        self.failed = 0           # completed exceptionally (infer failure)
        self.crashes = 0          # supervised worker exceptions survived
        self.bisects = 0          # group splits while isolating a poison
        self.quarantine_events = 0  # non-finite folds rolled back
        self.feedback_dropped = 0   # labeled samples lost to fold
        #                             failure or quarantine
        self._t_start: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------ record --
    def record_submit(self, n: int = 1, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        with self._lock:
            self.submitted += n
            self._arrivals.append(now)
            if self._t_start is None:
                self._t_start = now

    def record_batch(self, n_valid: int, bucket: int) -> None:
        with self._lock:
            self.batches += 1
            self.occupied_slots += n_valid
            self.padded_slots += bucket - n_valid
            self._groups.append(n_valid)

    def record_complete(self, latency_s: float,
                        now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        with self._lock:
            self.completed += 1
            self._lat_s.append(latency_s)
            self._t_last = now

    def record_learn(self, n_samples: int) -> None:
        with self._lock:
            self.learn_steps += 1
            self.learn_samples += n_samples

    def record_rejected(self, n: int = 1) -> None:
        with self._lock:
            self.rejected += n

    def record_shed(self, n: int = 1) -> None:
        with self._lock:
            self.shed += n

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def record_crash(self) -> None:
        with self._lock:
            self.crashes += 1

    def record_bisect(self) -> None:
        with self._lock:
            self.bisects += 1

    def record_quarantine(self) -> None:
        with self._lock:
            self.quarantine_events += 1

    def record_feedback_dropped(self, n: int) -> None:
        with self._lock:
            self.feedback_dropped += n

    # ------------------------------------------------- adaptive windows --
    def arrival_rate_hz(self) -> float:
        """Observed arrival rate over the recent-arrival window (0 until
        two arrivals have landed)."""
        with self._lock:
            if len(self._arrivals) < 2:
                return 0.0
            span = self._arrivals[-1] - self._arrivals[0]
            if span <= 0:
                return 0.0
            return (len(self._arrivals) - 1) / span

    def group_p90(self) -> float:
        """90th-percentile genuine-group size over recent microbatches
        (0 until a batch has run) — the occupancy window the adaptive
        bucket policy uses to keep a backlog-sized bucket active."""
        with self._lock:
            if not self._groups:
                return 0.0
            return float(np.percentile(np.asarray(self._groups, np.float64),
                                       90))

    # ---------------------------------------------------------- snapshot --
    def snapshot(self, queue_depth: int = 0) -> Dict[str, float]:
        """Aggregate view: throughput over the active window, latency
        percentiles over the recent ring, batching efficiency."""
        with self._lock:
            lat = np.asarray(self._lat_s, np.float64)
            elapsed = ((self._t_last - self._t_start)
                       if self._t_start is not None and self._t_last is not None
                       else 0.0)
            slots = self.occupied_slots + self.padded_slots
            out = {
                "submitted": float(self.submitted),
                "completed": float(self.completed),
                "queue_depth": float(queue_depth),
                "batches": float(self.batches),
                "batch_occupancy": (self.occupied_slots / slots
                                    if slots else 0.0),
                "learn_steps": float(self.learn_steps),
                "learn_samples": float(self.learn_samples),
                "rejected": float(self.rejected),
                "shed": float(self.shed),
                "failed": float(self.failed),
                "crashes": float(self.crashes),
                "bisects": float(self.bisects),
                "quarantine_events": float(self.quarantine_events),
                "feedback_dropped": float(self.feedback_dropped),
                "images_per_s": (self.completed / elapsed
                                 if elapsed > 0 else 0.0),
            }
        out["arrival_rate_hz"] = self.arrival_rate_hz()
        out.update(_percentile_keys(lat))
        return out

    @staticmethod
    def aggregate(metrics: Iterable["ServeMetrics"],
                  queue_depth: int = 0) -> Dict[str, float]:
        """One engine-wide snapshot over per-model registries: counters
        summed, occupancy over pooled slots, throughput over the earliest
        start / latest completion, percentiles over the concatenated
        latency rings."""
        ms = list(metrics)
        lats, t0s, t1s = [], [], []
        out = {"submitted": 0.0, "completed": 0.0, "batches": 0.0,
               "learn_steps": 0.0, "learn_samples": 0.0,
               "rejected": 0.0, "shed": 0.0, "failed": 0.0, "crashes": 0.0,
               "bisects": 0.0, "quarantine_events": 0.0,
               "feedback_dropped": 0.0}
        occupied = padded = 0
        for m in ms:
            with m._lock:
                lats.append(np.asarray(m._lat_s, np.float64))
                out["submitted"] += m.submitted
                out["completed"] += m.completed
                out["batches"] += m.batches
                out["learn_steps"] += m.learn_steps
                out["learn_samples"] += m.learn_samples
                out["rejected"] += m.rejected
                out["shed"] += m.shed
                out["failed"] += m.failed
                out["crashes"] += m.crashes
                out["bisects"] += m.bisects
                out["quarantine_events"] += m.quarantine_events
                out["feedback_dropped"] += m.feedback_dropped
                occupied += m.occupied_slots
                padded += m.padded_slots
                if m._t_start is not None:
                    t0s.append(m._t_start)
                if m._t_last is not None:
                    t1s.append(m._t_last)
        lat = np.concatenate(lats) if lats else np.zeros((0,))
        slots = occupied + padded
        elapsed = (max(t1s) - min(t0s)) if t0s and t1s else 0.0
        out["queue_depth"] = float(queue_depth)
        out["batch_occupancy"] = occupied / slots if slots else 0.0
        out["images_per_s"] = (out["completed"] / elapsed
                               if elapsed > 0 else 0.0)
        out["arrival_rate_hz"] = sum(m.arrival_rate_hz() for m in ms)
        out.update(_percentile_keys(lat))
        return out


class RouterMetrics:
    """Thread-safe counters for the cross-engine router's own failure
    ladder (the per-engine ``ServeMetrics`` stay authoritative for
    engine-side accounting; these count what only the ROUTER can see:
    reroutes, engine losses, re-placements, reconciliation outcomes).

    Router accounting closes the same way the engine's does:
    ``submitted == completed + failed + pending`` over router-issued
    ids, and ``offered == submitted + rejected`` (rejected =
    ``NoHealthyReplica`` — no engine ever admitted the request)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.submitted = 0          # admitted somewhere, router id issued
        self.completed = 0          # resolved with a result
        self.failed = 0             # resolved with a typed error
        self.rejected = 0           # NoHealthyReplica (never admitted)
        self.reroutes = 0           # extra submission attempts past the 1st
        self.engine_losses = 0      # engines declared dead
        self.replacements = 0       # models re-placed after a loss
        self.reconciliations = 0    # replica sets verified consistent
        self.mismatches = 0         # replica sets found diverged
        self.repairs = 0            # replica states repaired/installed
        self.quarantine_drains = 0  # replica-level drain+revalidate cycles
        self.crashes = 0            # survived router-maintenance errors
        self._loss_t: Dict[str, float] = {}       # engine -> loss time
        self.recovery_s: Dict[str, float] = {}    # engine -> re-place lag

    def record_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n

    def record_complete(self, n: int = 1) -> None:
        with self._lock:
            self.completed += n

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def record_rejected(self, n: int = 1) -> None:
        with self._lock:
            self.rejected += n

    def record_reroute(self, n: int = 1) -> None:
        with self._lock:
            self.reroutes += n

    def record_engine_loss(self, engine: str,
                           now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        with self._lock:
            self.engine_losses += 1
            self._loss_t[engine] = now

    def record_replacement(self, engine: str,
                           now: Optional[float] = None) -> None:
        """One model re-placed after ``engine``'s loss; the lag from the
        loss to the LAST replacement is the recovery time the bench
        reports."""
        now = time.perf_counter() if now is None else now
        with self._lock:
            self.replacements += 1
            t0 = self._loss_t.get(engine)
            if t0 is not None:
                self.recovery_s[engine] = now - t0

    def record_reconciliation(self, consistent: bool) -> None:
        with self._lock:
            if consistent:
                self.reconciliations += 1
            else:
                self.mismatches += 1

    def record_repair(self, n: int = 1) -> None:
        with self._lock:
            self.repairs += n

    def record_quarantine_drain(self) -> None:
        with self._lock:
            self.quarantine_drains += 1

    def record_crash(self) -> None:
        with self._lock:
            self.crashes += 1

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            out = {
                "submitted": float(self.submitted),
                "completed": float(self.completed),
                "failed": float(self.failed),
                "rejected": float(self.rejected),
                "reroutes": float(self.reroutes),
                "engine_losses": float(self.engine_losses),
                "replacements": float(self.replacements),
                "reconciliations": float(self.reconciliations),
                "mismatches": float(self.mismatches),
                "repairs": float(self.repairs),
                "quarantine_drains": float(self.quarantine_drains),
                "crashes": float(self.crashes),
            }
            if self.recovery_s:
                out["recovery_s_max"] = max(self.recovery_s.values())
            return out
