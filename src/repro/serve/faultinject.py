"""Deterministic fault injection for the serving robustness layer.

The engine exposes four named injection points, consulted only when a
``FaultInjector`` is wired in (``BCPNNService(fault_injector=...)``) —
production wiring passes ``None`` and pays a single attribute check:

* ``infer-raise`` — the jitted forward of one microbatch raises
  (transient device/runtime failure).  Exercises the engine's
  poison-request bisection: the group splits and retries, so a
  transient failure costs a retry, not the whole batch.
* ``fold-raise`` — one feedback fold raises mid-learn.  Exercises
  worker supervision: the crash is counted, the batch's labeled samples
  are dropped, and the worker keeps serving.
* ``nan-state``  — the state a fold produced is corrupted with a NaN
  before the engine's non-finite sentinel sees it.  Exercises
  learning-state quarantine: rollback to the last-good snapshot +
  inference-only degradation.
* ``slow-batch`` — one microbatch is delayed by ``slow_ms`` before
  compute (a straggler).  Exercises the ``StepTimer`` wiring: the delay
  must surface as an attributed straggler event, not silent tail
  latency.
* ``engine-kill`` — the engine worker raises ``EngineKilled`` (a
  BaseException) before one microbatch's compute: the WHOLE engine dies
  abruptly, every pending future completes ``WorkerDied``.  Exercises
  the router's engine-loss recovery (reroute + re-placement).

Determinism: every point owns an independent counter and an independent
``np.random.default_rng([seed, point_index])`` stream, so WHICH
invocation of a point fires depends only on ``(seed, rates/schedule)``,
never on thread timing or on the other points' traffic.  An explicit
``schedule={point: {indices}}`` pins exact firing invocations (the unit
tests use this); ``rates={point: p}`` drives the seeded Bernoulli
schedule (the chaos soak uses this).  ``poison(request_id)`` marks
specific admitted requests as malformed — the engine raises
``FaultInjected`` for any microbatch containing one, which is what the
bisection isolates.

Every fired fault is recorded in ``events`` (point, invocation index,
wall time) so a soak can attribute exactly what was injected.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, List, Mapping, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from .errors import FaultInjected

# "engine-kill" is appended LAST so the per-point rng stream indices of
# the original four points stay stable across seeds recorded in older
# soak baselines.  It is consulted on the microbatch path and raises
# EngineKilled (a BaseException): the whole engine dies abruptly — the
# router-level chaos soak uses it to exercise engine-loss recovery.
POINTS = ("infer-raise", "fold-raise", "nan-state", "slow-batch",
          "engine-kill")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One fired injection: which point, its per-point invocation index,
    and (slow-batch only) the injected delay."""

    point: str
    index: int
    delay_s: float = 0.0
    t: float = 0.0   # wall time at firing (attribution only)


class FaultInjector:
    """Seeded, thread-safe fault schedule over the engine's named
    injection points."""

    def __init__(self, seed: int = 0,
                 rates: Optional[Mapping[str, float]] = None,
                 schedule: Optional[Mapping[str, Iterable[int]]] = None,
                 slow_ms: float = 25.0):
        for m in (rates or {}), (schedule or {}):
            unknown = set(m) - set(POINTS)
            if unknown:
                raise ValueError(f"unknown injection points {sorted(unknown)}; "
                                 f"known: {list(POINTS)}")
        self.seed = seed
        self.slow_ms = slow_ms
        self._rates = {p: float((rates or {}).get(p, 0.0)) for p in POINTS}
        self._schedule = {p: set((schedule or {}).get(p, ()))
                          for p in POINTS}
        # one independent stream per point: point A's traffic volume can
        # never shift WHICH of point B's invocations fire
        self._rngs = {p: np.random.default_rng([seed, i])
                      for i, p in enumerate(POINTS)}
        self._counts = {p: 0 for p in POINTS}
        self._poison: Set[int] = set()
        self._lock = threading.Lock()
        self.events: List[Fault] = []

    # ------------------------------------------------------------ points --
    def maybe(self, point: str) -> Optional[Fault]:
        """Advance ``point``'s invocation counter; return a ``Fault`` if
        this invocation fires (explicit schedule first, then the seeded
        Bernoulli draw — the draw happens every invocation so the stream
        stays aligned with the counter regardless of the schedule)."""
        with self._lock:
            k = self._counts[point]
            self._counts[point] = k + 1
            draw = float(self._rngs[point].random())
            fire = k in self._schedule[point] or draw < self._rates[point]
            if not fire:
                return None
            f = Fault(point=point, index=k,
                      delay_s=(self.slow_ms * 1e-3
                               if point == "slow-batch" else 0.0),
                      t=time.perf_counter())
            self.events.append(f)
            return f

    def raise_if(self, point: str) -> None:
        """``maybe`` + raise ``FaultInjected`` when the point fires."""
        f = self.maybe(point)
        if f is not None:
            raise FaultInjected(f"injected {point} "
                                f"(invocation {f.index}, seed {self.seed})")

    # ----------------------------------------------------------- poison --
    def poison(self, request_id: int) -> None:
        """Mark one admitted request as malformed: any microbatch that
        contains it fails infer, until bisection isolates it."""
        with self._lock:
            self._poison.add(request_id)

    def check_group(self, request_ids: Iterable[int]) -> None:
        """Raise ``FaultInjected`` if the group contains a poisoned id
        (the engine calls this where a malformed input would crash the
        jitted forward)."""
        with self._lock:
            bad = [r for r in request_ids if r in self._poison]
        if bad:
            raise FaultInjected(f"injected poison request(s) {bad}")

    # -------------------------------------------------------- nan-state --
    @staticmethod
    def corrupt_state(state):
        """Return ``state`` with a NaN written into its first float leaf
        (what a numerically-diverged fold looks like to the sentinel)."""
        leaves, treedef = jax.tree_util.tree_flatten(state)
        for i, leaf in enumerate(leaves):
            if hasattr(leaf, "dtype") and \
                    jnp.issubdtype(leaf.dtype, jnp.floating) and leaf.size:
                flat = jnp.ravel(leaf).at[0].set(jnp.nan)
                leaves[i] = flat.reshape(leaf.shape)
                break
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ----------------------------------------------------------- report --
    def counts(self) -> Dict[str, int]:
        """Fired-event count per point (attribution summary)."""
        with self._lock:
            out = {p: 0 for p in POINTS}
            for f in self.events:
                out[f.point] += 1
            return out
