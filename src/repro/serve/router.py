"""BCPNNRouter — a fault-tolerant multi-engine serving front.

The PR 8 ladder made ONE engine survive bad requests, bad folds and a
dying worker; the router composes N engines so the tier survives the
loss of an ENTIRE engine (DESIGN.md §11).  It fronts ``EngineHandle``s
(in-process ``LocalEngineHandle`` today; the interface is shaped for a
multiprocess transport) and owns five concerns:

* **Sticky placement with replica fan-out** — ``add_model(replicas=k)``
  pins a model to the k least-loaded engines and keeps serving it from
  those engines (stickiness keeps per-engine jit caches and adaptive
  buckets warm); hot models replicate, cheap ones do not.
* **Bounded reroute over per-engine admission** — a submit that hits
  ``Overloaded`` or ``WorkerDied`` on one replica retries on the next
  (least-depth first), at most ``max_reroutes`` extra hops; the
  ABSOLUTE deadline stamped at ROUTER admission rides along unchanged
  (``submit(deadline_t=...)``), so a rerouted request sheds at its
  original budget — a retry can never resurrect an expired request.
  Exhaustion raises ``NoHealthyReplica`` (an ``Overloaded``): the
  request was never admitted anywhere.
* **Engine-loss recovery** — a dead engine's in-flight futures were
  already completed ``WorkerDied`` by the engine's own ``_die`` (typed,
  exactly once — the router only translates ids, it never re-executes a
  possibly-served request).  The router then removes the engine from
  every placement and re-places orphaned/under-replicated models onto
  survivors, from a live peer's fold-boundary state when one exists,
  else from the model's last checkpoint (registration-time, refreshed
  at every reconciliation).
* **Replica-level quarantine drain** — a replica that trips the
  engine-level quarantine stops receiving new work for that model
  (``draining``), its already-admitted share drains on the engine, then
  ``revalidate()`` re-arms it and its state is repaired from a healthy
  peer before it rejoins the rotation (``heal``).
* **Replica reconciliation** — for replicated online-learning models
  the router broadcasts feedback to all replicas in one admission
  order; with ``feedback_eager=False`` engines, quiescent replicas are
  bit-identical by construction, and ``reconcile()`` verifies exactly
  that with the disjoint-support merge (``serve/reconcile.py``) —
  repairing any diverged replica from the authoritative one (max folded
  samples, finite).

Weighted fairness is delegated: placement passes each model's
``weight`` to the engines, whose start-time-fair scheduler charges
``n * cost/weight`` virtual time per microbatch — a Model-3-sized stack
pays for its size on every engine it lands on.

Locking: ``_lock`` (RLock) guards placement/liveness; recovery runs
under it — submits briefly block while a lost engine's models re-place
(bounded, honest unavailability), and feedback broadcast holds it so
every replica sees one admission order.  ``_requests_lock`` guards only
the id map.  Router accounting closes like the engine's: every router
id resolves exactly once (result/typed error), offered = submitted +
rejected.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from .engine import BCPNNService, ServeResult
from .errors import (
    NoHealthyReplica, Overloaded, Quarantined, ServeError, WorkerDied,
)
from .handle import EngineHandle, LocalEngineHandle
from .metrics import RouterMetrics
from .reconcile import (
    merge_replica_states, state_divergence, state_finite,
    states_bitwise_equal,
)


def _host_copy(state: Any) -> Any:
    """Host-array snapshot of a state pytree (what a checkpoint codec
    would serialize — the process-boundary-safe form)."""
    return jax.tree_util.tree_map(np.asarray, state)


@dataclasses.dataclass
class _Placement:
    """Router-side record of one hosted model."""

    model: str
    spec: Any
    weight: float
    online: bool                  # replicated feedback + reconciliation
    desired: int                  # replica fan-out target
    replicas: List[str]           # engine ids currently hosting (sticky)
    draining: Set[str] = dataclasses.field(default_factory=set)
    rr: int = 0                   # tie-break rotation for equal depths


class BCPNNRouter:
    """Cross-engine router over N ``EngineHandle``s (see module doc)."""

    def __init__(self, engines: Sequence[EngineHandle],
                 max_reroutes: int = 2,
                 default_deadline_s: Optional[float] = None):
        if not engines:
            raise ValueError("router needs at least one engine")
        names = [h.name for h in engines]
        if len(set(names)) != len(names):
            raise ValueError(f"engine names must be unique, got {names}")
        if max_reroutes < 0:
            raise ValueError(f"max_reroutes must be >= 0, got {max_reroutes}")
        self._engines: Dict[str, EngineHandle] = {h.name: h for h in engines}
        self.max_reroutes = max_reroutes
        self.default_deadline_s = default_deadline_s
        self._lock = threading.RLock()
        self._live: Set[str] = set(self._engines)
        self._placements: Dict[str, _Placement] = {}
        # model -> (host state, spec): the engine-loss recovery source of
        # last resort.  Written at add_model, refreshed by reconcile().
        self._checkpoints: Dict[str, Tuple[Any, Any]] = {}
        self._requests: Dict[int, Tuple[str, int, str]] = {}
        self._requests_lock = threading.Lock()
        self._next_id = 0
        self._started = False
        self.metrics = RouterMetrics()
        self.engine_errors: Dict[str, BaseException] = {}
        self._last_crash: Optional[BaseException] = None
        self._maint_thread: Optional[threading.Thread] = None
        self._maint_stop = threading.Event()

    # ------------------------------------------------------- construction --
    @classmethod
    def local(cls, n_engines: int, max_reroutes: int = 2,
              default_deadline_s: Optional[float] = None,
              **engine_kwargs) -> "BCPNNRouter":
        """Router over ``n_engines`` fresh in-process engines (each an
        EMPTY ``BCPNNService`` — models arrive via ``add_model``).
        ``engine_kwargs`` (max_batch, online_learning, feedback_batch,
        feedback_eager, max_queue, fault injectors are per-engine — pass
        a list via ``fault_injectors`` ...) configure every engine."""
        if n_engines < 1:
            raise ValueError(f"need >= 1 engines, got {n_engines}")
        injectors = engine_kwargs.pop("fault_injectors", None)
        if injectors is not None and len(injectors) != n_engines:
            raise ValueError(f"fault_injectors has {len(injectors)} "
                             f"entries for {n_engines} engines")
        handles = []
        for i in range(n_engines):
            kw = dict(engine_kwargs)
            if injectors is not None:
                kw["fault_injector"] = injectors[i]
            svc = BCPNNService(name=f"engine{i}", **kw)
            handles.append(LocalEngineHandle(svc, name=f"engine{i}"))
        return cls(handles, max_reroutes=max_reroutes,
                   default_deadline_s=default_deadline_s)

    # ---------------------------------------------------------- placement --
    def add_model(self, model: str, state, spec, replicas: int = 1,
                  weight: float = 1.0, online: bool = False) -> Tuple[str, ...]:
        """Place one model on the ``replicas`` least-loaded live engines
        (sticky).  ``online=True`` marks it for feedback broadcast +
        replica reconciliation.  Returns the chosen engine ids."""
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        with self._lock:
            if model in self._placements:
                raise ValueError(f"model {model!r} already placed")
            targets = self._pick_engines(model, replicas)
            if len(targets) < 1:
                raise RuntimeError("no live engine available for placement")
            for eid in targets:
                self._engines[eid].add_model(model, state, spec,
                                             weight=weight,
                                             live=self._started)
            place = _Placement(model=model, spec=spec, weight=weight,
                               online=online, desired=replicas,
                               replicas=list(targets))
            self._placements[model] = place
            self._checkpoints[model] = (_host_copy(state), spec)
            return tuple(targets)

    def _pick_engines(self, model: str, k: int,
                      exclude: Set[str] = frozenset()) -> List[str]:
        """The k least-loaded live engines not already hosting ``model``
        (load = hosted model count, ties by engine id — deterministic)."""
        cands = [eid for eid in sorted(self._live)
                 if eid not in exclude
                 and model not in self._engines[eid].models()]
        cands.sort(key=lambda e: (len(self._engines[e].models()), e))
        return cands[:k]

    def models(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(self._placements)

    def placement(self, model: str) -> Dict[str, Any]:
        with self._lock:
            p = self._placement(model)
            return {"replicas": tuple(p.replicas), "desired": p.desired,
                    "draining": tuple(sorted(p.draining)),
                    "weight": p.weight, "online": p.online}

    def _placement(self, model: Optional[str]) -> _Placement:
        if model is None:
            if len(self._placements) == 1:
                return next(iter(self._placements.values()))
            raise ValueError(
                f"router hosts {sorted(self._placements)}; pass "
                f"model=<name> to route the request")
        try:
            return self._placements[model]
        except KeyError:
            raise KeyError(f"unknown model {model!r}; hosted: "
                           f"{sorted(self._placements)}") from None

    # ----------------------------------------------------------- lifecycle --
    def start(self, warmup: bool = True) -> "BCPNNRouter":
        with self._lock:
            for eid in sorted(self._live):
                self._engines[eid].start(warmup=warmup)
            self._started = True
        return self

    def stop(self, timeout_s: float = 60.0) -> Dict[str, BaseException]:
        """Drain every live engine.  Engines that died (chaos kills,
        real faults) raise their terminal ``WorkerDied`` from stop();
        the router RECORDS those instead of propagating — the loss was
        already handled, and a clean router shutdown must not depend on
        every engine having survived.  Returns {engine: error}."""
        self.stop_maintenance()
        errors: Dict[str, BaseException] = {}
        for eid in sorted(self._engines):
            try:
                self._engines[eid].stop(timeout_s=timeout_s)
            except (ServeError, RuntimeError) as e:
                errors[eid] = e
                self._on_engine_loss(eid, recover=False)
        with self._lock:
            self.engine_errors.update(errors)
            self._started = False
        return errors

    # ---------------------------------------------------------- data plane --
    def submit(self, x: np.ndarray, model: Optional[str] = None,
               deadline_s: Optional[float] = None) -> int:
        """Admit one sample; returns a ROUTER request id.

        The deadline becomes ABSOLUTE here, at router admission, and is
        carried verbatim across every reroute hop — the budget is one
        request's end-to-end allowance, not per-attempt.  ``Overloaded``
        / ``WorkerDied`` on a replica triggers rerouting to the next
        (bounded by ``max_reroutes`` extra attempts, each to a distinct
        replica); exhaustion — or a budget that expired mid-reroute —
        raises ``NoHealthyReplica`` without having admitted anywhere."""
        with self._lock:
            model = self._placement(model).model
        d = self.default_deadline_s if deadline_s is None else deadline_s
        deadline_t = (time.perf_counter() + d) if d is not None else None
        attempts = 0
        tried: Set[str] = set()
        last: Optional[BaseException] = None
        while attempts < 1 + self.max_reroutes:
            if deadline_t is not None and time.perf_counter() > deadline_t:
                break  # expired mid-reroute: never resurrect it
            eid = self._pick_replica(model, tried)
            if eid is None:
                break
            attempts += 1
            if attempts > 1:
                self.metrics.record_reroute()
            try:
                erid = self._engines[eid].submit(x, model=model,
                                                 deadline_t=deadline_t)
            except Overloaded as e:
                last = e
                tried.add(eid)
                continue
            except WorkerDied as e:
                last = e
                tried.add(eid)
                self._on_engine_loss(eid)
                continue
            with self._requests_lock:
                rid = self._next_id
                self._next_id += 1
                self._requests[rid] = (eid, erid, model)
            self.metrics.record_submit()
            return rid
        self.metrics.record_rejected()
        raise NoHealthyReplica(model, attempts, last)

    def _pick_replica(self, model: str, exclude: Set[str]) -> Optional[str]:
        """Least-depth live non-draining replica (deadline-aware queue
        picking: depth is the wait), ties rotated so equal-depth
        replicas share load."""
        with self._lock:
            place = self._placements[model]
            cands = [e for e in place.replicas
                     if e in self._live and e not in place.draining
                     and e not in exclude]
            if not cands:
                return None
            rr = place.rr
            place.rr = rr + 1
            order = {e: (cands.index(e) - rr) % len(cands) for e in cands}
            return min(cands, key=lambda e: (
                self._engines[e].queue_depth(model), order[e]))

    def result(self, request_id: int,
               timeout: Optional[float] = None) -> ServeResult:
        """Resolve one router id exactly once (result or typed error;
        the id is forgotten either way).  A ``WorkerDied`` here is the
        exactly-once completion of an in-flight request on a lost engine
        — the router triggers recovery and re-raises; it never re-runs
        the request (it may have executed before the death)."""
        with self._requests_lock:
            eid, erid, model = self._requests[request_id]
        try:
            res = self._engines[eid].result(erid, timeout=timeout)
        except WorkerDied:
            self.metrics.record_failed()
            self._on_engine_loss(eid)
            raise
        except BaseException:
            # typed serving errors, fault injections, timeouts — router
            # accounting counts the failure and re-raises unchanged
            self.metrics.record_failed()
            raise
        finally:
            with self._requests_lock:
                self._requests.pop(request_id, None)
        self.metrics.record_complete()
        return dataclasses.replace(res, request_id=request_id)

    def classify(self, x: np.ndarray, timeout: Optional[float] = None,
                 model: Optional[str] = None) -> ServeResult:
        return self.result(self.submit(x, model=model), timeout=timeout)

    def feedback(self, x: np.ndarray, label: int,
                 model: Optional[str] = None) -> None:
        """Broadcast one labeled sample to every live replica, under the
        router lock so all replicas see the SAME admission order — the
        precondition for bit-identical replicas (reconcile.py).  Raises
        ``Quarantined`` only if NO replica folded it (the label tick is
        lost, as the single-engine ladder already defines)."""
        for _ in range(2):  # one retry round if a loss re-placed mid-cast
            with self._lock:
                place = self._placement(model)
                model = place.model
                targets = [e for e in place.replicas if e in self._live]
                delivered = 0
                lost: List[str] = []
                for eid in targets:
                    try:
                        self._engines[eid].feedback(x, int(label), model)
                        delivered += 1
                    except Quarantined:
                        place.draining.add(eid)
                    except WorkerDied:
                        lost.append(eid)
                for eid in lost:
                    self._on_engine_loss(eid)
            if delivered > 0:
                return
            if not lost:
                break
        raise Quarantined(model)

    # ------------------------------------------------- engine-loss ladder --
    def check_engines(self) -> Tuple[str, ...]:
        """Probe liveness; declare dead engines lost (idempotent).
        Returns the engines declared lost by THIS call."""
        with self._lock:
            dead = tuple(e for e in sorted(self._live)
                         if not self._engines[e].alive())
        for eid in dead:
            self._on_engine_loss(eid)
        return dead

    def _on_engine_loss(self, eid: str, recover: bool = True) -> None:
        """Declare one engine dead and re-place its models (idempotent:
        a loss observed concurrently by submit, result, feedback and the
        maintenance probe runs recovery once).  Runs under the router
        lock: admission blocks for the (bounded) re-placement — honest,
        visible unavailability instead of racing a half-recovered
        placement."""
        with self._lock:
            if eid not in self._live:
                return
            self._live.discard(eid)
            self.metrics.record_engine_loss(eid)
            for place in self._placements.values():
                if eid in place.replicas:
                    place.replicas.remove(eid)
                    place.draining.discard(eid)
            if not recover:
                return
            for place in self._placements.values():
                self._top_up(place, lost=eid)

    def _top_up(self, place: _Placement, lost: Optional[str] = None) -> None:
        """Restore a placement to its desired replica count from live
        peer state (preferred: newest folds) or the model's checkpoint.
        Caller holds the lock."""
        while True:
            live = [e for e in place.replicas if e in self._live]
            if len(live) >= place.desired:
                return
            targets = self._pick_engines(place.model, 1)
            if not targets:
                return  # not enough engines left; serve degraded
            state, spec = self._recovery_source(place, live)
            eid = targets[0]
            self._engines[eid].add_model(place.model, state, spec,
                                         weight=place.weight,
                                         live=self._started)
            place.replicas.append(eid)
            if lost is not None:
                self.metrics.record_replacement(lost)

    def _recovery_source(self, place: _Placement,
                         live: Sequence[str]) -> Tuple[Any, Any]:
        """Newest usable state for a re-placement: a live peer's
        fold-boundary snapshot when one answers (it has every fold since
        the checkpoint), else the checkpoint."""
        for eid in live:
            if eid in place.draining:
                continue
            try:
                state = self._engines[eid].model_state_sync(place.model)
                if state_finite(state):
                    return state, self._engines[eid].model_spec(place.model)
            except (ServeError, TimeoutError):
                continue  # peer is struggling; fall through to checkpoint
        ckpt_state, ckpt_spec = self._checkpoints[place.model]
        return ckpt_state, ckpt_spec

    # ------------------------------------------- quarantine drain + heal --
    def heal(self, model: Optional[str] = None,
             drain_timeout_s: float = 30.0) -> Dict[str, List[str]]:
        """Replica-level quarantine ladder (DESIGN.md §11): quarantined
        replicas are marked ``draining`` (no new routed work — their
        share sheds to healthy peers), their already-admitted queue
        drains on the engine, then ``revalidate()`` re-arms them and
        their state is repaired from a healthy peer before they rejoin.
        Returns {model: [healed engine ids]}."""
        with self._lock:
            targets = ([self._placement(model).model] if model is not None
                       else list(self._placements))
            for m in targets:
                place = self._placements[m]
                for eid in place.replicas:
                    if eid in self._live and \
                            self._engines[eid].quarantined(m):
                        place.draining.add(eid)
            work = {m: [e for e in self._placements[m].draining
                        if e in self._live] for m in targets}
        healed: Dict[str, List[str]] = {m: [] for m in targets}
        for m, eids in work.items():
            for eid in eids:
                if self._drain_and_revalidate(m, eid, drain_timeout_s):
                    healed[m].append(eid)
        return {m: v for m, v in healed.items() if v}

    def _drain_and_revalidate(self, model: str, eid: str,
                              drain_timeout_s: float) -> bool:
        """One replica's drain -> revalidate -> repair -> rejoin."""
        handle = self._engines[eid]
        end = time.perf_counter() + drain_timeout_s
        while handle.queue_depth(model) > 0:
            if not handle.alive():
                self._on_engine_loss(eid)
                return False
            if time.perf_counter() > end:
                return False  # still draining; a later heal() retries
            time.sleep(0.005)
        with self._lock:  # freeze feedback while repairing
            place = self._placements[model]
            try:
                handle.revalidate()
                peers = [e for e in place.replicas
                         if e in self._live and e != eid
                         and e not in place.draining]
                if peers:
                    src = self._engines[peers[0]]
                    peer_state = src.model_state_sync(model)
                    handle.set_model_state(model, peer_state)
                    self.metrics.record_repair()
            except WorkerDied:
                self._on_engine_loss(eid)
                return False
            except (ServeError, TimeoutError, ValueError) as e:
                self._note_crash(e)
                return False
            place.draining.discard(eid)
            self.metrics.record_quarantine_drain()
            return True

    # ------------------------------------------------------ reconciliation --
    def reconcile(self, model: Optional[str] = None) -> Dict[str, Dict]:
        """Verify (and repair) replica consistency for online-learning
        models via the disjoint-support merge.  Holds the router lock:
        no feedback lands mid-comparison, and every state is read at a
        fold boundary (``model_state_sync``) — so a consistent verdict
        is a statement about the same folded prefix on every replica.
        Non-quiescent placements (buffered unfolded feedback) are
        SKIPPED, not guessed at: with ``feedback_eager=False`` a partial
        buffer means the replicas are mid-prefix by design.

        Returns {model: report}; consistent replica sets refresh the
        model's recovery checkpoint."""
        out: Dict[str, Dict] = {}
        with self._lock:
            targets = ([self._placement(model).model] if model is not None
                       else [m for m, p in self._placements.items()
                             if p.online])
            for m in targets:
                out[m] = self._reconcile_one(self._placements[m])
        return out

    def _reconcile_one(self, place: _Placement) -> Dict[str, Any]:
        """Caller holds the lock."""
        eids = [e for e in place.replicas
                if e in self._live and e not in place.draining]
        if not eids:
            return {"skipped": "no live replicas"}
        try:
            depths = {e: self._engines[e].feedback_depth(place.model)
                      for e in eids}
        except ServeError as e:
            self._note_crash(e)
            return {"skipped": f"telemetry failed: {e}"}
        if any(depths.values()):
            return {"skipped": f"not quiescent (buffered feedback "
                               f"{depths})"}
        states: Dict[str, Any] = {}
        for e in eids:
            try:
                states[e] = self._engines[e].model_state_sync(place.model)
            except WorkerDied:
                self._on_engine_loss(e)
            except (ServeError, TimeoutError) as err:
                self._note_crash(err)
        if not states:
            return {"skipped": "no replica answered"}
        order = sorted(states)
        merged = merge_replica_states([states[e] for e in order])
        consistent = all(states_bitwise_equal(merged, states[e])
                         for e in order)
        self.metrics.record_reconciliation(consistent)
        report: Dict[str, Any] = {"consistent": consistent,
                                  "replicas": order}
        if consistent:
            with self._lock:  # re-entrant; the lexical block is the contract
                self._checkpoints[place.model] = (_host_copy(merged),
                                                  place.spec)
            return report
        # diverged: crown the replica with the most folded samples (and
        # a finite state) authoritative, repair the laggards
        def folded(e: str) -> float:
            return self._engines[e].snapshot(
                model=place.model).get("learn_samples", 0.0)
        finite = [e for e in order if state_finite(states[e])]
        if not finite:
            report["repaired"] = []
            report["error"] = "no finite replica state; left untouched"
            return report
        # most folded samples wins; on a tie (e.g. a stale state restore
        # keeps the counters equal) the first replica id, deterministically
        auth = min(finite, key=lambda e: (-folded(e), e))
        repaired: List[str] = []
        for e in order:
            if e == auth or states_bitwise_equal(states[e], states[auth]):
                continue
            report.setdefault("divergence", state_divergence(
                states[auth], states[e])[:4])
            try:
                self._engines[e].set_model_state(place.model, states[auth])
                self.metrics.record_repair()
                repaired.append(e)
            except WorkerDied:
                self._on_engine_loss(e)
            except (ServeError, TimeoutError, ValueError) as err:
                self._note_crash(err)
        report["authoritative"] = auth
        report["repaired"] = repaired
        with self._lock:
            self._checkpoints[place.model] = (_host_copy(states[auth]),
                                              place.spec)
        return report

    # ----------------------------------------------------------- telemetry --
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            live = sorted(self._live)
            dead = sorted(set(self._engines) - self._live)
            placements = {m: self.placement(m) for m in self._placements}
        out: Dict[str, Any] = {"router": self.metrics.snapshot(),
                               "live_engines": live,
                               "dead_engines": dead,
                               "placements": placements}
        out["engines"] = {}
        for eid in live:
            try:
                out["engines"][eid] = self._engines[eid].snapshot()
            except (ServeError, RuntimeError) as e:
                out["engines"][eid] = {"error": repr(e)}
        return out

    def _note_crash(self, e: BaseException) -> None:
        """Supervision sink for survivable router-side errors (counted,
        never silently swallowed)."""
        self.metrics.record_crash()
        self._last_crash = e

    # ---------------------------------------------------------- maintenance --
    def start_maintenance(self, period_s: float = 1.0) -> None:
        """Background supervision: periodic liveness probe + quarantine
        heal + reconciliation.  Optional — every pass is also callable
        directly (tests drive the ladder deterministically)."""
        if self._maint_thread is not None:
            raise RuntimeError("maintenance already running")
        self._maint_stop.clear()

        def loop() -> None:
            while not self._maint_stop.wait(period_s):
                try:
                    self.check_engines()
                    self.heal()
                    self.reconcile()
                except Exception as e:
                    # supervised: a maintenance bug must not kill the
                    # router's background ladder
                    self._note_crash(e)

        self._maint_thread = threading.Thread(
            target=loop, daemon=True, name="bcpnn-router-maint")
        self._maint_thread.start()

    def stop_maintenance(self) -> None:
        t = self._maint_thread
        if t is None:
            return
        self._maint_stop.set()
        t.join(timeout=30.0)
        self._maint_thread = None
