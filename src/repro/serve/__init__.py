"""Serving subsystem: multi-model microbatched streaming inference +
in-deployment online learning for trained deep BCPNN networks
(DESIGN.md §6), with a typed robustness ladder — admission control,
deadlines/load-shedding, worker supervision, learning-state quarantine —
and a deterministic fault-injection harness (DESIGN.md §10)."""
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .engine import BCPNNService, ServeResult, cycle_batch
from .errors import (
    DeadlineExceeded, FaultInjected, Overloaded, Quarantined, ServeError,
    WorkerDied,
)
from .faultinject import POINTS, Fault, FaultInjector
from .loadgen import LoadReport, StreamSpec, run_multi_open_loop, run_open_loop
from .metrics import ServeMetrics

__all__ = [
    "MicroBatcher", "Request", "default_buckets", "pad_group", "pick_bucket",
    "BCPNNService", "ServeResult", "cycle_batch",
    "ServeError", "Overloaded", "DeadlineExceeded", "WorkerDied",
    "Quarantined", "FaultInjected",
    "POINTS", "Fault", "FaultInjector",
    "LoadReport", "StreamSpec", "run_multi_open_loop", "run_open_loop",
    "ServeMetrics",
]
