"""Serving subsystem: multi-model microbatched streaming inference +
in-deployment online learning for trained deep BCPNN networks
(DESIGN.md §6)."""
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .engine import BCPNNService, ServeResult, cycle_batch
from .loadgen import LoadReport, StreamSpec, run_multi_open_loop, run_open_loop
from .metrics import ServeMetrics

__all__ = [
    "MicroBatcher", "Request", "default_buckets", "pad_group", "pick_bucket",
    "BCPNNService", "ServeResult", "cycle_batch",
    "LoadReport", "StreamSpec", "run_multi_open_loop", "run_open_loop",
    "ServeMetrics",
]
