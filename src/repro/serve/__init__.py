"""Serving subsystem: multi-model microbatched streaming inference +
in-deployment online learning for trained deep BCPNN networks
(DESIGN.md §6), with a typed robustness ladder — admission control,
deadlines/load-shedding, worker supervision, learning-state quarantine —
a deterministic fault-injection harness (DESIGN.md §10), and a
fault-tolerant multi-engine router — replica failover, bounded
reroute-on-overload, engine-loss recovery, replica reconciliation
(DESIGN.md §11)."""
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .engine import BCPNNService, ServeResult, cycle_batch
from .errors import (
    DeadlineExceeded, EngineKilled, FaultInjected, NoHealthyReplica,
    Overloaded, Quarantined, ServeError, WorkerDied,
)
from .faultinject import POINTS, Fault, FaultInjector
from .handle import EngineHandle, LocalEngineHandle
from .loadgen import LoadReport, StreamSpec, run_multi_open_loop, run_open_loop
from .metrics import RouterMetrics, ServeMetrics
from .reconcile import (
    chunk_bounds, merge_replica_states, state_divergence, state_finite,
    states_bitwise_equal,
)
from .router import BCPNNRouter

__all__ = [
    "MicroBatcher", "Request", "default_buckets", "pad_group", "pick_bucket",
    "BCPNNService", "ServeResult", "cycle_batch",
    "ServeError", "Overloaded", "DeadlineExceeded", "WorkerDied",
    "Quarantined", "FaultInjected", "NoHealthyReplica", "EngineKilled",
    "POINTS", "Fault", "FaultInjector",
    "EngineHandle", "LocalEngineHandle", "BCPNNRouter",
    "chunk_bounds", "merge_replica_states", "states_bitwise_equal",
    "state_divergence", "state_finite",
    "LoadReport", "StreamSpec", "run_multi_open_loop", "run_open_loop",
    "ServeMetrics", "RouterMetrics",
]
