"""Serving subsystem: microbatched streaming inference + online learning
for trained deep BCPNN networks (DESIGN.md §6)."""
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .engine import BCPNNService, ServeResult
from .loadgen import LoadReport, run_open_loop
from .metrics import ServeMetrics

__all__ = [
    "MicroBatcher", "Request", "default_buckets", "pad_group", "pick_bucket",
    "BCPNNService", "ServeResult",
    "LoadReport", "run_open_loop",
    "ServeMetrics",
]
