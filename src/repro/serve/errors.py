"""Typed failure ladder of the serving tier (DESIGN.md §10).

Every way a request or a labeled feedback sample can fail to be served
normally has ONE exception type, so clients can branch on class instead
of parsing messages:

* ``Overloaded``       — rejected at admission: the target model's queue
  is at its ``max_queue`` bound.  The request was never admitted; retry
  with backoff (or against a replica).
* ``DeadlineExceeded`` — admitted, but shed at dequeue time because its
  per-request deadline had already expired before padding/compute.  No
  device work was spent on it.
* ``WorkerDied``       — the engine worker thread exited abnormally; the
  request (and every other pending one) was completed exceptionally so
  nothing hangs.  The service instance is dead — ``stop()`` re-raises
  the cause.
* ``Quarantined``      — the target model's learning state tripped the
  non-finite sentinel and the slot is serving inference-only from its
  last-good snapshot; labeled feedback is refused until
  ``revalidate()`` clears the quarantine.
* ``FaultInjected``    — raised by ``serve/faultinject.py`` injection
  points (and by nothing else); seeing it outside a fault-injection run
  means an injector leaked into production wiring.

``ServeError`` is the common base for the first four, so "any serving
failure" is one except clause.
"""
from __future__ import annotations


class ServeError(RuntimeError):
    """Base class of all typed serving-tier failures."""


class Overloaded(ServeError):
    """Admission rejected: the per-model queue is at its bound."""

    def __init__(self, model: str, depth: int, max_queue: int):
        super().__init__(
            f"model {model!r} queue at max_queue bound "
            f"({depth}/{max_queue}); request rejected at admission")
        self.model = model
        self.depth = depth
        self.max_queue = max_queue


class NoHealthyReplica(Overloaded):
    """Router-level rejection: every candidate replica rejected the
    request (``Overloaded``/``WorkerDied``) within the bounded reroute
    budget, or the request's deadline expired mid-reroute.  Subclasses
    ``Overloaded`` so open-loop clients that already treat admission
    rejection as "count and move on" need no new branch — the request
    was never admitted anywhere."""

    def __init__(self, model: str, attempts: int,
                 last_error: "BaseException | None" = None):
        detail = (f"; last: {type(last_error).__name__}: {last_error}"
                  if last_error is not None else "")
        RuntimeError.__init__(
            self, f"model {model!r}: no healthy replica admitted the "
                  f"request after {attempts} attempt(s){detail}")
        self.model = model
        self.attempts = attempts
        self.last_error = last_error
        self.depth = -1        # Overloaded attr compat: not one queue's
        self.max_queue = -1    # bound but the whole replica set's


class EngineKilled(BaseException):
    """Injected abrupt engine death (chaos testing only).  Deliberately
    a ``BaseException`` so the supervised worker loop's ``except
    Exception`` does NOT survive it — it reaches the terminal ``_die``
    path exactly like a real interpreter-level failure would, completing
    every pending future with ``WorkerDied``."""


class DeadlineExceeded(ServeError):
    """Admitted request shed at dequeue: its deadline expired before
    padding/compute."""

    def __init__(self, request_id: int, deadline_s: float, waited_s: float):
        super().__init__(
            f"request {request_id} shed: deadline {deadline_s * 1e3:.1f}ms "
            f"expired after {waited_s * 1e3:.1f}ms in queue")
        self.request_id = request_id
        self.deadline_s = deadline_s
        self.waited_s = waited_s


class WorkerDied(ServeError):
    """The engine worker thread exited abnormally; pending requests were
    completed with this error so nothing hangs."""


class Quarantined(ServeError):
    """The model's learning state is quarantined (inference-only from
    its last-good snapshot); feedback is refused until revalidate()."""

    def __init__(self, model: str):
        super().__init__(
            f"model {model!r} is quarantined (non-finite learning state "
            f"detected and rolled back); serving inference-only — call "
            f"revalidate() to re-arm learning")
        self.model = model


class FaultInjected(RuntimeError):
    """Raised only by serve/faultinject.py injection points."""
