"""Replica state reconciliation: the bit-exact disjoint-support merge,
lifted from the data-parallel trace all-reduce to whole model states.

Why replicas agree in the first place (the protocol, DESIGN.md §11):
the router BROADCASTS every labeled feedback sample of a replicated
online-learning model to all live replicas in one admission order, and
replicas run ``feedback_eager=False`` — folds fire only on FULL
feedback batches, so the fold compositions are a pure function of the
feedback stream prefix, never of worker timing.  Two replicas that have
folded the same prefix (both feedback buffers empty = quiescent) are
therefore bit-identical by construction, exactly like the served-vs-
offline-replay parity the PR 5 tests pin.

Reconciliation VERIFIES that invariant (and repairs drift): each of the
K replicas contributes one contiguous chunk of every raveled state
leaf, each chunk is scattered into zeros at its own offset, and the K
zero-padded partials are summed — the disjoint-support merge of
``distributed/data_parallel.py::_co_allreduce_dense``, generalized from
post-column shards to arbitrary contiguous chunks (no divisibility
constraint).  Every element of the merged leaf is one real value plus
zeros, so IF the replicas agree the merge is bit-identical to every one
of them; if they diverged, the merged state differs from at least one
replica and the router repairs the laggards from the authoritative
replica (max folded samples, finite).

Everything here is host-side numpy on settled states — reconciliation
runs at fold boundaries (``EngineHandle.model_state_sync``), never on
the per-request path.
"""
from __future__ import annotations

from typing import Any, List, Sequence, Tuple

import jax
import numpy as np


def chunk_bounds(n: int, k: int) -> List[Tuple[int, int]]:
    """K contiguous [start, stop) chunks covering range(n) — first
    ``n % k`` chunks one element longer (numpy array_split convention),
    so any leaf size shards over any replica count, empty chunks
    included."""
    if k < 1:
        raise ValueError(f"need k >= 1 chunks, got {k}")
    base, extra = divmod(n, k)
    out, start = [], 0
    for i in range(k):
        size = base + (1 if i < extra else 0)
        out.append((start, start + size))
        start += size
    return out


def _leaves(state: Any) -> List[np.ndarray]:
    return [np.asarray(leaf) for leaf in jax.tree_util.tree_leaves(state)]


def merge_replica_states(states: Sequence[Any]) -> Any:
    """Disjoint-support merge of K replica states (same treedef) into
    one: replica i contributes chunk i of every raveled leaf, scattered
    into zeros and summed.  Bit-identical to each input iff the
    replicas agree (see module docstring); returns a state pytree with
    the first replica's treedef."""
    states = list(states)
    if not states:
        raise ValueError("merge_replica_states needs >= 1 replica state")
    k = len(states)
    flats = [_leaves(s) for s in states]
    treedef = jax.tree_util.tree_structure(states[0])
    n_leaves = len(flats[0])
    for i, f in enumerate(flats[1:], 1):
        if len(f) != n_leaves:
            raise ValueError(f"replica {i} has {len(f)} leaves, replica 0 "
                             f"has {n_leaves} — states are not congruent")
    merged: List[np.ndarray] = []
    for leaf_idx in range(n_leaves):
        ref = flats[0][leaf_idx]
        bounds = chunk_bounds(ref.size, k)
        # zero-padded disjoint partials + sum — each element is one real
        # value plus zeros (the _co_allreduce_dense reassembly, with
        # np.add standing in for psum on host arrays)
        acc = np.zeros(ref.size, dtype=ref.dtype)
        for r, (a, b) in enumerate(bounds):
            part = np.zeros(ref.size, dtype=ref.dtype)
            part[a:b] = flats[r][leaf_idx].reshape(-1)[a:b]
            acc = np.add(acc, part)
        merged.append(acc.reshape(ref.shape))
    return jax.tree_util.tree_unflatten(treedef, merged)


def states_bitwise_equal(a: Any, b: Any) -> bool:
    """True iff two state pytrees agree leaf-for-leaf, bit-for-bit
    (dtype and content; NaNs compared by bit pattern, not by IEEE
    semantics — a reconciler must treat two identical NaN payloads as
    'same state', not 'diverged')."""
    fa, fb = _leaves(a), _leaves(b)
    if len(fa) != len(fb):
        return False
    for la, lb in zip(fa, fb):
        if la.dtype != lb.dtype or la.shape != lb.shape:
            return False
        if la.tobytes() != lb.tobytes():
            return False
    return True


def state_divergence(a: Any, b: Any) -> List[str]:
    """Human-readable description of where two states diverge (empty if
    bit-identical) — reconciliation reports name the drifted leaves."""
    out: List[str] = []
    paths_a = jax.tree_util.tree_flatten_with_path(a)[0]
    paths_b = jax.tree_util.tree_flatten_with_path(b)[0]
    if len(paths_a) != len(paths_b):
        return [f"leaf count differs: {len(paths_a)} vs {len(paths_b)}"]
    for (ka, la), (_, lb) in zip(paths_a, paths_b):
        la, lb = np.asarray(la), np.asarray(lb)
        where = jax.tree_util.keystr(ka)
        if la.dtype != lb.dtype or la.shape != lb.shape:
            out.append(f"{where}: {la.dtype}{la.shape} vs "
                       f"{lb.dtype}{lb.shape}")
        elif la.tobytes() != lb.tobytes():
            # byte-level count works for every leaf, 0-d scalars included
            ba = np.frombuffer(la.tobytes(), np.uint8)
            bb = np.frombuffer(lb.tobytes(), np.uint8)
            out.append(f"{where}: {int(np.sum(ba != bb))} differing byte(s)")
    return out


def state_finite(state: Any) -> bool:
    """Host-side finiteness probe over every float leaf (the reconciler
    must never crown a diverged/NaN replica authoritative)."""
    for leaf in _leaves(state):
        if np.issubdtype(leaf.dtype, np.floating) and \
                not np.all(np.isfinite(leaf)):
            return False
    return True
