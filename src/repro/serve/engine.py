"""BCPNNService — the streaming serving engine for trained DeepStates.

One worker thread owns the network state and drains the admission queue
into shape-bucketed microbatches (batching.py), running the inference-only
path (``core.network.infer``) per bucket — each bucket shape compiles once
and is reused forever, the jax analogue of the paper's pre-synthesized
inference bitstream.  With ``online_learning=True`` the engine also owns a
feedback buffer of labeled samples and folds it into the readout
projection via ``supervised_readout_step`` *between* inference
microbatches: the same deployment serves traffic and keeps learning from a
label stream, the runtime-selectable analogue of the follow-up paper's
inference-vs-training reconfiguration (no reflash — just a flag).

Thread model: ``submit``/``feedback`` may be called from any thread (they
only enqueue host arrays); all device work — inference and learning —
happens on the single worker thread, so the state needs no lock and
learning can never race an in-flight forward pass.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bcpnn_layer import validate_patchy_state
from ..core.network import as_spec, infer, supervised_readout_step
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .metrics import ServeMetrics


@dataclasses.dataclass
class ServeResult:
    """Completed inference for one request."""

    request_id: int
    probs: np.ndarray   # (n_classes,)
    pred: int
    latency_ms: float


class BCPNNService:
    """Microbatched streaming front-end over a trained ``DeepState``.

    API: ``submit`` (async admission) + ``result`` (blocking collect),
    ``classify`` (synchronous convenience), ``feedback`` (labeled sample
    for the online-learning mode), ``metrics`` (aggregate snapshot).
    """

    def __init__(self, state, spec_or_cfg, max_batch: int = 64,
                 buckets: Optional[Sequence[int]] = None,
                 max_wait_ms: float = 2.0, online_learning: bool = False,
                 feedback_batch: int = 32, metrics_window: int = 4096,
                 poll_ms: float = 20.0, result_retention: int = 4096):
        self.spec = as_spec(spec_or_cfg)
        self.state = state
        # Deployment boundary for arbitrary (possibly pre-exactly-nact-fix
        # or hand-migrated) checkpoints: the patchy infer path assumes the
        # exactly-nact mask invariant, and compact-resident projections
        # additionally assume their index-table leaf agrees with the mask
        # — verify both on the concrete state before any request is
        # served (a drifted table would route the WRONG synapses
        # silently).
        for l, (proj, pspec) in enumerate(zip(state.projs, self.spec.projs)):
            validate_patchy_state(proj, pspec, where=f"stack proj {l}")
        validate_patchy_state(state.readout, self.spec.readout,
                              where="readout")
        self.online_learning = online_learning
        self.feedback_batch = feedback_batch
        self._poll_s = poll_ms * 1e-3
        self._batcher = MicroBatcher(buckets or default_buckets(max_batch),
                                     max_wait_s=max_wait_ms * 1e-3)
        self.metrics = ServeMetrics(window=metrics_window)
        spec = self.spec
        self._infer_fn = jax.jit(
            lambda st, x, v: infer(st, spec, x, valid=v))
        self._learn_fn = jax.jit(
            lambda st, x, y: supervised_readout_step(st, spec, x, y))
        self._feedback: collections.deque = collections.deque()
        self._feedback_lock = threading.Lock()
        self._requests: Dict[int, Request] = {}
        self._requests_lock = threading.Lock()
        # Completed-but-uncollected results are retained for the most
        # recent ``result_retention`` requests only; older ones are
        # evicted so fire-and-forget submitters cannot grow the registry
        # without bound.  Collect promptly (result() frees the slot).
        self.result_retention = result_retention
        self._done_ids: collections.deque = collections.deque()
        self._next_id = 0
        self._stop = threading.Event()
        # Admission gate: submit()/feedback() enqueue under this lock and
        # stop() sets the stop flag under it, so every enqueue strictly
        # precedes the flag flip — the worker can then treat "stop set +
        # queues empty" as "everything admitted is done" with no window
        # for a straggler to land in a dead queue.
        self._admit_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------- lifecycle --
    def start(self, warmup: bool = True) -> "BCPNNService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        if warmup:
            self.warmup()
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="bcpnn-serve")
        self._thread.start()
        return self

    def stop(self) -> None:
        """Drain: the worker finishes everything already admitted (requests
        and feedback) before exiting; admissions racing stop() either land
        before the flag flips (and are served) or raise."""
        if self._thread is None:
            return
        with self._admit_lock:
            self._stop.set()
        self._thread.join()
        self._thread = None

    def warmup(self) -> None:
        """Pre-compile every bucket shape (and the learn shape) so no
        request pays a compile on the serving path."""
        ni = self.spec.input_geom.N
        for b in self._batcher.buckets:
            probs, _ = self._infer_fn(self.state,
                                      jnp.zeros((b, ni), jnp.float32),
                                      jnp.zeros((b,), jnp.float32))
            jax.block_until_ready(probs)
        if self.online_learning:
            st = self._learn_fn(self.state,
                                jnp.zeros((self.feedback_batch, ni),
                                          jnp.float32),
                                jnp.zeros((self.feedback_batch,), jnp.int32))
            jax.block_until_ready(st.readout.w)  # discard: compile only

    # ---------------------------------------------------------- front-end --
    def submit(self, x: np.ndarray) -> int:
        """Admit one sample ((N,) encoded rates); returns a request id."""
        with self._admit_lock:
            if self._thread is None or self._stop.is_set():
                raise RuntimeError("service is not running")
            with self._requests_lock:
                rid = self._next_id
                self._next_id += 1
                req = Request(id=rid, x=np.asarray(x, np.float32),
                              enqueue_t=time.perf_counter())
                self._requests[rid] = req
            self.metrics.record_submit()
            self._batcher.put(req)
        return rid

    def result(self, request_id: int, timeout: Optional[float] = None) -> ServeResult:
        """Block until ``request_id`` completes and return its result.

        The id is forgotten on return AND on timeout — a timed-out request
        still executes (its work is already admitted) but the result is
        discarded, so abandoned requests cannot leak registry entries.
        """
        with self._requests_lock:
            req = self._requests[request_id]
        try:
            if not req.done.wait(timeout):
                raise TimeoutError(f"request {request_id} not done "
                                   f"within {timeout}s")
        finally:
            with self._requests_lock:
                self._requests.pop(request_id, None)
        if req.error is not None:
            raise req.error
        return req.result

    def classify(self, x: np.ndarray, timeout: Optional[float] = None) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.result(self.submit(x), timeout=timeout)

    def feedback(self, x: np.ndarray, label: int) -> None:
        """Queue one labeled sample for the online-learning mode."""
        if not self.online_learning:
            raise RuntimeError("service was built with online_learning=False")
        with self._admit_lock:
            if self._thread is None or self._stop.is_set():
                raise RuntimeError("service is not running")
            with self._feedback_lock:
                self._feedback.append((np.asarray(x, np.float32), int(label)))

    def queue_depth(self) -> int:
        return self._batcher.depth()

    def snapshot(self) -> Dict[str, float]:
        return self.metrics.snapshot(queue_depth=self.queue_depth())

    # ------------------------------------------------------------- worker --
    def _run(self) -> None:
        while True:
            group = self._batcher.next_group(timeout_s=self._poll_s)
            if group:
                self._execute(group)
            if self.online_learning:
                # Fold between microbatches: immediately when a full learn
                # batch is buffered, opportunistically when idle.
                self._fold_feedback(force=not group)
            if self._stop.is_set() and not group \
                    and self._batcher.depth() == 0:
                while self.online_learning and self._feedback:
                    # flush the WHOLE buffer, one learn batch at a time
                    self._fold_feedback(force=True)
                return

    def _execute(self, group) -> None:
        bucket = pick_bucket(len(group), self._batcher.buckets)
        x, valid = pad_group([r.x for r in group], bucket)
        try:
            probs, pred = self._infer_fn(self.state, jnp.asarray(x),
                                         jnp.asarray(valid))
            probs = np.asarray(probs)
            pred = np.asarray(pred)
        except Exception as e:  # complete exceptionally, keep serving
            for r in group:
                r.error = e
                r.done.set()
            return
        t_done = time.perf_counter()
        self.metrics.record_batch(n_valid=len(group), bucket=bucket)
        for i, r in enumerate(group):
            r.result = ServeResult(request_id=r.id, probs=probs[i],
                                   pred=int(pred[i]),
                                   latency_ms=(t_done - r.enqueue_t) * 1e3)
            self.metrics.record_complete(t_done - r.enqueue_t)
            r.done.set()
            self._done_ids.append(r.id)
        while len(self._done_ids) > self.result_retention:
            stale = self._done_ids.popleft()  # usually already collected
            with self._requests_lock:
                self._requests.pop(stale, None)

    def _fold_feedback(self, force: bool = False) -> None:
        """One ``supervised_readout_step`` on up to ``feedback_batch``
        buffered labeled samples.  Short groups are padded by CYCLING the
        genuine samples (every row stays real data, so the batch-mean trace
        update needs no mask — padding only reweights within the batch),
        keeping a single compiled learn shape."""
        with self._feedback_lock:
            if not self._feedback:
                return
            if len(self._feedback) < self.feedback_batch and not force:
                return
            items = [self._feedback.popleft()
                     for _ in range(min(len(self._feedback),
                                        self.feedback_batch))]
        n = len(items)
        idx = [i % n for i in range(self.feedback_batch)]
        x = np.stack([items[i][0] for i in idx]).astype(np.float32)
        y = np.asarray([items[i][1] for i in idx], np.int32)
        self.state = self._learn_fn(self.state, jnp.asarray(x),
                                    jnp.asarray(y))
        self.metrics.record_learn(n)
