"""BCPNNService — the multi-model streaming serving engine.

One worker thread owns N checkpointed ``DeepState``s (each a "model
slot": its own spec, shape buckets, metrics and compiled-once jits per
(model, bucket) — the jax analogue of a library of pre-synthesized
bitstreams selected at runtime) and drains a SHARED admission front into
shape-bucketed microbatches:

  * **Per-model weighted fairness**: each slot has its own admission
    queue; the worker picks the pending slot with the smallest virtual
    finish time (start-time fair queueing): serving ``n`` samples of a
    model advances its finish tag by ``n * cost / weight``, where
    ``cost`` is the model's per-sample MAC estimate from its spec and
    ``weight`` its provisioned share.  A Model-3-sized stack therefore
    pays for its size in virtual time and cannot starve cheap models;
    with equal costs and weights the tags tie every pass and the
    cursor tie-break degenerates to exact round-robin (the PR 5
    behavior — under a 10:1 skewed arrival mix the minority model is
    never more than one microbatch away from service).
  * **Adaptive bucket selection**: each model's active bucket is
    re-derived from its observed arrival-rate and group-occupancy
    windows (``ServeMetrics``): the collect loop stops waiting once the
    group reaches the bucket the observed rate can fill inside the batch
    window, instead of dawdling ``max_wait_ms`` for arrivals that won't
    come — low-rate streams get small-bucket latency, bursts still fill
    the largest bucket (an existing backlog always overrides the cap).
    All buckets stay compiled (warmup covers the full set); adaptation
    only moves which bucket a group WAITS for.
  * **Online learning in deployment** (``online_learning=True``): labeled
    feedback buffers per model and is folded between inference
    microbatches.  ``learn_stack=False`` updates only the readout
    (``supervised_readout_step``); ``learn_stack=True`` additionally
    runs deterministic plasticity on every stack projection and the
    ``struct_every`` structural-plasticity cold path
    (``core.network.online_learn_step``) — receptive fields keep
    rewiring while the same deployment serves traffic, and the fold is
    bit-reproducible against an offline replay of the same feedback
    batches.

  * **Robustness ladder** (DESIGN.md §10): bounded per-model admission
    queues (``max_queue`` -> typed ``Overloaded`` rejection), per-request
    deadlines (``submit(deadline_s=...)``; expired requests are shed at
    dequeue time BEFORE padding/compute and resolve with
    ``DeadlineExceeded``), a supervised worker loop (fold/infer/adapt
    exceptions are counted and survived, never fatal; a group-level
    infer failure bisects the microbatch so one poison request resolves
    exceptionally while its groupmates still serve), learning-state
    quarantine (a non-finite post-fold state rolls back to the last-good
    snapshot and degrades the slot to inference-only until
    ``revalidate()``), and dead-worker detection (``submit``/``result``/
    ``stop`` raise ``WorkerDied`` instead of hanging if the worker
    thread ever exits abnormally — every pending future is completed
    exceptionally on the way down).

Thread model: ``submit``/``feedback`` may be called from any thread (they
only enqueue host arrays); all device work — inference and learning —
happens on the single worker thread, so no model state needs a lock and
learning can never race an in-flight forward pass.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.bcpnn_layer import INFER_DTYPES, validate_patchy_state
from ..core.network import (
    as_spec, infer_packed, online_learn_step, pack_state,
    supervised_readout_step,
)
from ..distributed.fault import StepTimer
from .batching import MicroBatcher, Request, default_buckets, pad_group, pick_bucket
from .errors import (
    DeadlineExceeded, EngineKilled, Overloaded, Quarantined, WorkerDied,
)
from .faultinject import FaultInjector
from .metrics import ServeMetrics

DEFAULT_MODEL = "default"


@dataclasses.dataclass
class ServeResult:
    """Completed inference for one request."""

    request_id: int
    probs: np.ndarray   # (n_classes,)
    pred: int
    latency_ms: float
    model: str = DEFAULT_MODEL


def cycle_batch(items: Sequence[Tuple[np.ndarray, int]],
                batch: int) -> Tuple[np.ndarray, np.ndarray]:
    """(x, y) arrays for one learn fold: a short group is padded by
    CYCLING the genuine samples (every row stays real data, so the
    batch-mean trace update needs no mask — padding only reweights within
    the batch), keeping a single compiled learn shape.  Module-level so
    an offline parity reference can replay the engine's exact batch
    composition."""
    n = len(items)
    idx = [i % n for i in range(batch)]
    x = np.stack([items[i][0] for i in idx]).astype(np.float32)
    y = np.asarray([items[i][1] for i in idx], np.int32)
    return x, y


def _spec_cost(spec: Any) -> float:
    """Virtual per-sample service cost of one model: the MAC estimate of
    its forward pass (patchy projections count only their ``nact`` active
    input hypercolumns).  Only RATIOS between hosted models matter — the
    weighted scheduler divides by it, so equal-geometry models degenerate
    to unit quanta."""
    total = 0.0
    for p in list(spec.projs) + [spec.readout]:
        fan_in = (p.nact * p.pre.M) if p.nact else p.pre.N
        total += float(fan_in * p.post.N)
    return max(total, 1.0)


@dataclasses.dataclass
class _ModelSlot:
    """Everything one hosted model owns inside the engine."""

    name: str
    state: Any                       # DeepState (worker thread only)
    spec: Any                        # NetworkSpec
    batcher: MicroBatcher
    metrics: ServeMetrics
    infer_fn: Any
    learn_fn: Any
    feedback: collections.deque
    target_bucket: int               # adaptive active bucket (worker only)
    # Weighted fair scheduling (start-time fair queueing): ``cost`` is
    # the per-sample MAC estimate from the spec, ``weight`` the
    # provisioned share, ``vft`` the slot's virtual finish tag — serving
    # n samples advances it by n * cost / weight (worker thread only).
    weight: float = 1.0
    cost: float = 1.0
    vft: float = 0.0
    pack: Any = None                 # InferParams derived at fold boundaries
    # Learning-state quarantine (worker thread only).  ``last_good`` is
    # the newest state that passed the post-fold non-finite sentinel; a
    # failing fold rolls back to it and flips ``quarantined`` — the slot
    # keeps SERVING from the last-good pack but accepts no feedback
    # until revalidate() re-arms it.
    last_good: Any = None
    quarantined: bool = False

    def repack(self) -> None:
        """Re-derive the serving-dtype inference weights from the fp32
        state.  Called at fold boundaries ONLY (model registration, after
        each feedback fold / in-deployment rewire, state swap) — never on
        the per-request path; requests between folds serve the packed
        weights as-is (DESIGN.md §8).  Runs eagerly on concrete arrays so
        a patchy pack reuses the memoized index table unless the mask
        actually changed (a rewire)."""
        self.pack = pack_state(self.state, self.spec)


def _validate_state(state, spec, name: str) -> None:
    # Deployment boundary for arbitrary (possibly pre-exactly-nact-fix or
    # hand-migrated) checkpoints: the patchy infer path assumes the
    # exactly-nact mask invariant, and compact-resident projections
    # additionally assume their index-table leaf agrees with the mask —
    # verify both on the concrete state before any request is served (a
    # drifted table would route the WRONG synapses silently).
    for l, (proj, pspec) in enumerate(zip(state.projs, spec.projs)):
        validate_patchy_state(proj, pspec, where=f"model {name!r} stack "
                                                 f"proj {l}")
    validate_patchy_state(state.readout, spec.readout,
                          where=f"model {name!r} readout")


@dataclasses.dataclass
class _ControlOp:
    """One deferred control-plane operation (state install/read): the
    worker runs ``fn`` at the top of its loop — a fold boundary — and
    completes ``done``; the caller blocks on it (or gets WorkerDied)."""

    fn: Any
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    result: Any = None
    error: Optional[BaseException] = None


def _state_finite(state) -> bool:
    """Cheap post-fold sentinel: True iff every float leaf of the state
    pytree (traces, weights, biases — everything a diverged fold could
    poison) is finite.  One fused all-reduce per leaf, a dozen leaves per
    fold — noise next to the learn step itself."""
    flags = [jnp.all(jnp.isfinite(leaf))
             for leaf in jax.tree_util.tree_leaves(state)
             if hasattr(leaf, "dtype")
             and jnp.issubdtype(leaf.dtype, jnp.floating)]
    if not flags:
        return True
    return bool(jnp.stack(flags).all())


class BCPNNService:
    """Microbatched streaming front-end over trained ``DeepState``s.

    API: ``submit`` (async admission) + ``result`` (blocking collect),
    ``classify`` (synchronous convenience), ``feedback`` (labeled sample
    for the online-learning mode), ``metrics``/``snapshot`` (aggregate +
    per-model telemetry).  Constructed single-model
    (``BCPNNService(state, spec)``) requests need no model name; use
    ``BCPNNService.multi({...})`` / ``add_model`` to host several
    checkpoints behind one admission front, then route with
    ``submit(x, model=...)``.
    """

    def __init__(self, state=None, spec_or_cfg=None, max_batch: int = 64,
                 buckets: Optional[Sequence[int]] = None,
                 max_wait_ms: float = 2.0, online_learning: bool = False,
                 feedback_batch: int = 32, metrics_window: int = 4096,
                 poll_ms: float = 20.0, result_retention: int = 4096,
                 learn_stack: bool = False, adaptive_buckets: bool = True,
                 feedback_eager: bool = True, name: str = DEFAULT_MODEL,
                 infer_dtype: Optional[str] = None,
                 max_queue: Optional[int] = None,
                 default_deadline_s: Optional[float] = None,
                 fault_injector: Optional[FaultInjector] = None):
        if infer_dtype is not None and infer_dtype not in INFER_DTYPES:
            raise ValueError(f"infer_dtype must be one of {INFER_DTYPES}, "
                             f"got {infer_dtype!r}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        # Admission control: per-model queue bound (Overloaded past it)
        # and the engine-wide default deadline stamped on every submit
        # that does not carry its own (None = no deadline).
        self.max_queue = max_queue
        self.default_deadline_s = default_deadline_s
        self.fault_injector = fault_injector
        # Engine-wide serving-precision override: when set, every hosted
        # model's spec is re-tagged with this infer_dtype at registration
        # (None = honor each spec/checkpoint's own tag).  Learning state
        # stays fp32 either way — precision only changes the derived
        # inference weights (DESIGN.md §8).
        self.infer_dtype = infer_dtype
        self.online_learning = online_learning
        self.learn_stack = learn_stack
        self.adaptive_buckets = adaptive_buckets
        # eager: fold partial feedback batches whenever the worker idles
        # (lowest label-to-weight latency).  Non-eager: fold only FULL
        # batches until the stop() drain — the fold compositions then
        # depend only on the feedback stream order, never on worker
        # timing, which is what makes a served learning run bit-exactly
        # replayable offline (the parity tests rely on this).
        self.feedback_eager = feedback_eager
        self.feedback_batch = feedback_batch
        self.metrics_window = metrics_window
        self._poll_s = poll_ms * 1e-3
        self._buckets = tuple(sorted(buckets or default_buckets(max_batch)))
        self._max_wait_s = max_wait_ms * 1e-3
        self._slots: Dict[str, _ModelSlot] = {}
        self._order: List[str] = []          # slot registration order
        self._cursor = 0                     # tie-break cursor (worker only)
        self._vclock = 0.0                   # virtual clock (worker only)
        self._fb_cursor = 0                  # next slot to fold feedback
        self._requests: Dict[int, Request] = {}
        self._requests_lock = threading.Lock()
        # Completed-but-uncollected results are retained for the most
        # recent ``result_retention`` requests only; older ones are
        # evicted so fire-and-forget submitters cannot grow the registry
        # without bound.  Collect promptly (result() frees the slot).
        self.result_retention = result_retention
        self._done_ids: collections.deque = collections.deque()
        self._next_id = 0
        self._stop = threading.Event()
        self._work = threading.Event()       # any-slot work signal
        # Admission gate: submit()/feedback() enqueue under this lock and
        # stop() sets the stop flag under it, so every enqueue strictly
        # precedes the flag flip — the worker can then treat "stop set +
        # queues empty" as "everything admitted is done" with no window
        # for a straggler to land in a dead queue.
        self._admit_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        # Worker supervision state.  ``_dead`` flips (under the admission
        # lock) only if the worker thread exits abnormally; from then on
        # submit/feedback/result/stop raise WorkerDied instead of
        # hanging, and every future pending at death completes
        # exceptionally.  ``_last_crash`` is the newest SURVIVED
        # exception (supervised: counted, never fatal).
        self._dead = threading.Event()
        self._worker_error: Optional[BaseException] = None
        self._last_crash: Optional[BaseException] = None
        # Per-microbatch wall times feed the shared straggler detector;
        # stop(tag=model) attributes outlier batches (injected slow-batch
        # faults included) to the slot that stalled.
        self.step_timer = StepTimer()
        self._batch_seq = 0
        # Control plane: deferred operations (state install/read) the
        # worker executes at the top of its loop — a fold boundary, so a
        # router-installed reconciled state can never race a fold or an
        # in-flight forward.  Appended under the admission lock; drained
        # by the worker (also under the lock) or by _die.
        self._control: collections.deque = collections.deque()
        # Chaos kill switch: set by kill(); the worker raises
        # EngineKilled on its next pass (terminal, like a real abort).
        self._kill_reason: Optional[str] = None
        if state is not None or spec_or_cfg is not None:
            if state is None or spec_or_cfg is None:
                raise ValueError("pass BOTH state and spec_or_cfg (or "
                                 "neither, for an engine that starts "
                                 "empty behind a router)")
            self.add_model(name, state, spec_or_cfg)

    @classmethod
    def multi(cls, models: Mapping[str, Tuple[Any, Any]],
              **kwargs) -> "BCPNNService":
        """Multi-model engine from ``{name: (state, spec)}`` — every
        model behind one shared admission front, served fairly."""
        items = list(models.items())
        if not items:
            raise ValueError("multi() needs at least one model")
        name0, (state0, spec0) = items[0]
        svc = cls(state0, spec0, name=name0, **kwargs)
        for name, (state, spec) in items[1:]:
            svc.add_model(name, state, spec)
        return svc

    # ---------------------------------------------------------- models ----
    def add_model(self, name: str, state, spec_or_cfg,
                  weight: float = 1.0, live: bool = False) -> None:
        """Register one checkpointed model.

        By default registration is a construction-time operation (a
        running service raises).  ``live=True`` is the router's
        engine-loss recovery path: the slot is built and its jits warmed
        on the CALLING thread, then published to the worker atomically
        under the admission lock — the worker's scheduler scan only ever
        sees it fully formed, and no request pays the compile.

        ``weight`` is the model's provisioned share for the weighted
        fair scheduler (>0; service time is proportional to
        weight/cost, so a 2x weight buys 2x the virtual-time share)."""
        if self._thread is not None and not live:
            raise RuntimeError("cannot add a model to a running service "
                               "(pass live=True for an online placement, "
                               "e.g. router engine-loss recovery)")
        if name in self._slots:
            raise ValueError(f"model {name!r} already registered")
        if not (weight > 0):
            raise ValueError(f"weight must be > 0, got {weight}")
        spec = as_spec(spec_or_cfg)
        if self.infer_dtype is not None:
            spec = spec.with_infer_dtype(self.infer_dtype)
        _validate_state(state, spec, name)
        # The serving forward runs over the slot's packed inference
        # weights (InferParams), not the fp32 learning state: fp32 packs
        # alias the state (bit-identical to infer()), bf16/int8 packs are
        # re-derived only when a fold mutates the state.
        infer_fn = jax.jit(lambda pk, x, v, _spec=spec:
                           infer_packed(pk, _spec, x, valid=v))
        if self.learn_stack:
            learn_fn = jax.jit(lambda st, x, y, _spec=spec:
                               online_learn_step(st, _spec, x, y,
                                                 learn_stack=True))
        else:
            learn_fn = jax.jit(lambda st, x, y, _spec=spec:
                               supervised_readout_step(st, _spec, x, y))
        slot = _ModelSlot(
            name=name, state=state, spec=spec,
            batcher=MicroBatcher(self._buckets, max_wait_s=self._max_wait_s,
                                 max_depth=self.max_queue),
            metrics=ServeMetrics(window=self.metrics_window),
            infer_fn=infer_fn, learn_fn=learn_fn,
            feedback=collections.deque(),
            target_bucket=self._buckets[-1],
            weight=float(weight), cost=_spec_cost(spec),
            last_good=state,
        )
        slot.repack()
        if live and self._thread is not None:
            # compile off the serving path, on the caller's thread
            self._warm_slot(slot)
        with self._admit_lock:
            if self._thread is not None:
                self._check_alive()
            # a late joiner starts at the current virtual clock so it
            # cannot claim credit for virtual time it never waited
            slot.vft = self._vclock
            self._slots[name] = slot
            self._order.append(name)

    def models(self) -> Tuple[str, ...]:
        return tuple(self._order)

    def _slot(self, model: Optional[str]) -> _ModelSlot:
        if model is None:
            if len(self._slots) == 1:
                return self._slots[self._order[0]]
            raise ValueError(
                f"multi-model service hosts {sorted(self._slots)}; pass "
                f"model=<name> to route the request")
        try:
            return self._slots[model]
        except KeyError:
            raise KeyError(f"unknown model {model!r}; hosted models: "
                           f"{sorted(self._slots)}") from None

    def model_state(self, model: Optional[str] = None):
        """The current DeepState of one hosted model (the worker owns it
        while running — read after ``stop`` for a settled value)."""
        return self._slot(model).state

    def model_spec(self, model: Optional[str] = None):
        return self._slot(model).spec

    def model_pack(self, model: Optional[str] = None):
        """The packed serving-dtype inference weights (``InferParams``)
        the model currently serves from — derived at the last fold
        boundary (read after ``stop`` for a settled value)."""
        return self._slot(model).pack

    def revalidate(self) -> None:
        """Re-run the deployment-boundary patchy/compact invariants on the
        CURRENT states — cheap (vectorized host check), useful after a
        run with in-deployment rewires.  Additionally re-arms any
        quarantined slot whose current (rolled-back) state is finite:
        quarantine is a degradation, not a death sentence — an operator
        (or a test) calls revalidate() to resume learning from the
        last-good snapshot."""
        for slot in self._slots.values():
            _validate_state(slot.state, slot.spec, slot.name)
            if slot.quarantined and _state_finite(slot.state):
                slot.quarantined = False

    # --------------------------------------- single-model back-compat -----
    @property
    def state(self):
        return self.model_state()

    @state.setter
    def state(self, value):
        slot = self._slot(None)
        slot.state = value
        slot.repack()  # a state swap is a fold boundary

    @property
    def spec(self):
        return self.model_spec()

    @property
    def metrics(self) -> ServeMetrics:
        return self._slot(None).metrics

    @metrics.setter
    def metrics(self, value: ServeMetrics) -> None:
        self._slot(None).metrics = value

    @property
    def _feedback(self) -> collections.deque:
        return self._slot(None).feedback

    # ---------------------------------------------------------- lifecycle --
    def start(self, warmup: bool = True) -> "BCPNNService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        if self._dead.is_set():
            raise WorkerDied(f"service worker died and cannot be "
                             f"restarted: {self._worker_error!r}")
        if warmup:
            self.warmup()
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="bcpnn-serve")
        self._thread.start()
        return self

    def stop(self, timeout_s: float = 60.0) -> None:
        """Drain: the worker finishes everything already admitted (requests
        and feedback) before exiting; admissions racing stop() either land
        before the flag flips (and are served) or raise.

        Never hangs silently: the join is bounded by ``timeout_s`` (a
        wedged worker raises RuntimeError naming the last survived
        crash), and a worker that died abnormally raises ``WorkerDied``
        naming its terminal exception instead of returning as if the
        drain succeeded."""
        if self._thread is None:
            return
        with self._admit_lock:
            self._stop.set()
            self._work.set()
        self._thread.join(timeout_s)
        alive = self._thread.is_alive()
        self._thread = None
        if alive:
            hint = (f" (last survived crash: {self._last_crash!r})"
                    if self._last_crash is not None else "")
            raise RuntimeError(f"serving worker failed to drain within "
                               f"{timeout_s}s{hint}")
        if self._worker_error is not None:
            raise WorkerDied(f"serving worker died: "
                             f"{type(self._worker_error).__name__}: "
                             f"{self._worker_error}")

    def warmup(self) -> None:
        """Pre-compile every (model, bucket) shape (and the learn shapes)
        so no request pays a compile on the serving path."""
        for slot in self._slots.values():
            self._warm_slot(slot)

    def _warm_slot(self, slot: _ModelSlot) -> None:
        ni = slot.spec.input_geom.N
        for b in self._buckets:
            probs, _ = slot.infer_fn(slot.pack,
                                     jnp.zeros((b, ni), jnp.float32),
                                     jnp.zeros((b,), jnp.float32))
            jax.block_until_ready(probs)
        if self.online_learning:
            st = slot.learn_fn(
                slot.state,
                jnp.zeros((self.feedback_batch, ni), jnp.float32),
                jnp.zeros((self.feedback_batch,), jnp.int32))
            jax.block_until_ready(st.readout.w)  # discard: compile only

    # ---------------------------------------------------------- front-end --
    def submit(self, x: np.ndarray, model: Optional[str] = None,
               deadline_s: Optional[float] = None,
               deadline_t: Optional[float] = None) -> int:
        """Admit one sample ((N,) encoded rates); returns a request id.
        Multi-model services route by ``model`` name.

        ``deadline_s`` (or the engine's ``default_deadline_s``) bounds
        how long the request may WAIT: if it is still queued past the
        deadline it is shed at dequeue time and ``result`` raises
        ``DeadlineExceeded``.  ``deadline_t`` is the same bound as an
        ABSOLUTE ``time.perf_counter()`` instant and wins over both —
        it is how a router re-submitting a rerouted request carries the
        ORIGINAL admission deadline across hops, so a retry can never
        resurrect an expired budget.  A full admission queue
        (``max_queue``) raises ``Overloaded`` here instead of admitting
        — the request is never registered, so rejection is O(1) and
        allocation-free for the engine."""
        slot = self._slot(model)
        with self._admit_lock:
            self._check_alive()
            now = time.perf_counter()
            if deadline_t is None:
                d = (self.default_deadline_s if deadline_s is None
                     else deadline_s)
                deadline_t = (now + d) if d is not None else None
            with self._requests_lock:
                rid = self._next_id
                self._next_id += 1
                req = Request(id=rid, x=np.asarray(x, np.float32),
                              enqueue_t=now, model=slot.name,
                              deadline_t=deadline_t)
                self._requests[rid] = req
            try:
                slot.batcher.put(req)
            except Overloaded:
                with self._requests_lock:
                    self._requests.pop(rid, None)
                slot.metrics.record_rejected()
                raise
            slot.metrics.record_submit(now=now)
            self._work.set()
        return rid

    def _check_alive(self) -> None:
        """Admission-side liveness gate (call under ``_admit_lock``)."""
        if self._dead.is_set():
            raise WorkerDied(f"service worker is dead: "
                             f"{self._worker_error!r}")
        if self._thread is None or self._stop.is_set():
            raise RuntimeError("service is not running")

    def result(self, request_id: int, timeout: Optional[float] = None) -> ServeResult:
        """Block until ``request_id`` completes and return its result.

        The id is forgotten on return AND on timeout — a timed-out request
        still executes (its work is already admitted) but the result is
        discarded, so abandoned requests cannot leak registry entries.

        Shed, rejected-at-source or failed requests re-raise their typed
        error here (``DeadlineExceeded``, infer failure, ...).  A worker
        that dies mid-wait completes every pending future with
        ``WorkerDied`` on its way down, so this never hangs on a dead
        service; the bounded wait slices below are belt-and-braces for a
        death racing registration.
        """
        with self._requests_lock:
            req = self._requests[request_id]
        try:
            end = (time.perf_counter() + timeout
                   if timeout is not None else None)
            while not req.done.wait(
                    0.2 if end is None
                    else max(0.0, min(0.2, end - time.perf_counter()))):
                if req.done.is_set():
                    break
                if self._dead.is_set():
                    raise WorkerDied(f"request {request_id} abandoned: "
                                     f"worker died "
                                     f"({self._worker_error!r})")
                if end is not None and time.perf_counter() >= end:
                    raise TimeoutError(f"request {request_id} not done "
                                       f"within {timeout}s")
        finally:
            with self._requests_lock:
                self._requests.pop(request_id, None)
        if req.error is not None:
            raise req.error
        return req.result

    def classify(self, x: np.ndarray, timeout: Optional[float] = None,
                 model: Optional[str] = None) -> ServeResult:
        """Synchronous convenience: submit + wait."""
        return self.result(self.submit(x, model=model), timeout=timeout)

    def feedback(self, x: np.ndarray, label: int,
                 model: Optional[str] = None) -> None:
        """Queue one labeled sample for the online-learning mode.  A
        quarantined slot raises ``Quarantined`` — it still serves
        inference from its last-good state, but learning stays off until
        ``revalidate()`` re-arms it."""
        if not self.online_learning:
            raise RuntimeError("service was built with online_learning=False")
        slot = self._slot(model)
        with self._admit_lock:
            self._check_alive()
            if slot.quarantined:
                raise Quarantined(slot.name)
            slot.feedback.append((np.asarray(x, np.float32), int(label)))
            self._work.set()

    def queue_depth(self, model: Optional[str] = None) -> int:
        if model is None and len(self._slots) != 1:
            # engine-wide total (0 for an empty router-managed engine)
            return sum(s.batcher.depth() for s in self._slots.values())
        return self._slot(model).batcher.depth()

    def feedback_depth(self, model: Optional[str] = None) -> int:
        """Buffered (not yet folded) labeled samples for one model — the
        router's quiescence probe: a replica with an empty buffer has
        folded its whole feedback prefix, which is when reconciliation
        can compare replicas bit-exactly."""
        if model is None and len(self._slots) != 1:
            return sum(len(s.feedback) for s in self._slots.values())
        return len(self._slot(model).feedback)

    def alive(self) -> bool:
        """True while the engine can take traffic: started, not stopped,
        worker not dead."""
        return (self._thread is not None and not self._dead.is_set()
                and not self._stop.is_set())

    def quarantined(self, model: Optional[str] = None) -> bool:
        return self._slot(model).quarantined

    # ----------------------------------------------------- control plane --
    def kill(self, reason: str = "killed") -> None:
        """Abrupt death (chaos testing): the worker raises
        ``EngineKilled`` on its next pass, which takes the same terminal
        ``_die`` path as a real interpreter-level failure — every
        pending future completes ``WorkerDied``, later admissions fail
        fast.  No drain, no cleanup: that is the point."""
        with self._admit_lock:
            if self._dead.is_set() or self._thread is None:
                return  # already dead or never started: nothing to kill
            self._kill_reason = reason
            self._work.set()

    def _control_call(self, fn, timeout_s: float = 60.0):
        """Run ``fn`` on the worker thread at its next fold boundary and
        return its result (raises the op's error, ``WorkerDied`` if the
        engine dies while waiting, or TimeoutError)."""
        op = _ControlOp(fn=fn)
        with self._admit_lock:
            self._check_alive()
            self._control.append(op)
            self._work.set()
        end = time.perf_counter() + timeout_s
        while not op.done.wait(0.1):
            if op.done.is_set():
                break
            if self._dead.is_set():
                raise WorkerDied(f"control op abandoned: worker died "
                                 f"({self._worker_error!r})")
            if time.perf_counter() >= end:
                raise TimeoutError(f"control op not served within "
                                   f"{timeout_s}s")
        if op.error is not None:
            raise op.error
        return op.result

    def set_model_state(self, model: Optional[str], state,
                        timeout_s: float = 60.0) -> None:
        """Install ``state`` as one model's new learning state — the
        router's replica-repair/reconciliation hook.  On a running
        engine the install happens on the worker thread at a fold
        boundary (never racing a fold or an in-flight forward) and is a
        fold boundary itself: last-good resets, the serving pack is
        re-derived, and a finite state clears any quarantine."""
        slot = self._slot(model)

        def install():
            _validate_state(state, slot.spec, slot.name)
            slot.state = state
            slot.last_good = state
            slot.repack()
            if slot.quarantined and _state_finite(state):
                slot.quarantined = False

        if self._thread is None:
            install()
        else:
            self._control_call(install, timeout_s=timeout_s)

    def model_state_sync(self, model: Optional[str] = None,
                         timeout_s: float = 60.0):
        """One model's state read AT A FOLD BOUNDARY of the running
        worker (falls back to a direct read on a stopped engine) — the
        consistent snapshot replica reconciliation compares.  A plain
        ``model_state`` read can observe a state mid-sequence; this one
        cannot."""
        slot = self._slot(model)
        if self._thread is None:
            return slot.state
        return self._control_call(lambda: slot.state, timeout_s=timeout_s)

    def active_buckets(self, model: Optional[str] = None) -> Tuple[int, ...]:
        """The bucket subset the adaptive policy currently collects
        toward for one model (the full set stays compiled; larger
        buckets re-activate instantly when a backlog demands them)."""
        target = self._slot(model).target_bucket
        return tuple(b for b in self._buckets if b <= target)

    def snapshot(self, model: Optional[str] = None) -> Dict[str, float]:
        """Aggregate engine snapshot; multi-model services additionally
        carry a ``per_model`` breakdown (each with its adaptive
        ``target_bucket``).  ``model=<name>`` narrows to one model."""
        if model is not None:
            slot = self._slot(model)
            out = slot.metrics.snapshot(queue_depth=slot.batcher.depth())
            out["target_bucket"] = float(slot.target_bucket)
            out["quarantined"] = 1.0 if slot.quarantined else 0.0
            out["straggler_events"] = float(
                sum(1 for e in self.step_timer.events
                    if e.get("tag") == slot.name))
            return out
        if len(self._slots) == 1:
            return self.snapshot(model=self._order[0])
        out = ServeMetrics.aggregate(
            (s.metrics for s in self._slots.values()),
            queue_depth=self.queue_depth())
        out["quarantined"] = float(
            sum(1 for s in self._slots.values() if s.quarantined))
        out["straggler_events"] = float(len(self.step_timer.events))
        out["per_model"] = {name: self.snapshot(model=name)
                            for name in self._order}
        return out

    # ------------------------------------------------------------- worker --
    def _run(self) -> None:
        # Outermost supervision: _serve_loop survives every Exception on
        # its own; anything that still escapes (KeyboardInterrupt, a
        # MemoryError, a bug in the supervisor itself) must not strand
        # the callers blocked in result() — _die completes every pending
        # future with WorkerDied and flips the dead flag so later
        # admissions fail fast instead of queueing into the void.
        try:
            self._serve_loop()
        except EngineKilled as e:
            self._die(e)  # intentional kill(): bookkept, no excepthook spam
        except BaseException as e:
            self._die(e)
            raise

    def _serve_loop(self) -> None:
        while True:
            group = []
            try:
                if self._kill_reason is not None:
                    raise EngineKilled(self._kill_reason)
                self._drain_control()
                group, slot = self._next_work()
                if group:
                    self._execute(slot, group)
                if self.online_learning:
                    # Fold between microbatches: immediately when a full
                    # learn batch is buffered, opportunistically when
                    # idle (eager mode only).
                    self._fold_feedback(
                        force=(not group) and self.feedback_eager)
                if self._stop.is_set() and not group \
                        and all(s.batcher.depth() == 0
                                for s in self._slots.values()):
                    while self.online_learning \
                            and any(s.feedback for s in self._slots.values()):
                        # flush EVERY model's buffer, one batch at a time
                        self._fold_feedback(force=True)
                    return
            except Exception as e:
                # Supervised: scheduler/adapt/metrics bugs are counted
                # and survived (the request-completing paths below have
                # their own containment, so nothing admitted is lost).
                self._note_crash(e)
                time.sleep(self._poll_s)  # never hot-spin a crash loop

    def _drain_control(self) -> None:
        """Serve queued control ops (state installs/reads) — the loop
        top is a fold boundary: no forward is in flight and the previous
        iteration's fold has committed."""
        while True:
            with self._admit_lock:
                if not self._control:
                    return
                op = self._control.popleft()
            try:
                op.result = op.fn()
            except Exception as e:
                op.error = e
            op.done.set()

    def _note_crash(self, e: Exception) -> None:
        """Count one survived worker exception.  Attribution: scheduler-
        level crashes have no owning slot, so they land in the first
        slot's registry — aggregate accounting stays closed either way."""
        self._last_crash = e
        if self._order:
            self._slots[self._order[0]].metrics.record_crash()

    def _die(self, exc: BaseException) -> None:
        """Terminal path: record the killer, flip the dead flag under the
        admission gate (no new request can land after it), and complete
        every pending future exceptionally so no caller hangs."""
        self._worker_error = exc
        err = WorkerDied(f"serving worker died: "
                         f"{type(exc).__name__}: {exc}")
        with self._admit_lock:
            self._dead.set()
            with self._requests_lock:
                pending = [r for r in self._requests.values()
                           if not r.done.is_set()]
            for r in pending:
                r.error = err
                r.done.set()
            # control-plane callers must not hang on a dead worker either
            while self._control:
                op = self._control.popleft()
                op.error = err
                op.done.set()

    def _next_work(self) -> Tuple[List[Request], Optional[_ModelSlot]]:
        """Weighted fair scheduler (start-time fair queueing): among
        slots with pending requests, serve one microbatch of the slot
        with the smallest virtual start ``max(slot.vft, vclock)`` —
        serving n samples advances the slot's finish tag by
        ``n * cost / weight``, so an expensive model pays for its size
        in virtual time instead of taking one unit-cost turn per pass.
        Tag ties break by round-robin distance from the cursor, which
        makes equal-cost equal-weight slots degenerate to EXACT
        round-robin (the deterministic PR 5 fairness the scheduler tests
        pin).  ``max(vft, vclock)`` re-bases an idle slot's tag to the
        current virtual clock, so a model cannot bank credit while it
        has no traffic and then monopolize the engine.

        When nothing is pending anywhere, block briefly on the shared
        work signal (a submit landing after the scan re-sets it, so no
        wakeup is lost — the worker always rescans after the wait)."""
        n = len(self._order)
        best_i = -1
        best_key: Optional[Tuple[float, int]] = None
        for i in range(n):
            slot = self._slots[self._order[(self._cursor + i) % n]]
            if slot.batcher.depth() > 0:
                key = (max(slot.vft, self._vclock), i)
                if best_key is None or key < best_key:
                    best_key, best_i = key, i
        if best_key is None:
            self._work.wait(self._poll_s)
            self._work.clear()
            return [], None
        slot = self._slots[self._order[(self._cursor + best_i) % n]]
        self._adapt(slot)
        group = slot.batcher.next_group(
            timeout_s=0.0,
            target=(slot.target_bucket if self.adaptive_buckets
                    else None))
        if not group:
            return [], None
        self._cursor = (self._cursor + best_i + 1) % n
        start = max(slot.vft, self._vclock)
        self._vclock = start
        slot.vft = start + len(group) * slot.cost / slot.weight
        live = self._shed_expired(slot, group)
        if not live:
            # whole group expired; rescan from the advanced cursor on
            # the next loop pass
            return [], None
        return live, slot

    def _shed_expired(self, slot: _ModelSlot,
                      group: List[Request]) -> List[Request]:
        """Load shedding at the dequeue boundary: requests whose deadline
        passed while queued complete with ``DeadlineExceeded`` NOW —
        before padding and compute — so an overloaded engine spends
        device time only on results somebody is still waiting for."""
        now = time.perf_counter()
        live = [r for r in group if not r.expired(now)]
        n_shed = len(group) - len(live)
        if n_shed:
            slot.metrics.record_shed(n_shed)
            for r in group:
                if r.expired(now):
                    self._finish_exceptionally(
                        r, DeadlineExceeded(r.id,
                                            r.deadline_t - r.enqueue_t,
                                            now - r.enqueue_t))
        return live

    def _finish_exceptionally(self, r: Request,
                              exc: BaseException) -> None:
        """Complete one request's future with a typed error (no-op if it
        already resolved) and keep the done-id retention window tight."""
        if r.done.is_set():
            return
        r.error = exc
        r.done.set()
        self._done_ids.append(r.id)
        self._evict_done()

    def _evict_done(self) -> None:
        while len(self._done_ids) > self.result_retention:
            stale = self._done_ids.popleft()  # usually already collected
            with self._requests_lock:
                self._requests.pop(stale, None)

    def _adapt(self, slot: _ModelSlot) -> None:
        """Re-derive the slot's active bucket from its observed windows:
        the group the arrival rate can fill inside one batch window
        (with headroom), floored by the recent p90 group size so a
        steady backlog-driven batch keeps its bucket."""
        if not self.adaptive_buckets:
            slot.target_bucket = self._buckets[-1]
            return
        window = self._max_wait_s + self._poll_s
        predicted = slot.metrics.arrival_rate_hz() * window * 1.5
        want = max(1.0, predicted, slot.metrics.group_p90())
        n = min(int(math.ceil(want)), self._buckets[-1])
        slot.target_bucket = pick_bucket(n, self._buckets)

    def _execute(self, slot: _ModelSlot, group: List[Request]) -> None:
        """Supervised microbatch execution with poison bisection.

        A request handed to _execute ALWAYS resolves.  A group-level
        infer failure splits the group and retries each half (recursion
        depth log2(max_batch)): a single poison request costs O(log n)
        retry batches and resolves exceptionally ALONE — its groupmates
        still get genuine results instead of inheriting its error, and
        a transient failure simply succeeds on retry.

        The deadline check repeats at EVERY bisection hop against the
        request's absolute ``deadline_t``: retry time is queue time, so
        a request whose budget ran out during its groupmate's isolation
        sheds here instead of being resurrected by the retry."""
        group = self._shed_expired(slot, group)
        if not group:
            return
        try:
            self._infer_group(slot, group)
        except Exception as e:
            slot.metrics.record_crash()
            if len(group) == 1:
                slot.metrics.record_failed()
                self._finish_exceptionally(group[0], e)
                return
            slot.metrics.record_bisect()
            mid = len(group) // 2
            self._execute(slot, group[:mid])
            self._execute(slot, group[mid:])

    def _infer_group(self, slot: _ModelSlot, group: List[Request]) -> None:
        """One padded forward + completion sweep (raises on failure; the
        caller owns containment)."""
        bucket = pick_bucket(len(group), self._buckets)
        inj = self.fault_injector
        self._batch_seq += 1
        self.step_timer.start()
        try:
            if inj is not None:
                f = inj.maybe("slow-batch")
                if f is not None:
                    time.sleep(f.delay_s)  # injected straggler
                k = inj.maybe("engine-kill")
                if k is not None:
                    # BaseException: skips every supervision layer and
                    # lands in _die — the whole engine goes down with
                    # this batch in flight (router chaos soak fodder)
                    raise EngineKilled(
                        f"injected engine-kill (invocation {k.index})")
                inj.check_group([r.id for r in group])
                inj.raise_if("infer-raise")
            x, valid = pad_group([r.x for r in group], bucket)
            probs, pred = slot.infer_fn(slot.pack, jnp.asarray(x),
                                        jnp.asarray(valid))
            probs = np.asarray(probs)
            pred = np.asarray(pred)
        finally:
            # even a failing batch is a timed step: injected or genuine
            # stragglers surface as events attributed to this model
            self.step_timer.stop(self._batch_seq, tag=slot.name)
        t_done = time.perf_counter()
        slot.metrics.record_batch(n_valid=len(group), bucket=bucket)
        for i, r in enumerate(group):
            r.result = ServeResult(request_id=r.id, probs=probs[i],
                                   pred=int(pred[i]),
                                   latency_ms=(t_done - r.enqueue_t) * 1e3,
                                   model=slot.name)
            slot.metrics.record_complete(t_done - r.enqueue_t)
            r.done.set()
            self._done_ids.append(r.id)
        self._evict_done()

    def _fold_feedback(self, force: bool = False) -> None:
        """At most ONE learn fold per call, rotating fairly across models:
        one ``learn_fn`` step (readout-only or stack+rewire, see
        ``learn_stack``) on up to ``feedback_batch`` buffered labeled
        samples of the first slot, from the feedback cursor, that is
        ready (full batch buffered, or anything buffered under
        ``force``).

        The fold is the engine's only state-mutating path, so its
        containment lives here: a raising fold drops that batch's
        samples and keeps serving (counted), and every fold's output
        passes the non-finite sentinel BEFORE it is committed — a
        diverged fold rolls the slot back to the last-good snapshot
        (bit-identical: the candidate state is simply never installed)
        and quarantines the slot to inference-only mode."""
        n = len(self._order)
        for i in range(n):
            j = (self._fb_cursor + i) % n
            slot = self._slots[self._order[j]]
            with self._admit_lock:
                if not slot.feedback:
                    continue
                if slot.quarantined:
                    # inference-only: feedback admitted before the
                    # quarantine flipped is dropped (counted), so a
                    # stop() drain can never wedge on a dead buffer
                    dropped = len(slot.feedback)
                    slot.feedback.clear()
                    slot.metrics.record_feedback_dropped(dropped)
                    continue
                if len(slot.feedback) < self.feedback_batch and not force:
                    continue
                items = [slot.feedback.popleft()
                         for _ in range(min(len(slot.feedback),
                                            self.feedback_batch))]
            self._fb_cursor = (j + 1) % n
            inj = self.fault_injector
            try:
                if inj is not None:
                    inj.raise_if("fold-raise")
                x, y = cycle_batch(items, self.feedback_batch)
                cand = slot.learn_fn(slot.state, jnp.asarray(x),
                                     jnp.asarray(y))
                if inj is not None and inj.maybe("nan-state") is not None:
                    cand = FaultInjector.corrupt_state(cand)
            except Exception:
                # survived: this batch's labels are lost, serving and
                # later folds continue on the unchanged state
                slot.metrics.record_crash()
                slot.metrics.record_feedback_dropped(len(items))
                return
            if not _state_finite(cand):
                # Quarantine: the candidate is never installed, so the
                # slot keeps serving from ``last_good`` unchanged — the
                # explicit restore makes the rollback contract literal
                # (and bitwise-checkable, analysis contract
                # ``quarantine-rollback``).
                slot.metrics.record_quarantine()
                slot.metrics.record_feedback_dropped(len(items))
                slot.state = slot.last_good
                slot.quarantined = True
                return
            slot.state = cand
            slot.last_good = cand
            # THE fold boundary: the fold (and any struct_every rewire
            # inside it) just mutated the fp32 state, so the packed
            # serving weights are re-derived here — stale int8 scales or
            # bf16 casts never outlive a fold.
            slot.repack()
            slot.metrics.record_learn(len(items))
            return
