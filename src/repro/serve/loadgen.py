"""Synthetic open-loop load generators for the serving engine.

Open loop means arrivals follow their own clock (Poisson at a target
rate), never waiting for responses — the honest way to measure a serving
system, since closed-loop generators self-throttle and hide queueing
collapse.  Each tick submits one sample from a pool; optionally a labeled
feedback sample rides along (the online-learning stream), emulating
deployed traffic where a fraction of predictions later gets ground truth.

``run_open_loop`` drives one model's stream; ``run_multi_open_loop``
merges several models' independent Poisson processes into one arrival
stream (superposition: combined rate = Σ rates, each arrival belongs to
model m with probability rate_m/Σ), the skewed multi-tenant load the
engine's per-model fairness is measured under.

Both generators are robustness-aware (DESIGN.md §10): an ``Overloaded``
rejection at submit is counted and the tick continues (an open-loop
client does not retry into a collapsing queue), a ``Quarantined`` slot
silently drops the feedback tick, and collection tolerates typed
per-request failures (``DeadlineExceeded``, bisected poison errors,
timeouts) — every error lands in ``LoadReport.errors`` so a chaos soak
can assert that EVERY submitted id resolved one way or the other.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from .engine import ServeResult
from .errors import FaultInjected, Overloaded, Quarantined, ServeError

# Any serving front with submit/result/feedback and the typed error
# ladder: a BCPNNService, or a BCPNNRouter over several of them (its
# NoHealthyReplica rejection IS an Overloaded — open-loop clients need
# no router-specific branch).
ServingFront = Any


@dataclasses.dataclass
class LoadReport:
    """Outcome of one open-loop run (one model's stream).

    ``results``/``labels`` hold only the SUCCESSFUL requests (aligned,
    submission order), so ``accuracy`` is over served predictions;
    failed-but-resolved requests are in ``errors`` and never-admitted
    ones are counted by ``n_rejected``.  Accounting closes:
    submitted = len(results) + len(errors), offered = submitted +
    n_rejected."""

    results: List[ServeResult]   # successful requests, submission order
    labels: np.ndarray           # (n,) ground truth per successful request
    wall_s: float
    offered_rate_hz: float
    errors: List[BaseException] = dataclasses.field(default_factory=list)
    n_rejected: int = 0          # Overloaded at submit (never admitted)

    @property
    def achieved_rate_hz(self) -> float:
        return len(self.results) / max(self.wall_s, 1e-9)

    @property
    def max_latency_ms(self) -> float:
        return max((r.latency_ms for r in self.results), default=0.0)

    def error_counts(self) -> Dict[str, int]:
        """{error type name: count} over the resolved-with-error ids."""
        out: Dict[str, int] = {}
        for e in self.errors:
            out[type(e).__name__] = out.get(type(e).__name__, 0) + 1
        return out

    def accuracy(self, lo: float = 0.0, hi: float = 1.0) -> float:
        """Accuracy of the served predictions over the [lo, hi) fraction
        of the request stream (e.g. (0, .5) vs (.5, 1) shows online
        learning improving the stream as it runs)."""
        n = len(self.results)
        a, b = int(lo * n), max(int(lo * n) + 1, int(hi * n))
        pred = np.asarray([r.pred for r in self.results[a:b]])
        return float(np.mean(pred == self.labels[a:b]))


@dataclasses.dataclass
class StreamSpec:
    """One model's traffic in a multi-model run."""

    x_pool: np.ndarray
    y_pool: np.ndarray
    rate_hz: float
    feedback_frac: float = 0.0
    fb_x: Optional[np.ndarray] = None  # defaults to x_pool
    fb_y: Optional[np.ndarray] = None


def _submit_tick(service: ServingFront, x, model: Optional[str],
                 deadline_s: Optional[float]) -> Optional[int]:
    """One open-loop admission: the id, or None on Overloaded (the
    open-loop client counts the rejection and moves on — retrying into
    an already-full queue would just convert rejection into latency)."""
    try:
        return service.submit(x, model=model, deadline_s=deadline_s)
    except Overloaded:
        return None


def _feedback_tick(service: ServingFront, x, y: int,
                   model: Optional[str]) -> None:
    try:
        service.feedback(x, y, model=model)
    except Quarantined:
        pass  # slot degraded to inference-only; the label tick is lost


def _collect(service: ServingFront,
             submitted: List[Tuple[int, int]], timeout_s: float,
             ) -> Tuple[List[ServeResult], List[int], List[BaseException]]:
    """Resolve every submitted id: successes keep (result, label)
    aligned; typed failures (shed deadlines, bisected poison, worker
    death, collect timeout) are gathered — never raised — so one bad
    request cannot abort collection of the rest.  Anything OUTSIDE the
    typed ladder still propagates: a genuine bug must not be absorbed
    into a load report."""
    results: List[ServeResult] = []
    labels: List[int] = []
    errors: List[BaseException] = []
    for rid, label in submitted:
        try:
            results.append(service.result(rid, timeout=timeout_s))
            labels.append(label)
        except (ServeError, FaultInjected, TimeoutError) as e:
            errors.append(e)
    return results, labels, errors


def run_open_loop(
    service: ServingFront,
    x_pool: np.ndarray,
    y_pool: np.ndarray,
    n_requests: int,
    rate_hz: float,
    seed: int = 0,
    feedback_frac: float = 0.0,
    fb_x: Optional[np.ndarray] = None,
    fb_y: Optional[np.ndarray] = None,
    timeout_s: float = 120.0,
    model: Optional[str] = None,
    deadline_s: Optional[float] = None,
) -> LoadReport:
    """Submit ``n_requests`` samples (drawn with replacement from the
    pool) at Poisson-``rate_hz``, then collect every result.

    With ``feedback_frac > 0`` each tick also submits, with that
    probability, one labeled sample from the feedback pool (defaults to
    the request pool) — the label stream the online-learning mode folds
    into the network while inference traffic keeps flowing.  ``model``
    routes the whole stream to one model of a multi-model service;
    ``deadline_s`` stamps a per-request queueing deadline on every
    submit (expired requests are shed and land in ``errors``).
    """
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(x_pool), size=n_requests)
    waits = rng.exponential(1.0 / max(rate_hz, 1e-9), size=n_requests)
    fb_x = x_pool if fb_x is None else fb_x
    fb_y = y_pool if fb_y is None else fb_y
    submitted: List[Tuple[int, int]] = []
    n_rejected = 0
    t0 = time.perf_counter()
    next_t = t0
    for k, i in enumerate(picks):
        next_t += waits[k]
        delay = next_t - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        rid = _submit_tick(service, x_pool[i], model, deadline_s)
        if rid is None:
            n_rejected += 1
        else:
            submitted.append((rid, int(y_pool[i])))
        if feedback_frac > 0 and rng.random() < feedback_frac:
            j = rng.integers(0, len(fb_x))
            _feedback_tick(service, fb_x[j], int(fb_y[j]), model)
    results, labels, errors = _collect(service, submitted, timeout_s)
    wall = time.perf_counter() - t0
    return LoadReport(results=results,
                      labels=np.asarray(labels, np.int64),
                      wall_s=wall, offered_rate_hz=rate_hz,
                      errors=errors, n_rejected=n_rejected)


def run_multi_open_loop(
    service: ServingFront,
    streams: Mapping[str, StreamSpec],
    n_requests: int,
    seed: int = 0,
    timeout_s: float = 120.0,
    deadline_s: Optional[float] = None,
) -> Dict[str, LoadReport]:
    """One merged open-loop arrival process over several models.

    ``n_requests`` total arrivals are generated from the superposed
    Poisson process (combined rate = sum of per-stream rates); each
    arrival is assigned to model ``m`` with probability
    ``rate_m / rate_total`` — the exact decomposition of independent
    Poisson streams, so each model sees Poisson arrivals at its own rate
    while the engine sees the true interleaved mix.  Returns one
    ``LoadReport`` per model.
    """
    names = list(streams)
    if not names:
        raise ValueError("run_multi_open_loop needs at least one stream")
    rates = np.asarray([streams[n].rate_hz for n in names], np.float64)
    if (rates <= 0).any():
        raise ValueError(f"every stream needs rate_hz > 0 (got {rates})")
    total = float(rates.sum())
    rng = np.random.default_rng(seed)
    owners = rng.choice(len(names), size=n_requests, p=rates / total)
    waits = rng.exponential(1.0 / total, size=n_requests)
    submitted: Dict[str, List[Tuple[int, int]]] = {n: [] for n in names}
    rejected: Dict[str, int] = {n: 0 for n in names}
    t0 = time.perf_counter()
    next_t = t0
    for k in range(n_requests):
        name = names[int(owners[k])]
        s = streams[name]
        next_t += waits[k]
        delay = next_t - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        i = rng.integers(0, len(s.x_pool))
        rid = _submit_tick(service, s.x_pool[i], name, deadline_s)
        if rid is None:
            rejected[name] += 1
        else:
            submitted[name].append((rid, int(s.y_pool[i])))
        if s.feedback_frac > 0 and rng.random() < s.feedback_frac:
            fb_x = s.x_pool if s.fb_x is None else s.fb_x
            fb_y = s.y_pool if s.fb_y is None else s.fb_y
            j = rng.integers(0, len(fb_x))
            _feedback_tick(service, fb_x[j], int(fb_y[j]), name)
    collected = {name: _collect(service, submitted[name], timeout_s)
                 for name in names}
    wall = time.perf_counter() - t0  # one clock for every stream's report
    return {name: LoadReport(
        results=collected[name][0],
        labels=np.asarray(collected[name][1], np.int64),
        wall_s=wall,
        offered_rate_hz=float(streams[name].rate_hz),
        errors=collected[name][2],
        n_rejected=rejected[name]) for name in names}
