"""Synthetic open-loop load generator for the serving engine.

Open loop means arrivals follow their own clock (Poisson at a target
rate), never waiting for responses — the honest way to measure a serving
system, since closed-loop generators self-throttle and hide queueing
collapse.  Each tick submits one sample from a pool; optionally a labeled
feedback sample rides along (the online-learning stream), emulating
deployed traffic where a fraction of predictions later gets ground truth.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from .engine import BCPNNService, ServeResult


@dataclasses.dataclass
class LoadReport:
    """Outcome of one open-loop run."""

    results: List[ServeResult]   # in submission order
    labels: np.ndarray           # (n,) ground truth per request
    wall_s: float
    offered_rate_hz: float

    @property
    def achieved_rate_hz(self) -> float:
        return len(self.results) / max(self.wall_s, 1e-9)

    def accuracy(self, lo: float = 0.0, hi: float = 1.0) -> float:
        """Accuracy of the served predictions over the [lo, hi) fraction
        of the request stream (e.g. (0, .5) vs (.5, 1) shows online
        learning improving the stream as it runs)."""
        n = len(self.results)
        a, b = int(lo * n), max(int(lo * n) + 1, int(hi * n))
        pred = np.asarray([r.pred for r in self.results[a:b]])
        return float(np.mean(pred == self.labels[a:b]))


def run_open_loop(
    service: BCPNNService,
    x_pool: np.ndarray,
    y_pool: np.ndarray,
    n_requests: int,
    rate_hz: float,
    seed: int = 0,
    feedback_frac: float = 0.0,
    fb_x: Optional[np.ndarray] = None,
    fb_y: Optional[np.ndarray] = None,
    timeout_s: float = 120.0,
) -> LoadReport:
    """Submit ``n_requests`` samples (drawn with replacement from the
    pool) at Poisson-``rate_hz``, then collect every result.

    With ``feedback_frac > 0`` each tick also submits, with that
    probability, one labeled sample from the feedback pool (defaults to
    the request pool) — the label stream the online-learning mode folds
    into the readout while inference traffic keeps flowing.
    """
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(x_pool), size=n_requests)
    waits = rng.exponential(1.0 / max(rate_hz, 1e-9), size=n_requests)
    fb_x = x_pool if fb_x is None else fb_x
    fb_y = y_pool if fb_y is None else fb_y
    ids: List[int] = []
    t0 = time.perf_counter()
    next_t = t0
    for k, i in enumerate(picks):
        next_t += waits[k]
        delay = next_t - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        ids.append(service.submit(x_pool[i]))
        if feedback_frac > 0 and rng.random() < feedback_frac:
            j = rng.integers(0, len(fb_x))
            service.feedback(fb_x[j], int(fb_y[j]))
    results = [service.result(rid, timeout=timeout_s) for rid in ids]
    wall = time.perf_counter() - t0
    return LoadReport(results=results, labels=y_pool[picks].astype(np.int64),
                      wall_s=wall, offered_rate_hz=rate_hz)
