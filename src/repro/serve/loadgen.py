"""Synthetic open-loop load generators for the serving engine.

Open loop means arrivals follow their own clock (Poisson at a target
rate), never waiting for responses — the honest way to measure a serving
system, since closed-loop generators self-throttle and hide queueing
collapse.  Each tick submits one sample from a pool; optionally a labeled
feedback sample rides along (the online-learning stream), emulating
deployed traffic where a fraction of predictions later gets ground truth.

``run_open_loop`` drives one model's stream; ``run_multi_open_loop``
merges several models' independent Poisson processes into one arrival
stream (superposition: combined rate = Σ rates, each arrival belongs to
model m with probability rate_m/Σ), the skewed multi-tenant load the
engine's per-model fairness is measured under.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional

import numpy as np

from .engine import BCPNNService, ServeResult


@dataclasses.dataclass
class LoadReport:
    """Outcome of one open-loop run (one model's stream)."""

    results: List[ServeResult]   # in submission order
    labels: np.ndarray           # (n,) ground truth per request
    wall_s: float
    offered_rate_hz: float

    @property
    def achieved_rate_hz(self) -> float:
        return len(self.results) / max(self.wall_s, 1e-9)

    @property
    def max_latency_ms(self) -> float:
        return max((r.latency_ms for r in self.results), default=0.0)

    def accuracy(self, lo: float = 0.0, hi: float = 1.0) -> float:
        """Accuracy of the served predictions over the [lo, hi) fraction
        of the request stream (e.g. (0, .5) vs (.5, 1) shows online
        learning improving the stream as it runs)."""
        n = len(self.results)
        a, b = int(lo * n), max(int(lo * n) + 1, int(hi * n))
        pred = np.asarray([r.pred for r in self.results[a:b]])
        return float(np.mean(pred == self.labels[a:b]))


@dataclasses.dataclass
class StreamSpec:
    """One model's traffic in a multi-model run."""

    x_pool: np.ndarray
    y_pool: np.ndarray
    rate_hz: float
    feedback_frac: float = 0.0
    fb_x: Optional[np.ndarray] = None  # defaults to x_pool
    fb_y: Optional[np.ndarray] = None


def run_open_loop(
    service: BCPNNService,
    x_pool: np.ndarray,
    y_pool: np.ndarray,
    n_requests: int,
    rate_hz: float,
    seed: int = 0,
    feedback_frac: float = 0.0,
    fb_x: Optional[np.ndarray] = None,
    fb_y: Optional[np.ndarray] = None,
    timeout_s: float = 120.0,
    model: Optional[str] = None,
) -> LoadReport:
    """Submit ``n_requests`` samples (drawn with replacement from the
    pool) at Poisson-``rate_hz``, then collect every result.

    With ``feedback_frac > 0`` each tick also submits, with that
    probability, one labeled sample from the feedback pool (defaults to
    the request pool) — the label stream the online-learning mode folds
    into the network while inference traffic keeps flowing.  ``model``
    routes the whole stream to one model of a multi-model service.
    """
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(x_pool), size=n_requests)
    waits = rng.exponential(1.0 / max(rate_hz, 1e-9), size=n_requests)
    fb_x = x_pool if fb_x is None else fb_x
    fb_y = y_pool if fb_y is None else fb_y
    ids: List[int] = []
    t0 = time.perf_counter()
    next_t = t0
    for k, i in enumerate(picks):
        next_t += waits[k]
        delay = next_t - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        ids.append(service.submit(x_pool[i], model=model))
        if feedback_frac > 0 and rng.random() < feedback_frac:
            j = rng.integers(0, len(fb_x))
            service.feedback(fb_x[j], int(fb_y[j]), model=model)
    results = [service.result(rid, timeout=timeout_s) for rid in ids]
    wall = time.perf_counter() - t0
    return LoadReport(results=results, labels=y_pool[picks].astype(np.int64),
                      wall_s=wall, offered_rate_hz=rate_hz)


def run_multi_open_loop(
    service: BCPNNService,
    streams: Mapping[str, StreamSpec],
    n_requests: int,
    seed: int = 0,
    timeout_s: float = 120.0,
) -> Dict[str, LoadReport]:
    """One merged open-loop arrival process over several models.

    ``n_requests`` total arrivals are generated from the superposed
    Poisson process (combined rate = sum of per-stream rates); each
    arrival is assigned to model ``m`` with probability
    ``rate_m / rate_total`` — the exact decomposition of independent
    Poisson streams, so each model sees Poisson arrivals at its own rate
    while the engine sees the true interleaved mix.  Returns one
    ``LoadReport`` per model.
    """
    names = list(streams)
    if not names:
        raise ValueError("run_multi_open_loop needs at least one stream")
    rates = np.asarray([streams[n].rate_hz for n in names], np.float64)
    if (rates <= 0).any():
        raise ValueError(f"every stream needs rate_hz > 0 (got {rates})")
    total = float(rates.sum())
    rng = np.random.default_rng(seed)
    owners = rng.choice(len(names), size=n_requests, p=rates / total)
    waits = rng.exponential(1.0 / total, size=n_requests)
    ids: Dict[str, List[int]] = {n: [] for n in names}
    labels: Dict[str, List[int]] = {n: [] for n in names}
    t0 = time.perf_counter()
    next_t = t0
    for k in range(n_requests):
        name = names[int(owners[k])]
        s = streams[name]
        next_t += waits[k]
        delay = next_t - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        i = rng.integers(0, len(s.x_pool))
        ids[name].append(service.submit(s.x_pool[i], model=name))
        labels[name].append(int(s.y_pool[i]))
        if s.feedback_frac > 0 and rng.random() < s.feedback_frac:
            fb_x = s.x_pool if s.fb_x is None else s.fb_x
            fb_y = s.y_pool if s.fb_y is None else s.fb_y
            j = rng.integers(0, len(fb_x))
            service.feedback(fb_x[j], int(fb_y[j]), model=name)
    results = {name: [service.result(rid, timeout=timeout_s)
                      for rid in ids[name]] for name in names}
    wall = time.perf_counter() - t0  # one clock for every stream's report
    return {name: LoadReport(
        results=results[name],
        labels=np.asarray(labels[name], np.int64),
        wall_s=wall,
        offered_rate_hz=float(streams[name].rate_hz)) for name in names}
