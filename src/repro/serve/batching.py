"""Admission queue + shape-bucketed microbatching.

Requests arrive one sample at a time; the engine wants device-sized
batches of a FIXED small set of shapes so each shape hits an
already-compiled jit of ``infer`` (the serving analogue of the paper's
pre-synthesized bitstreams: a handful of configurations, selected at
runtime, never recompiled).  The batcher collects whatever is queued —
up to the largest bucket, waiting at most ``max_wait_s`` after the first
request of a batch — and the collector pads the group up to the smallest
admissible bucket with zero rows plus a validity mask, which
``core.network.infer`` uses to make pad-slot outputs inert.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from .errors import Overloaded


def default_buckets(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to ``max_batch`` (always including it)."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    out = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that fits ``n`` samples (``n`` <= max(buckets))."""
    for b in sorted(buckets):
        if b >= n:
            return b
    raise ValueError(f"group of {n} exceeds largest bucket {max(buckets)}")


def pad_group(xs: List[np.ndarray], bucket: int) -> Tuple[np.ndarray, np.ndarray]:
    """Stack samples (each (N,)) into a (bucket, N) batch + validity mask."""
    n = len(xs)
    x = np.zeros((bucket, xs[0].shape[-1]), np.float32)
    x[:n] = np.stack(xs).astype(np.float32)
    valid = np.zeros((bucket,), np.float32)
    valid[:n] = 1.0
    return x, valid


@dataclasses.dataclass
class Request:
    """One admitted inference request."""

    id: int
    x: np.ndarray                 # (N,) encoded input rates
    enqueue_t: float
    model: str = "default"        # owning model in a multi-model engine
    done: threading.Event = dataclasses.field(default_factory=threading.Event)
    result: Optional[Any] = None  # ServeResult once completed
    error: Optional[BaseException] = None
    # Absolute perf_counter deadline (None = no deadline).  Checked at
    # dequeue time: an expired request is shed BEFORE padding/compute and
    # completed with DeadlineExceeded — device time is never spent on a
    # result nobody is waiting for.
    deadline_t: Optional[float] = None

    def expired(self, now: float) -> bool:
        return self.deadline_t is not None and now > self.deadline_t


class MicroBatcher:
    """Admission queue that hands the engine bucket-sized request groups.

    ``max_depth`` bounds the queue: ``put`` raises a typed ``Overloaded``
    once the bound is reached (admission control — an unbounded queue
    converts overload into unbounded latency instead of fast rejection;
    None keeps the legacy unbounded behavior)."""

    def __init__(self, buckets: Sequence[int], max_wait_s: float = 2e-3,
                 max_depth: Optional[int] = None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.buckets = tuple(sorted(buckets))
        self.max_batch = self.buckets[-1]
        self.max_wait_s = max_wait_s
        self.max_depth = max_depth
        self._q: "queue.Queue[Request]" = queue.Queue()

    def put(self, req: Request) -> None:
        # qsize() is exact here: the engine admits under one lock, and a
        # concurrent worker dequeue only makes the queue SHORTER — the
        # bound can never be overshot, only momentarily under-filled.
        if self.max_depth is not None and self._q.qsize() >= self.max_depth:
            raise Overloaded(req.model, self._q.qsize(), self.max_depth)
        self._q.put(req)

    def depth(self) -> int:
        return self._q.qsize()

    def next_group(self, timeout_s: float = 0.05,
                   target: Optional[int] = None) -> List[Request]:
        """Block up to ``timeout_s`` for the first request, then drain the
        queue for at most ``max_wait_s`` more or until the group fills.
        Returns [] on timeout (lets the engine poll its stop flag and fold
        pending online-learning feedback between batches).

        ``target`` (optional) caps how large a group the collect loop
        WAITS for — the engine's adaptive bucket selection passes its
        predicted bucket here, so a low-rate stream stops dawdling for
        arrivals that won't come inside the window.  The cap never splits
        an existing backlog: whatever is already queued when the group
        starts is always admitted up to ``max_batch``.
        """
        try:
            if timeout_s > 0:
                first = self._q.get(timeout=timeout_s)
            else:
                first = self._q.get_nowait()
        except queue.Empty:
            return []
        cap = self.max_batch
        if target is not None:
            backlog = 1 + self._q.qsize()
            cap = max(min(target, self.max_batch),
                      min(backlog, self.max_batch))
        group = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(group) < cap:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                # past the window: keep only what is already queued
                try:
                    group.append(self._q.get_nowait())
                    continue
                except queue.Empty:
                    break
            try:
                group.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return group
