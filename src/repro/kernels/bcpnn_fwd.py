"""Pallas TPU kernel: fused BCPNN activation stage.

support = bias + x @ w, followed by per-hypercolumn softmax — in ONE
kernel, so the support matrix never exists in HBM.  This is the TPU
translation of the paper's stream-dataflow: the FPGA forwards support
packets from the matmul stage straight into the softmax stage through a
FIFO; here the MXU accumulator feeds the epilogue in VMEM.

Grid = (B/tb, Nj/tj, Ni/tk) over the PADDED shapes, contraction
innermost.  Pad semantics (DESIGN.md §7): batch rows and contraction
columns pad with zeros (inert in the matmul); the post-synaptic unit axis
pads HC-aware — extra minicolumn lanes get zero weight columns and
``NEG`` bias, so they vanish from every real softmax sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .padding import pad_axis, pad_hc_axis, unpad_hc_axis
from .tiling import NEG, SUBLANE, lane_multiple, pad_hc_spec, pad_spec


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int, n_mc: int, gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        s = (acc_ref[...] + b_ref[...]) * gain       # (tb, tj)
        tb, tj = s.shape
        s = s.reshape(tb, tj // n_mc, n_mc)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        out = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[...] = out.reshape(tb, tj).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_hc", "n_mc", "gain", "block_b", "block_j", "block_k", "interpret"),
)
def bcpnn_fwd_pallas(
    x: jax.Array,      # (B, Ni)
    w: jax.Array,      # (Ni, Nj)
    bias: jax.Array,   # (Nj,)
    n_hc: int,
    n_mc: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_j: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, ni = x.shape
    nj = w.shape[1]
    assert nj == n_hc * n_mc
    bs = pad_spec(b, block_b, SUBLANE)
    ks = pad_spec(ni, block_k, lane_multiple(ni))
    js = pad_hc_spec(n_hc, n_mc, block_j)  # keep HCs whole in a tile
    xp = pad_axis(pad_axis(x, 1, ks.pad), 0, bs.pad)
    wp = pad_hc_axis(pad_axis(w, 0, ks.pad), 1, js)
    bp = pad_hc_axis(bias.reshape(1, nj), 1, js, value=NEG)
    grid = (bs.grid, js.grid, ks.grid)
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=ks.grid, n_mc=js.mc_padded,
                          gain=gain),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs.block, ks.block), lambda i, j, k: (i, k)),
            pl.BlockSpec((ks.block, js.block_units), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, js.block_units), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bs.block, js.block_units),
                               lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bs.padded, js.padded_units), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, js.block_units), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return unpad_hc_axis(out[:b], 1, js)
