"""Pallas TPU kernel: fused BCPNN activation stage.

support = bias + x @ w, followed by per-hypercolumn softmax — in ONE
kernel, so the support matrix never exists in HBM.  This is the TPU
translation of the paper's stream-dataflow: the FPGA forwards support
packets from the matmul stage straight into the softmax stage through a
FIFO; here the MXU accumulator feeds the epilogue in VMEM.

Grid = (B/tb, Nj/tj, Ni/tk) with the contraction innermost; the output
tile tj must be a multiple of the post-synaptic minicolumn count M so the
softmax is block-local.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tiling import fit_block, fit_hc_block


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int, n_mc: int, gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        s = (acc_ref[...] + b_ref[...]) * gain       # (tb, tj)
        tb, tj = s.shape
        s = s.reshape(tb, tj // n_mc, n_mc)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        out = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[...] = out.reshape(tb, tj).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_hc", "n_mc", "gain", "block_b", "block_j", "block_k", "interpret"),
)
def bcpnn_fwd_pallas(
    x: jax.Array,      # (B, Ni)
    w: jax.Array,      # (Ni, Nj)
    bias: jax.Array,   # (Nj,)
    n_hc: int,
    n_mc: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_j: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, ni = x.shape
    nj = w.shape[1]
    assert nj == n_hc * n_mc
    block_b = fit_block(b, block_b)
    block_k = fit_block(ni, block_k)
    block_j = fit_hc_block(n_hc, n_mc, block_j)  # keep HCs whole in a tile
    k_steps = ni // block_k
    grid = (b // block_b, nj // block_j, k_steps)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps, n_mc=n_mc, gain=gain),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_j), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_j), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_j), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, nj), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_j), jnp.float32)],
        interpret=interpret,
    )(x, w, bias.reshape(1, nj))
