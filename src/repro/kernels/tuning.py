"""Autotuned block-size cache consulted by the kernel wrappers.

``benchmarks/autotune.py`` sweeps block sizes per (kernel, geometry,
backend) and persists the winners as a small JSON cache; the public
wrappers in ``kernels/ops.py`` consult it so Model-1/2/3-scale geometries
run on measured blocks instead of guessed defaults.  Explicit ``block_*``
kwargs always win over the cache.

Cache format (DESIGN.md §7):

    {"version": 1,
     "entries": {"<backend>|<kernel>|k1=v1,k2=v2": {"block_b": 128, ...}}}

where the dims are the wrapper's shape-defining integers in sorted-key
order.  Location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro_bcpnn/autotune.json``.  Lookups are memoized per file
mtime, so a fresh autotune run is picked up without restarting, and a
missing/corrupt cache degrades to the defaults silently.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Dict, Optional

import jax

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
VERSION = 1

_BLOCK_KEYS = ("block_b", "block_h", "block_i", "block_j", "block_k")


def cache_path() -> str:
    return os.environ.get(ENV_CACHE) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro_bcpnn", "autotune.json")


def entry_key(kernel: str, backend: Optional[str] = None, **dims: int) -> str:
    backend = backend or jax.default_backend()
    flat = ",".join(f"{k}={dims[k]}" for k in sorted(dims))
    return f"{backend}|{kernel}|{flat}"


@functools.lru_cache(maxsize=8)
def _load(path: str, mtime: float) -> Dict[str, dict]:
    del mtime  # part of the key only: invalidates on rewrite
    try:
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != VERSION:
            return {}
        return dict(data.get("entries", {}))
    except (OSError, ValueError):
        return {}


def load_cache() -> Dict[str, dict]:
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime
    except OSError:
        return {}
    return _load(path, mtime)


def lookup(kernel: str, **dims: int) -> Dict[str, int]:
    """Tuned ``block_*`` kwargs for this call site, or {} if untuned."""
    entry = load_cache().get(entry_key(kernel, **dims), {})
    return {k: int(v) for k, v in entry.items() if k in _BLOCK_KEYS}


def save_entries(entries: Dict[str, dict], path: Optional[str] = None) -> str:
    """Merge ``entries`` into the cache file (used by the autotuner)."""
    path = path or cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    merged = {}
    try:
        with open(path) as f:
            data = json.load(f)
        if data.get("version") == VERSION:
            merged.update(data.get("entries", {}))
    except (OSError, ValueError):
        pass
    merged.update(entries)
    with open(path, "w") as f:
        json.dump({"version": VERSION, "entries": merged}, f, indent=2,
                  sort_keys=True)
    return path
