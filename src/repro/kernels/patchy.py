"""Patchy-sparse streaming kernels: compact gather layout + fused stages.

The paper's accelerator never touches synapses that don't exist: sparse,
patchy connectivity caps each post-synaptic hypercolumn at ``nact``
pre-synaptic HCs (Table 1's nactHi), and the datapath streams only those.
The dense kernels emulate this by multiplying a mask into a full (Ni, Nj)
product — burning Hi/nact× excess MXU work.  This module is the faithful
translation: the ``(Hj, nact)`` *active-pre-HC index table* (built once
from the HC-level mask — persistent state on compact projections,
memoized on mask identity elsewhere; see core/compact.py) names the live
pre-blocks, which are gathered into a compact ``(Hj, B, K)`` /
``(Hj, K, Mj)`` layout (K = nact·Mi — the aligned "burst" the FPGA
reads), and the fused kernels run dense aligned tiles over the compact
layout only.

Two tiers share the same kernel bodies (DESIGN.md §7):

* ``patchy_forward`` / ``patchy_update`` — DENSE-resident state: operands
  are gathered from (Ni, Nj) matrices per call and (for the update)
  scattered back, an O(Ni·Nj) round-trip per learn step that is the price
  of keeping the trace layout shared with the dense path.
* ``compact_forward`` / ``compact_update`` — COMPACT-resident state
  (``ProjSpec.compact``): weights and joint traces live as (Hj, K, Mj)
  leaves, so the update reads and writes the compact layout in place —
  zero O(Ni·Nj) work on the hot path.  Only the activation gather
  (O(B·Hj·K), inherent to patchy streaming) remains.

Both kernels tile a 3-D grid with the post-HC index as the leading
(unaligned — it never enters a tile) axis; batch/contraction axes are
padded per tiling.pad_spec with the same inert-pad semantics as the dense
kernels (DESIGN.md §7).  Because the mask is exactly-nact per column
(topk_mask invariant), gathers cover precisely the live blocks; K-padding
uses an out-of-range sentinel so pad rows gather zeros and scatter-back
drops them.

Correctness contract:

* the forward kernels are EXACT versus the masked-dense forward for any
  exactly-nact mask (masked-out weights are zero, so skipping them
  changes nothing).
* ``patchy_update`` implements the *patchy-held* plasticity semantics
  (silent synapses hold their last pij); ``compact_update`` implements
  the *compact* semantics (silent synapses are pinned at the independence
  product — they are simply not stored).  The jnp references live in
  core.bcpnn_layer._learn_jnp (dense compute of both semantics) and
  core.compact.learn_compact_jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compact import build_table, gather_dense, scatter_dense, unit_indices
from .padding import pad_axis
from .tiling import NEG, SUBLANE, lane_multiple, pad_mc, pad_spec

# Back-compat aliases: these helpers started life here; their home is now
# core/compact.py (the layout is state, not just a kernel detail).
active_pre_hcs = build_table
unit_gather_indices = unit_indices


def _gather_pre(x: jax.Array, ui: jax.Array, b_pad: int) -> jax.Array:
    """x (B, Ni) -> compact (Hj, B+b_pad, Kp) with zero-filled pads."""
    xg = jnp.take(x, ui, axis=1, mode="fill", fill_value=0.0)  # (B, Hj, Kp)
    return pad_axis(xg, 0, b_pad).transpose(1, 0, 2)


# ------------------------------------------------------ forward kernel ----

def _fwd_kernel(xg_ref, wg_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        xg_ref[0].astype(jnp.float32),
        wg_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # One post-HC per tile: the softmax spans the whole (padded) lane.
        s = (acc_ref[...] + b_ref[0]) * gain           # (tb, Mp)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        o_ref[0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _fwd_call(xg, wg, bg, dtype, bs, ks, hj, mp, gain, interpret):
    """Shared pallas_call for both forward tiers (operands pre-gathered
    and padded to (Hj, Bp, Kp) / (Hj, Kp, Mp) / (Hj, 1, Mp))."""
    return pl.pallas_call(
        functools.partial(_fwd_kernel, k_steps=ks.grid, gain=gain),
        grid=(hj, bs.grid, ks.grid),
        in_specs=[
            pl.BlockSpec((1, bs.block, ks.block), lambda h, i, k: (h, i, k)),
            pl.BlockSpec((1, ks.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs.block, mp), lambda h, i, k: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hj, bs.padded, mp), dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, mp), jnp.float32)],
        interpret=interpret,
    )(xg, wg, bg)


@functools.partial(
    jax.jit,
    static_argnames=("mi", "hj", "mj", "gain", "block_b", "block_k",
                     "interpret"),
)
def patchy_forward(
    x: jax.Array,      # (B, Ni)
    w: jax.Array,      # (Ni, Hj*Mj) masked dense weights
    bias: jax.Array,   # (Hj*Mj,)
    table: jax.Array,  # (Hj, nact) active-pre-HC index table
    mi: int,
    hj: int,
    mj: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused patchy activation over DENSE-resident weights: gather live
    pre-blocks per post-HC, then support-matmul + per-HC softmax over the
    compact layout only."""
    b, ni = x.shape
    k_units = table.shape[1] * mi
    bs = pad_spec(b, block_b, SUBLANE)
    ks = pad_spec(k_units, block_k, lane_multiple(k_units))
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, ks.pad, sentinel=ni)
    xg = _gather_pre(x, ui, bs.pad)                        # (Hj, Bp, Kp)
    wg = pad_axis(gather_dense(w, ui, hj, mj), 2, mp - mj)  # (Hj, Kp, Mp)
    bg = pad_axis(bias.reshape(hj, 1, mj), 2, mp - mj, value=NEG)
    out = _fwd_call(xg, wg, bg, x.dtype, bs, ks, hj, mp, gain, interpret)
    return out[:, :b, :mj].transpose(1, 0, 2).reshape(b, hj * mj)


@functools.partial(
    jax.jit,
    static_argnames=("mi", "gain", "block_b", "block_k", "interpret"),
)
def compact_forward(
    x: jax.Array,      # (B, Ni)
    w_c: jax.Array,    # (Hj, K, Mj) compact-RESIDENT weights
    bias: jax.Array,   # (Hj*Mj,)
    table: jax.Array,  # (Hj, nact)
    mi: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused patchy activation over compact-resident weights: no per-call
    weight gather — only the inherent activation gather feeds the same
    fused matmul+softmax kernel as ``patchy_forward``."""
    b, ni = x.shape
    hj, k_units, mj = w_c.shape
    bs = pad_spec(b, block_b, SUBLANE)
    ks = pad_spec(k_units, block_k, lane_multiple(k_units))
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, ks.pad, sentinel=ni)
    xg = _gather_pre(x, ui, bs.pad)                        # (Hj, Bp, Kp)
    wg = pad_axis(pad_axis(w_c, 1, ks.pad), 2, mp - mj)    # (Hj, Kp, Mp)
    bg = pad_axis(bias.reshape(hj, 1, mj), 2, mp - mj, value=NEG)
    out = _fwd_call(xg, wg, bg, x.dtype, bs, ks, hj, mp, gain, interpret)
    return out[:, :b, :mj].transpose(1, 0, 2).reshape(b, hj * mj)


# ------------------------------------------------------- update kernel ----

def _update_kernel(xg_ref, yg_ref, pij_ref, lpi_ref, lpj_ref, alpha_ref,
                   pij_out_ref, w_out_ref, acc_ref, *, k_steps: int,
                   batch: int, eps: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        xg_ref[0].astype(jnp.float32).T,
        yg_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        alpha = alpha_ref[0, 0]
        co = acc_ref[...] / batch
        new_pij = (1.0 - alpha) * pij_ref[0] + alpha * co
        pij_out_ref[0] = new_pij
        logp = jnp.log(jnp.clip(new_pij, eps * eps, 1.0))
        w_out_ref[0] = logp - (lpi_ref[0].T + lpj_ref[0])


def _update_call(xg, yg, pij_c, lpi_g, lpj_c, alpha, b, bs, is_, hj, mp, eps,
                 interpret):
    """Shared pallas_call for both update tiers: compact co-activation
    matmul + EMA + log-weight fold, all on (Hj, Kp, Mp) tiles."""
    return pl.pallas_call(
        functools.partial(_update_kernel, k_steps=bs.grid, batch=b, eps=eps),
        grid=(hj, is_.grid, bs.grid),
        in_specs=[
            pl.BlockSpec((1, bs.block, is_.block), lambda h, i, k: (h, k, i)),
            pl.BlockSpec((1, bs.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
            pl.BlockSpec((1, 1, is_.block), lambda h, i, k: (h, 0, i)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
            pl.BlockSpec((1, 1), lambda h, i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hj, is_.padded, mp), jnp.float32),
            jax.ShapeDtypeStruct((hj, is_.padded, mp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((is_.block, mp), jnp.float32)],
        interpret=interpret,
    )(xg, yg, pij_c, lpi_g, lpj_c, alpha.reshape(1, 1).astype(jnp.float32))


@functools.partial(
    jax.jit,
    static_argnames=("mi", "hj", "mj", "eps", "block_i", "block_k",
                     "interpret"),
)
def patchy_update(
    pij: jax.Array,     # (Ni, Hj*Mj) dense joint trace
    log_pi: jax.Array,  # (Ni,)
    log_pj: jax.Array,  # (Hj*Mj,)
    x: jax.Array,       # (B, Ni)
    y: jax.Array,       # (B, Hj*Mj)
    table: jax.Array,   # (Hj, nact) active-pre-HC index table
    alpha: jax.Array,   # scalar effective smoothing
    mi: int,
    hj: int,
    mj: int,
    eps: float = 1e-4,
    block_i: int = 512,
    block_k: int = 128,
    interpret: bool = False,
):
    """Patchy-held plasticity on DENSE-resident traces: EMA + weight
    recompute on the compact active layout, gathered from and scattered
    back to the (Ni, Nj) state (the O(Ni·Nj) round-trip ``compact_update``
    eliminates).  Returns (new_pij, new_w): active entries are the exact
    dense EMA, inactive pij entries hold their previous value, inactive
    weights are zero."""
    b, ni = x.shape
    k_units = table.shape[1] * mi
    ks_b = pad_spec(b, block_k, SUBLANE)
    is_ = pad_spec(k_units, block_i, lane_multiple(k_units))
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, is_.pad, sentinel=ni)
    xg = _gather_pre(x, ui, ks_b.pad)                        # (Hj, Bp, Kp)
    y3 = y.reshape(b, hj, mj).transpose(1, 0, 2)
    yg = pad_axis(pad_axis(y3, 2, mp - mj), 1, ks_b.pad)     # (Hj, Bp, Mp)
    pij_c = pad_axis(gather_dense(pij, ui, hj, mj), 2, mp - mj)
    lpi_g = jnp.take(log_pi, ui, axis=0, mode="fill",
                     fill_value=0.0)[:, None, :]             # (Hj, 1, Kp)
    lpj_c = pad_axis(log_pj.reshape(hj, 1, mj), 2, mp - mj)
    new_c, w_c = _update_call(xg, yg, pij_c, lpi_g, lpj_c, alpha, b, ks_b,
                              is_, hj, mp, eps, interpret)
    pij3 = pij.reshape(ni, hj, mj)
    new_pij = scatter_dense(pij3, ui, new_c[:, :, :mj]).reshape(ni, hj * mj)
    w = scatter_dense(jnp.zeros_like(pij3), ui,
                      w_c[:, :, :mj]).reshape(ni, hj * mj)
    return new_pij, w


@functools.partial(
    jax.jit,
    static_argnames=("mi", "eps", "block_i", "block_k", "interpret"),
)
def compact_update(
    pij_c: jax.Array,   # (Hj, K, Mj) compact-RESIDENT joint trace
    log_pi: jax.Array,  # (Ni,)
    log_pj: jax.Array,  # (Hj*Mj,)
    x: jax.Array,       # (B, Ni)
    y: jax.Array,       # (B, Hj*Mj)
    table: jax.Array,   # (Hj, nact)
    alpha: jax.Array,   # scalar effective smoothing
    mi: int,
    eps: float = 1e-4,
    block_i: int = 512,
    block_k: int = 128,
    interpret: bool = False,
):
    """Scatter-free compact plasticity: the EMA reads the resident
    (Hj, K, Mj) trace and the kernel writes the new trace and folded
    weights in the same layout — no (Ni, Nj) array exists anywhere in
    this call.  Returns (new_pij_c, new_w_c), both (Hj, K, Mj)."""
    b, ni = x.shape
    hj, k_units, mj = pij_c.shape
    ks_b = pad_spec(b, block_k, SUBLANE)
    is_ = pad_spec(k_units, block_i, lane_multiple(k_units))
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, is_.pad, sentinel=ni)
    xg = _gather_pre(x, ui, ks_b.pad)                        # (Hj, Bp, Kp)
    y3 = y.reshape(b, hj, mj).transpose(1, 0, 2)
    yg = pad_axis(pad_axis(y3, 2, mp - mj), 1, ks_b.pad)     # (Hj, Bp, Mp)
    pij_p = pad_axis(pad_axis(pij_c, 1, is_.pad), 2, mp - mj)
    lpi_g = jnp.take(log_pi, ui, axis=0, mode="fill",
                     fill_value=0.0)[:, None, :]             # (Hj, 1, Kp)
    lpj_c = pad_axis(log_pj.reshape(hj, 1, mj), 2, mp - mj)
    new_c, w_c = _update_call(xg, yg, pij_p, lpi_g, lpj_c, alpha, b, ks_b,
                              is_, hj, mp, eps, interpret)
    return new_c[:, :k_units, :mj], w_c[:, :k_units, :mj]
