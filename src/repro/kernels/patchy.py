"""Patchy-sparse streaming kernels: compact gather layout + fused stages.

The paper's accelerator never touches synapses that don't exist: sparse,
patchy connectivity caps each post-synaptic hypercolumn at ``nact``
pre-synaptic HCs (Table 1's nactHi), and the datapath streams only those.
The dense kernels emulate this by multiplying a mask into a full (Ni, Nj)
product — burning Hi/nact× excess MXU work.  This module is the faithful
translation: an ``(Hj, nact)`` *active-pre-HC index table* is derived
from the HC-level mask, the live pre-blocks are gathered into a compact
``(Hj, B, K)`` / ``(Hj, K, Mj)`` layout (K = nact·Mi — the aligned
"burst" the FPGA reads), and the fused kernels run dense aligned tiles
over the compact layout only.

Cost model (be precise about what shrinks): **MXU work** and the
weight/trace **matrix traffic through the kernels** scale with nact/Hi
instead of 1 — that is the Hi/nact× win the BENCH tracks.  Two costs do
NOT shrink: the activation gather duplicates x per post-HC (Hj·K vs Ni
values — a net traffic increase whenever Hj·nact > Hi, cheap relative to
the matmul savings because it is O(B·Hj·K) vs O(B·Hi·Mi·Nj) MACs), and
``patchy_update`` scatters its compact results back through the DENSE
(Ni, Nj) pij state, an O(Ni·Nj) copy per learn step that is the price of
keeping the trace layout shared with the dense path, checkpoints and
sharding.  A compact-resident pij layout that eliminates the scatter is
tracked in ROADMAP ("Patchy-trace exploration").

Both kernels tile a 3-D grid with the post-HC index as the leading
(unaligned — it never enters a tile) axis; batch/contraction axes are
padded per tiling.pad_spec with the same inert-pad semantics as the dense
kernels (DESIGN.md §7).  Because the mask is exactly-nact per column
(topk_mask invariant), gathers cover precisely the live blocks; K-padding
uses an out-of-range sentinel so pad rows gather zeros and scatter-back
drops them.

Correctness contract:

* ``patchy_forward`` is EXACT versus the masked-dense forward for any
  exactly-nact mask (masked-out weights are zero, so skipping them
  changes nothing).
* ``patchy_update`` implements the *patchy-trace* plasticity semantics
  (ProjSpec.patchy_traces): active-pair joint traces update exactly as
  the dense EMA; masked-out pairs HOLD their last value (silent synapses
  remember — the memory-capped hardware model).  The jnp reference of the
  same semantics lives in core.bcpnn_layer._learn_jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .padding import pad_axis
from .tiling import NEG, SUBLANE, lane_multiple, pad_mc, pad_spec


def active_pre_hcs(mask: jax.Array, nact: int) -> jax.Array:
    """(Hi, Hj) exactly-nact HC mask -> (Hj, nact) int32 table of active
    pre-HC indices per post-HC, ascending (the compact stream order).

    Derived from the mask on every call — cheap (O(Hi·Hj)) and therefore
    automatically consistent after ``rewire`` swaps receptive fields.
    """
    _, idx = jax.lax.top_k(mask.T, nact)  # (Hj, nact) distinct rows
    return jnp.sort(idx, axis=1).astype(jnp.int32)


def unit_gather_indices(table: jax.Array, mi: int, k_pad: int,
                        sentinel: int) -> jax.Array:
    """Expand the HC table to unit-level gather indices (Hj, nact*Mi+k_pad).
    Pad slots carry ``sentinel`` (out of range): gathers fill zeros there
    and scatters drop them."""
    hj, nact = table.shape
    ui = (table[:, :, None] * mi
          + jnp.arange(mi, dtype=jnp.int32)[None, None, :]).reshape(hj, nact * mi)
    if k_pad:
        ui = jnp.concatenate(
            [ui, jnp.full((hj, k_pad), sentinel, jnp.int32)], axis=1)
    return ui


def _gather_pre(x: jax.Array, ui: jax.Array, b_pad: int) -> jax.Array:
    """x (B, Ni) -> compact (Hj, B+b_pad, Kp) with zero-filled pads."""
    xg = jnp.take(x, ui, axis=1, mode="fill", fill_value=0.0)  # (B, Hj, Kp)
    return pad_axis(xg, 0, b_pad).transpose(1, 0, 2)


def _gather_cols(dense: jax.Array, ui: jax.Array, hj: int, mj: int) -> jax.Array:
    """dense (Ni, Hj*Mj) -> compact (Hj, Kp, Mj), zero fill for pad rows."""
    d3 = dense.reshape(dense.shape[0], hj, mj)
    take = lambda idx, col: jnp.take(col, idx, axis=0, mode="fill",
                                     fill_value=0.0)
    return jax.vmap(take, in_axes=(0, 1))(ui, d3)


def _scatter_cols(base3: jax.Array, ui: jax.Array, vals: jax.Array) -> jax.Array:
    """Scatter compact (Hj, Kp, Mj) values back into a (Ni, Hj, Mj) base;
    sentinel rows drop."""
    put = lambda col, idx, v: col.at[idx].set(v, mode="drop")
    return jax.vmap(put, in_axes=(1, 0, 0), out_axes=1)(base3, ui, vals)


# ------------------------------------------------------ forward kernel ----

def _fwd_kernel(xg_ref, wg_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        xg_ref[0].astype(jnp.float32),
        wg_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # One post-HC per tile: the softmax spans the whole (padded) lane.
        s = (acc_ref[...] + b_ref[0]) * gain           # (tb, Mp)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        o_ref[0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nact", "mi", "hj", "mj", "gain", "block_b", "block_k",
                     "interpret"),
)
def patchy_forward(
    x: jax.Array,      # (B, Ni)
    w: jax.Array,      # (Ni, Hj*Mj) masked dense weights
    bias: jax.Array,   # (Hj*Mj,)
    mask: jax.Array,   # (Hi, Hj) exactly-nact HC mask
    nact: int,
    mi: int,
    hj: int,
    mj: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Fused patchy activation: gather live pre-blocks per post-HC, then
    support-matmul + per-HC softmax over the compact layout only."""
    b, ni = x.shape
    k_units = nact * mi
    bs = pad_spec(b, block_b, SUBLANE)
    ks = pad_spec(k_units, block_k, lane_multiple(k_units))
    mp = pad_mc(mj)
    table = active_pre_hcs(mask, nact)
    ui = unit_gather_indices(table, mi, ks.pad, sentinel=ni)
    xg = _gather_pre(x, ui, bs.pad)                        # (Hj, Bp, Kp)
    wg = pad_axis(_gather_cols(w, ui, hj, mj), 2, mp - mj)  # (Hj, Kp, Mp)
    bg = pad_axis(bias.reshape(hj, 1, mj), 2, mp - mj, value=NEG)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, k_steps=ks.grid, gain=gain),
        grid=(hj, bs.grid, ks.grid),
        in_specs=[
            pl.BlockSpec((1, bs.block, ks.block), lambda h, i, k: (h, i, k)),
            pl.BlockSpec((1, ks.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs.block, mp), lambda h, i, k: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hj, bs.padded, mp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, mp), jnp.float32)],
        interpret=interpret,
    )(xg, wg, bg)
    return out[:, :b, :mj].transpose(1, 0, 2).reshape(b, hj * mj)


# ------------------------------------------------------- update kernel ----

def _update_kernel(xg_ref, yg_ref, pij_ref, lpi_ref, lpj_ref, alpha_ref,
                   pij_out_ref, w_out_ref, acc_ref, *, k_steps: int,
                   batch: int, eps: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        xg_ref[0].astype(jnp.float32).T,
        yg_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        alpha = alpha_ref[0, 0]
        co = acc_ref[...] / batch
        new_pij = (1.0 - alpha) * pij_ref[0] + alpha * co
        pij_out_ref[0] = new_pij
        logp = jnp.log(jnp.clip(new_pij, eps * eps, 1.0))
        w_out_ref[0] = logp - (lpi_ref[0].T + lpj_ref[0])


@functools.partial(
    jax.jit,
    static_argnames=("nact", "mi", "hj", "mj", "eps", "block_i", "block_k",
                     "interpret"),
)
def patchy_update(
    pij: jax.Array,     # (Ni, Hj*Mj) dense joint trace
    log_pi: jax.Array,  # (Ni,)
    log_pj: jax.Array,  # (Hj*Mj,)
    x: jax.Array,       # (B, Ni)
    y: jax.Array,       # (B, Hj*Mj)
    mask: jax.Array,    # (Hi, Hj) exactly-nact HC mask
    alpha: jax.Array,   # scalar effective smoothing
    nact: int,
    mi: int,
    hj: int,
    mj: int,
    eps: float = 1e-4,
    block_i: int = 512,
    block_k: int = 128,
    interpret: bool = False,
):
    """Patchy-trace plasticity: EMA + weight recompute on the compact
    active layout only, scattered back to the dense state.  Returns
    (new_pij, new_w): active entries are the exact dense EMA, inactive
    pij entries hold their previous value, inactive weights are zero."""
    b, ni = x.shape
    k_units = nact * mi
    ks_b = pad_spec(b, block_k, SUBLANE)
    is_ = pad_spec(k_units, block_i, lane_multiple(k_units))
    mp = pad_mc(mj)
    table = active_pre_hcs(mask, nact)
    ui = unit_gather_indices(table, mi, is_.pad, sentinel=ni)
    xg = _gather_pre(x, ui, ks_b.pad)                        # (Hj, Bp, Kp)
    y3 = y.reshape(b, hj, mj).transpose(1, 0, 2)
    yg = pad_axis(pad_axis(y3, 2, mp - mj), 1, ks_b.pad)     # (Hj, Bp, Mp)
    pij_c = pad_axis(_gather_cols(pij, ui, hj, mj), 2, mp - mj)
    lpi_g = jnp.take(log_pi, ui, axis=0, mode="fill",
                     fill_value=0.0)[:, None, :]             # (Hj, 1, Kp)
    lpj_c = pad_axis(log_pj.reshape(hj, 1, mj), 2, mp - mj)
    new_c, w_c = pl.pallas_call(
        functools.partial(_update_kernel, k_steps=ks_b.grid, batch=b, eps=eps),
        grid=(hj, is_.grid, ks_b.grid),
        in_specs=[
            pl.BlockSpec((1, ks_b.block, is_.block), lambda h, i, k: (h, k, i)),
            pl.BlockSpec((1, ks_b.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
            pl.BlockSpec((1, 1, is_.block), lambda h, i, k: (h, 0, i)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
            pl.BlockSpec((1, 1), lambda h, i, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
            pl.BlockSpec((1, is_.block, mp), lambda h, i, k: (h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((hj, is_.padded, mp), jnp.float32),
            jax.ShapeDtypeStruct((hj, is_.padded, mp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((is_.block, mp), jnp.float32)],
        interpret=interpret,
    )(xg, yg, pij_c, lpi_g, lpj_c, alpha.reshape(1, 1).astype(jnp.float32))
    pij3 = pij.reshape(ni, hj, mj)
    new_pij = _scatter_cols(pij3, ui, new_c[:, :, :mj]).reshape(ni, hj * mj)
    w = _scatter_cols(jnp.zeros_like(pij3), ui,
                      w_c[:, :, :mj]).reshape(ni, hj * mj)
    return new_pij, w
