"""Public jit'd wrappers for the Pallas kernels.

Auto-selects interpret mode off-TPU (this container validates kernels on
CPU via the Pallas interpreter; on a real TPU the same calls compile to
Mosaic).  `fused_forward` and `fused_learn` are the production
implementations behind `ProjSpec(backend="pallas")`: the core's dispatch
point (core/bcpnn_layer.py, DESIGN.md §3) routes every activation /
plasticity call of a pallas-tagged projection here, mirroring the paper's
stream-dataflow configuration, while the pure-jnp reference path plays
the sequential baseline (benchmarks/bench_stream_vs_seq.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.bcpnn_layer import Projection, ProjSpec, _expand_mask
from ..core.traces import Traces
from .bcpnn_fwd import bcpnn_fwd_pallas
from .bcpnn_update import bcpnn_update_pallas
from .hc_softmax import hc_softmax_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def hc_softmax(support: jax.Array, n_hc: int, n_mc: int, gain: float = 1.0,
               **kw) -> jax.Array:
    return hc_softmax_pallas(support, n_hc, n_mc, gain,
                             interpret=_interpret(), **kw)


def bcpnn_fwd(x: jax.Array, w: jax.Array, bias: jax.Array, n_hc: int,
              n_mc: int, gain: float = 1.0, **kw) -> jax.Array:
    return bcpnn_fwd_pallas(x, w, bias, n_hc, n_mc, gain,
                            interpret=_interpret(), **kw)


def bcpnn_update(pij, log_pi, log_pj, x, y, mask, alpha, eps=1e-4, **kw):
    return bcpnn_update_pallas(pij, log_pi, log_pj, x, y, mask, alpha,
                               eps=eps, interpret=_interpret(), **kw)


# ------------------------------------------------- fused core stages ----

def fused_forward(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Kernel-fused equivalent of core.bcpnn_layer.forward."""
    return bcpnn_fwd(x, proj.w, proj.b, spec.post.H, spec.post.M, spec.gain)


def fused_learn(proj: Projection, spec: ProjSpec, x: jax.Array,
                y: jax.Array) -> Projection:
    """Kernel-fused equivalent of core.bcpnn_layer.learn.

    The cheap vector traces (p_i, p_j) update in plain jnp; the O(Ni·Nj)
    joint-trace EMA + weight recompute run in the fused Pallas kernel.
    """
    tr = proj.traces
    a = jnp.maximum(1.0 / (tr.t.astype(jnp.float32) + 1.0), spec.alpha)
    pi = (1.0 - a) * tr.pi + a * jnp.mean(x, axis=0)
    pj = (1.0 - a) * tr.pj + a * jnp.mean(y, axis=0)
    log_pi = jnp.log(jnp.clip(pi, spec.eps, 1.0))
    log_pj = jnp.log(jnp.clip(pj, spec.eps, 1.0))
    mask_units = _expand_mask(proj.mask, spec)
    new_pij, w = bcpnn_update(tr.pij, log_pi, log_pj, x, y, mask_units,
                              a, eps=spec.eps)
    b = log_pj
    return Projection(
        traces=Traces(pi=pi, pj=pj, pij=new_pij, t=tr.t + 1),
        w=w, b=b, mask=proj.mask,
    )
