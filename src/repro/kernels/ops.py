"""Public jit'd wrappers for the Pallas kernels.

Auto-selects interpret mode off-TPU (this container validates kernels on
CPU via the Pallas interpreter; on a real TPU the same calls compile to
Mosaic).  `fused_forward` and `fused_learn` are the production
implementations behind `ProjSpec(backend="pallas")`: the core's dispatch
point (core/bcpnn_layer.py, DESIGN.md §3) routes every activation /
plasticity call of a pallas-tagged projection here, mirroring the paper's
stream-dataflow configuration, while the pure-jnp reference path plays
the sequential baseline (benchmarks/bench_stream_vs_seq.py).

Two per-projection execution choices happen here (DESIGN.md §7):

* **dense vs patchy** — projections with an ``nact`` connectivity budget
  route ``fused_forward`` through the compact patchy kernels
  (kernels/patchy.py), streaming only live pre-blocks; ``fused_learn``
  additionally requires ``spec.patchy_traces`` (patchy plasticity is a
  semantic choice — silent synapses hold their traces — not just a
  schedule).
* **block sizes** — unless the caller passes explicit ``block_*`` kwargs,
  each wrapper consults the autotune cache (kernels/tuning.py) keyed by
  the call's geometry and the active jax backend.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ..core.bcpnn_layer import (
    InferPack, Projection, ProjSpec, expand_hc_mask, is_compact, is_patchy,
)
from ..core.compact import cached_table
from ..core.traces import Traces
from . import tuning
from .bcpnn_fwd import bcpnn_fwd_pallas
from .bcpnn_update import bcpnn_update_pallas
from .hc_softmax import hc_softmax_pallas
from .patchy import compact_forward, compact_update, patchy_forward, patchy_update
from .quant import quant_compact_forward, quant_fwd_pallas, quant_patchy_forward

# Force interpret mode on ("1") or off ("0") regardless of the detected
# backend — tests and CI pin the interpreter explicitly with this.
ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"


@functools.lru_cache(maxsize=1)
def _default_backend() -> str:
    # jax.default_backend() initializes the platform on every call; the
    # answer cannot change within a process, so resolve it once.
    return jax.default_backend()


def _interpret() -> bool:
    env = os.environ.get(ENV_INTERPRET)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "")
    return _default_backend() != "tpu"


# block kwargs each wrapper accepts — guards against stale cache entries
_KERNEL_BLOCKS = {
    "hc_softmax": ("block_b", "block_h"),
    "bcpnn_fwd": ("block_b", "block_j", "block_k"),
    "bcpnn_update": ("block_i", "block_j", "block_k"),
    "patchy_forward": ("block_b", "block_k"),
    "patchy_update": ("block_i", "block_k"),
    "compact_forward": ("block_b", "block_k"),
    "compact_update": ("block_i", "block_k"),
    "quant_fwd": ("block_b", "block_j", "block_k"),
    "quant_patchy_forward": ("block_b", "block_k"),
    "quant_compact_forward": ("block_b", "block_k"),
}


def _blocks(kernel: str, kw: dict, **dims: int) -> dict:
    """Merge autotuned block sizes under explicit caller kwargs."""
    if any(k.startswith("block_") for k in kw):
        return kw
    tuned = tuning.lookup(kernel, **dims)
    if not tuned:
        return kw
    allowed = _KERNEL_BLOCKS[kernel]
    return {**{k: v for k, v in tuned.items() if k in allowed}, **kw}


def hc_softmax(support: jax.Array, n_hc: int, n_mc: int, gain: float = 1.0,
               **kw) -> jax.Array:
    kw = _blocks("hc_softmax", kw, b=support.shape[0], n_hc=n_hc, n_mc=n_mc)
    return hc_softmax_pallas(support, n_hc, n_mc, gain,
                             interpret=_interpret(), **kw)


def bcpnn_fwd(x: jax.Array, w: jax.Array, bias: jax.Array, n_hc: int,
              n_mc: int, gain: float = 1.0, **kw) -> jax.Array:
    kw = _blocks("bcpnn_fwd", kw, b=x.shape[0], ni=x.shape[1],
                 n_hc=n_hc, n_mc=n_mc)
    return bcpnn_fwd_pallas(x, w, bias, n_hc, n_mc, gain,
                            interpret=_interpret(), **kw)


def bcpnn_update(pij, log_pi, log_pj, x, y, mask, alpha, eps=1e-4, **kw):
    kw = _blocks("bcpnn_update", kw, b=x.shape[0], ni=x.shape[1],
                 nj=y.shape[1])
    return bcpnn_update_pallas(pij, log_pi, log_pj, x, y, mask, alpha,
                               eps=eps, interpret=_interpret(), **kw)


# ------------------------------------------------- fused core stages ----

def fused_forward(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Kernel-fused equivalent of core.bcpnn_layer.forward.

    Patchy projections stream only the live pre-blocks (exact: masked-out
    weights are zero, so the skipped work contributes nothing).
    Compact-resident projections additionally skip the per-call weight
    gather: the resident (Hj, K, Mj) weights and the persistent index
    table feed the kernel directly."""
    if is_compact(spec) and proj.table is not None:
        kw = _blocks("compact_forward", {}, b=x.shape[0],
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        return compact_forward(x, proj.w, proj.b, proj.table, spec.pre.M,
                               spec.gain, interpret=_interpret(), **kw)
    if is_patchy(spec):
        kw = _blocks("patchy_forward", {}, b=x.shape[0],
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        table = cached_table(proj.mask, spec.nact)
        return patchy_forward(
            x, proj.w, proj.b, table, spec.pre.M,
            spec.post.H, spec.post.M, spec.gain,
            interpret=_interpret(), **kw)
    return bcpnn_fwd(x, proj.w, proj.b, spec.post.H, spec.post.M, spec.gain)


def fused_packed_forward(pack: InferPack, spec: ProjSpec,
                         x: jax.Array) -> jax.Array:
    """Kernel-fused forward from an ``InferPack`` (DESIGN.md §8).

    fp32/bf16 packs route through the same kernels as ``fused_forward``
    (their matmuls cast operands to fp32 in-kernel, so bf16 weights are
    a pure bandwidth win); int8 packs route through the fixed-point
    kernels in kernels/quant.py with the pack's per-HC scales folded
    into the softmax epilogue.  The patchy index table comes from the
    pack — never re-derived from the mask on the serving path."""
    b = x.shape[0]
    if pack.w.dtype == jnp.int8:
        if pack.w.ndim == 3:  # compact-resident layout
            hj, k_units, mj = pack.w.shape
            kw = _blocks("quant_compact_forward", {}, b=b, k=k_units,
                         hj=hj, mj=mj)
            return quant_compact_forward(
                x, pack.w, pack.b, pack.scale, pack.table, spec.pre.M,
                spec.gain, interpret=_interpret(), **kw)
        if is_patchy(spec) and pack.table is not None:
            kw = _blocks("quant_patchy_forward", {}, b=b,
                         k=spec.nact * spec.pre.M, hj=spec.post.H,
                         mj=spec.post.M)
            return quant_patchy_forward(
                x, pack.w, pack.b, pack.scale, pack.table, spec.pre.M,
                spec.post.H, spec.post.M, spec.gain,
                interpret=_interpret(), **kw)
        kw = _blocks("quant_fwd", {}, b=b, ni=x.shape[1],
                     n_hc=spec.post.H, n_mc=spec.post.M)
        return quant_fwd_pallas(x, pack.w, pack.b, pack.scale, spec.post.H,
                                spec.post.M, spec.gain,
                                interpret=_interpret(), **kw)
    if pack.w.ndim == 3:
        kw = _blocks("compact_forward", {}, b=b,
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        return compact_forward(x, pack.w, pack.b, pack.table, spec.pre.M,
                               spec.gain, interpret=_interpret(), **kw)
    if is_patchy(spec) and pack.table is not None:
        kw = _blocks("patchy_forward", {}, b=b,
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        return patchy_forward(
            x, pack.w, pack.b, pack.table, spec.pre.M,
            spec.post.H, spec.post.M, spec.gain,
            interpret=_interpret(), **kw)
    return bcpnn_fwd(x, pack.w, pack.b, spec.post.H, spec.post.M, spec.gain)


def fused_learn(proj: Projection, spec: ProjSpec, x: jax.Array,
                y: jax.Array) -> Projection:
    """Kernel-fused equivalent of core.bcpnn_layer.learn.

    The cheap vector traces (p_i, p_j) update in plain jnp; the O(Ni·Nj)
    joint-trace EMA + weight recompute run in the fused Pallas kernel —
    the compact patchy kernel when the projection opted into patchy-trace
    plasticity (DESIGN.md §7), the dense masked kernel otherwise.
    """
    tr = proj.traces
    a = jnp.maximum(1.0 / (tr.t.astype(jnp.float32) + 1.0), spec.alpha)
    pi = (1.0 - a) * tr.pi + a * jnp.mean(x, axis=0)
    pj = (1.0 - a) * tr.pj + a * jnp.mean(y, axis=0)
    log_pi = jnp.log(jnp.clip(pi, spec.eps, 1.0))
    log_pj = jnp.log(jnp.clip(pj, spec.eps, 1.0))
    if is_compact(spec) and proj.table is None:
        raise ValueError(
            "fused_learn: ProjSpec.compact projection carries a dense-layout "
            "state (no index-table leaf); convert it with "
            "core.compact.compactify_state (or scripts/migrate_ckpt.py) — "
            "the dense-compute reference of the compact semantics lives on "
            "the jnp backend only")
    if is_compact(spec):
        # Scatter-free hot path: the kernel reads and writes the resident
        # compact trace/weights — zero O(Ni·Nj) work per step.
        kw = _blocks("compact_update", {}, b=x.shape[0],
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        new_pij, w = compact_update(
            tr.pij, log_pi, log_pj, x, y, proj.table, a, spec.pre.M,
            eps=spec.eps, interpret=_interpret(), **kw)
    elif is_patchy(spec) and spec.patchy_traces:
        kw = _blocks("patchy_update", {}, b=x.shape[0],
                     k=spec.nact * spec.pre.M, hj=spec.post.H,
                     mj=spec.post.M)
        table = cached_table(proj.mask, spec.nact)
        new_pij, w = patchy_update(
            tr.pij, log_pi, log_pj, x, y, table, a,
            spec.pre.M, spec.post.H, spec.post.M, eps=spec.eps,
            interpret=_interpret(), **kw)
    else:
        mask_units = expand_hc_mask(proj.mask, spec)
        new_pij, w = bcpnn_update(tr.pij, log_pi, log_pj, x, y, mask_units,
                                  a, eps=spec.eps)
    b = log_pj
    return Projection(
        traces=Traces(pi=pi, pj=pj, pij=new_pij, t=tr.t + 1),
        w=w, b=b, mask=proj.mask, table=proj.table,
    )
