"""Pallas TPU kernel: per-hypercolumn softmax (divisive normalization).

The (padded) minicolumn dimension is kept whole inside each block so the
normalization is block-local; the batch and hypercolumn dimensions tile
the grid.  Operands are padded to aligned blocks (tiling.pad_hc_spec):
pad minicolumn lanes carry ``NEG`` support, so they underflow to zero
probability and leave real softmax sums untouched; pad batch rows and
pad-HCs produce inert values that are sliced off before returning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .padding import pad_axis, pad_hc_axis, unpad_hc_axis
from .tiling import NEG, SUBLANE, pad_hc_spec, pad_spec


def _kernel(s_ref, o_ref, *, n_mc: int, gain: float):
    s = s_ref[...].astype(jnp.float32) * gain          # (tb, th*M)
    tb, tn = s.shape
    s = s.reshape(tb, tn // n_mc, n_mc)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = out.reshape(tb, tn).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_hc", "n_mc", "gain", "block_b", "block_h", "interpret")
)
def hc_softmax_pallas(
    support: jax.Array,
    n_hc: int,
    n_mc: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_h: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """support: (B, n_hc*n_mc) -> rates, softmax within each HC."""
    b, n = support.shape
    assert n == n_hc * n_mc, (n, n_hc, n_mc)
    bs = pad_spec(b, block_b, SUBLANE)
    hs = pad_hc_spec(n_hc, n_mc, block_h * n_mc)
    s = pad_hc_axis(support, 1, hs, value=NEG)
    s = pad_axis(s, 0, bs.pad)
    grid = (bs.grid, hs.grid)
    out = pl.pallas_call(
        functools.partial(_kernel, n_mc=hs.mc_padded, gain=gain),
        grid=grid,
        in_specs=[pl.BlockSpec((bs.block, hs.block_units), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bs.block, hs.block_units), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bs.padded, hs.padded_units),
                                       support.dtype),
        interpret=interpret,
    )(s)
    return unpad_hc_axis(out[:b], 1, hs)
