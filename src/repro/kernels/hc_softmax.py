"""Pallas TPU kernel: per-hypercolumn softmax (divisive normalization).

The minicolumn dimension M is kept whole inside each block so the
normalization is block-local; the batch and hypercolumn dimensions tile
the grid.  VMEM per block: tb * th * M * 4 bytes (default 128*8*128*4 =
512 KiB, comfortably double-bufferable in ~16 MiB VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import fit_block


def _kernel(s_ref, o_ref, *, n_mc: int, gain: float):
    s = s_ref[...].astype(jnp.float32) * gain          # (tb, th*M)
    tb, tn = s.shape
    s = s.reshape(tb, tn // n_mc, n_mc)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = out.reshape(tb, tn).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_hc", "n_mc", "gain", "block_b", "block_h", "interpret")
)
def hc_softmax_pallas(
    support: jax.Array,
    n_hc: int,
    n_mc: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_h: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """support: (B, n_hc*n_mc) -> rates, softmax within each HC."""
    b, n = support.shape
    assert n == n_hc * n_mc, (n, n_hc, n_mc)
    block_b = fit_block(b, block_b)
    block_h = fit_block(n_hc, block_h)
    bn = block_h * n_mc
    grid = (b // block_b, n_hc // block_h)
    return pl.pallas_call(
        functools.partial(_kernel, n_mc=n_mc, gain=gain),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_b, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), support.dtype),
        interpret=interpret,
    )(support)
