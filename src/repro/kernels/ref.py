"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret
mode on CPU, sweeping shapes/dtypes in tests/test_kernels.py) and the
"sequential baseline" of the paper's §4.1 optimization story: each oracle
materializes every intermediate in HBM, exactly what the fused kernels
avoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_hc_softmax(support: jax.Array, n_hc: int, n_mc: int,
                   gain: float = 1.0) -> jax.Array:
    """Per-hypercolumn softmax.  support: (B, n_hc * n_mc)."""
    b = support.shape[0]
    s = support.reshape(b, n_hc, n_mc).astype(jnp.float32) * gain
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    return out.reshape(b, n_hc * n_mc).astype(support.dtype)


def ref_bcpnn_fwd(x: jax.Array, w: jax.Array, bias: jax.Array,
                  n_hc: int, n_mc: int, gain: float = 1.0) -> jax.Array:
    """Activation stage: support matmul + bias + per-HC softmax.

    x: (B, Ni), w: (Ni, Nj), bias: (Nj,)  ->  rates (B, Nj).
    """
    support = x.astype(jnp.float32) @ w.astype(jnp.float32) + bias.astype(jnp.float32)
    return ref_hc_softmax(support, n_hc, n_mc, gain).astype(x.dtype)


def ref_bcpnn_update(
    pij: jax.Array,      # (Ni, Nj) joint trace
    log_pi: jax.Array,   # (Ni,) log of (clipped) updated pre marginals
    log_pj: jax.Array,   # (Nj,) log of (clipped) updated post marginals
    x: jax.Array,        # (B, Ni) pre rates
    y: jax.Array,        # (B, Nj) post rates
    mask: jax.Array,     # (Ni, Nj) unit-level structural mask
    alpha: jax.Array,    # scalar effective smoothing
    eps: float = 1e-4,
):
    """Plasticity stage: trace EMA + Bayesian log-weight recompute.

    Returns (new_pij, new_w).  The co-activation XᵀY/B is the MXU matmul;
    the log-weight epilogue is fused so p_ij never round-trips to HBM
    between the two stages (paper Opt #2).
    """
    b = x.shape[0]
    co = (x.astype(jnp.float32).T @ y.astype(jnp.float32)) / b
    new_pij = (1.0 - alpha) * pij + alpha * co
    w = jnp.log(jnp.clip(new_pij, eps * eps, 1.0)) - (log_pi[:, None] + log_pj[None, :])
    return new_pij, w * mask
