"""Pallas TPU kernels for the BCPNN hot spots (+ pure-jnp oracles).

Dense kernels run on pad-to-aligned tiling plans (tiling.py); patchy
projections stream a compact gathered layout (patchy.py); block sizes
come from the autotune cache (tuning.py) unless the caller overrides.
"""
from .ops import (bcpnn_fwd, bcpnn_update, fused_forward, fused_learn,
                  fused_packed_forward, hc_softmax)
from .patchy import (active_pre_hcs, compact_forward, compact_update,
                     patchy_forward, patchy_update)
from .quant import (dequantize_compact, dequantize_dense,
                    quant_compact_forward, quant_fwd_pallas,
                    quant_patchy_forward, quantize_acts, quantize_compact,
                    quantize_dense)
from .ref import ref_bcpnn_fwd, ref_bcpnn_update, ref_hc_softmax

__all__ = [
    "bcpnn_fwd", "bcpnn_update", "fused_forward", "fused_learn", "hc_softmax",
    "fused_packed_forward",
    "active_pre_hcs", "patchy_forward", "patchy_update",
    "compact_forward", "compact_update",
    "quantize_acts", "quantize_dense", "quantize_compact",
    "dequantize_dense", "dequantize_compact",
    "quant_fwd_pallas", "quant_patchy_forward", "quant_compact_forward",
    "ref_bcpnn_fwd", "ref_bcpnn_update", "ref_hc_softmax",
]
