"""Pallas TPU kernels for the BCPNN hot spots (+ pure-jnp oracles)."""
from .ops import bcpnn_fwd, bcpnn_update, fused_forward, fused_learn, hc_softmax
from .ref import ref_bcpnn_fwd, ref_bcpnn_update, ref_hc_softmax

__all__ = [
    "bcpnn_fwd", "bcpnn_update", "fused_forward", "fused_learn", "hc_softmax",
    "ref_bcpnn_fwd", "ref_bcpnn_update", "ref_hc_softmax",
]
