"""Pallas TPU kernels for the BCPNN hot spots (+ pure-jnp oracles).

Dense kernels run on pad-to-aligned tiling plans (tiling.py); patchy
projections stream a compact gathered layout (patchy.py); block sizes
come from the autotune cache (tuning.py) unless the caller overrides.
"""
from .ops import bcpnn_fwd, bcpnn_update, fused_forward, fused_learn, hc_softmax
from .patchy import (active_pre_hcs, compact_forward, compact_update,
                     patchy_forward, patchy_update)
from .ref import ref_bcpnn_fwd, ref_bcpnn_update, ref_hc_softmax

__all__ = [
    "bcpnn_fwd", "bcpnn_update", "fused_forward", "fused_learn", "hc_softmax",
    "active_pre_hcs", "patchy_forward", "patchy_update",
    "compact_forward", "compact_update",
    "ref_bcpnn_fwd", "ref_bcpnn_update", "ref_hc_softmax",
]
