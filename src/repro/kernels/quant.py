"""Tier-2 low-precision inference: per-hypercolumn int8 quantization and
the int8 forward kernels.

The paper's fixed-point analysis (§3) splits the precision budget: the
trace EMAs must stay fp32 (per-step increments are below narrow-float
resolution), but the *inference-only* weights — folded log-odds that are
read, never accumulated into — tolerate aggressive quantization.  This
module is that split's int8 tier (DESIGN.md §8):

* **Per-post-HC symmetric scales.**  A post-hypercolumn is the natural
  quantization group: its Mj minicolumns compete in one softmax, so a
  shared scale preserves their support *ordering* exactly up to rounding,
  and the scale folds into the softmax epilogue as one scalar per HC.
  ``scale[j] = absmax_j / 127`` with ``w ≈ w_q * scale[j]`` — symmetric,
  zero-point-free (BCPNN log-odds are naturally zero-centered: silent and
  independent synapses sit at exactly 0, which must quantize to exactly
  0 for the patchy forward to stay exact).
* **Fixed Q0.7 activations.**  BCPNN rates are probabilities in [0, 1]
  (per-HC softmax outputs / complement-coded inputs), so activations
  quantize with the *static* scale 1/127 — no per-batch ranging on the
  hot path.
* **Integer accumulation, fp32 epilogue.**  The support matmul
  accumulates int8×int8 products exactly; dequantization is one fused
  multiply (``acc * scale[j]/127²``) folded into the fp32 bias-add +
  per-HC softmax stage, which stays fp32 like every other kernel here.

Accumulator note: the kernels keep an int32 accumulator but compute each
block's partial product on the float unit (operands cast int8→f32, dot
with f32 preferred type, partial cast back to int32).  For block_k ≤ 1024
this is *bit-exact* int8×int8→int32 arithmetic — products are ≤ 127² <
2¹⁴ and a ≤1024-term sum stays < 2²⁴, inside f32's exact-integer range —
while running at f32 MXU/GEMM speed everywhere (XLA:CPU lowers native s8
dots to scalar loops ~7× slower, and the bandwidth win of int8 operands
is the point of this tier, not integer ALUs).  ``_check_exact_block``
enforces the bound.

Everything here is forward/inference-only: quantization happens at fold
boundaries from the fp32 weights (core.bcpnn_layer.pack_projection), and
no learning state ever leaves fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.compact import unit_indices
from .padding import pad_axis, pad_hc_axis, unpad_hc_axis
from .tiling import LANE, NEG, pad_hc_spec, pad_mc, pad_spec

INT8_MAX = 127          # symmetric: code -128 is never emitted
ACT_SCALE = 1.0 / 127   # fixed Q0.7 step for rates in [0, 1]
INT8_SUBLANE = 32       # int8 Mosaic sublane tile (f32's is 8)

# Exact-integer ceiling of the f32-emulated int8 dot: a block_k-term sum
# of ≤127² products must stay below 2^24.
_EXACT_BLOCK_K = (1 << 24) // (INT8_MAX * INT8_MAX)


def _check_exact_block(block_k: int) -> None:
    if block_k > _EXACT_BLOCK_K:
        raise ValueError(
            f"int8 kernels require block_k <= {_EXACT_BLOCK_K} for the "
            f"f32-emulated integer dot to be bit-exact (got {block_k})")


# ------------------------------------------------- fold-time quantize ----

def quantize_acts(x: jax.Array) -> jax.Array:
    """Rates (values in [0, 1]) -> int8 codes on the fixed Q0.7 grid.
    Per-request cost, O(B·N) — the weight quantization is the fold-time
    half; this is the streaming half."""
    return jnp.round(jnp.clip(x, 0.0, 1.0) * INT8_MAX).astype(jnp.int8)


def _scales_from_absmax(absmax: jax.Array) -> jax.Array:
    # An all-zero group (freshly-initialized or fully-silent HC) gets a
    # harmless nonzero scale: its codes are all 0 either way.
    return jnp.maximum(absmax, jnp.float32(1e-12)) / INT8_MAX


def quantize_dense(w: jax.Array, n_hc: int, n_mc: int):
    """Dense (Ni, Nj=n_hc·n_mc) fp32 weights -> (w_q int8, scale (Hj,))
    with per-post-HC symmetric scales: ``w ≈ w_q * scale[j]``."""
    ni, nj = w.shape
    w3 = w.reshape(ni, n_hc, n_mc)
    scale = _scales_from_absmax(jnp.max(jnp.abs(w3), axis=(0, 2)))
    codes = jnp.round(w3 / scale[None, :, None])
    w_q = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return w_q.reshape(ni, nj), scale


def quantize_compact(w_c: jax.Array):
    """Compact-resident (Hj, K, Mj) fp32 weights -> (w_q int8,
    scale (Hj,)); same per-post-HC scheme on the compact layout."""
    scale = _scales_from_absmax(jnp.max(jnp.abs(w_c), axis=(1, 2)))
    codes = jnp.round(w_c / scale[:, None, None])
    w_q = jnp.clip(codes, -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return w_q, scale


def dequantize_dense(w_q: jax.Array, scale: jax.Array, n_hc: int,
                     n_mc: int) -> jax.Array:
    ni, nj = w_q.shape
    w3 = w_q.astype(jnp.float32).reshape(ni, n_hc, n_mc)
    return (w3 * scale[None, :, None]).reshape(ni, nj)


def dequantize_compact(w_q: jax.Array, scale: jax.Array) -> jax.Array:
    return w_q.astype(jnp.float32) * scale[:, None, None]


# ----------------------------------------------------- jnp references ----

def quant_support_dense_jnp(x, w_q, scale, b, n_hc, n_mc):
    """Fixed-point support on the dense layout, pure jnp: the production
    path of ``backend="jnp"`` int8 projections and the oracle of the
    padded-dense int8 kernel.  Same arithmetic (quantized activations,
    integer-valued accumulation, scale-folded fp32 dequant)."""
    xq = quantize_acts(x).astype(jnp.float32)
    acc = xq @ w_q.astype(jnp.float32)
    su = jnp.repeat(scale * ACT_SCALE, n_mc)
    return b.astype(jnp.float32)[None, :] + acc * su[None, :]


def quant_support_compact_jnp(x, w_q, scale, b, table, mi):
    """Fixed-point support on the compact (Hj, K, Mj) layout, pure jnp."""
    hj, k, mj = w_q.shape
    ui = unit_indices(table, mi, sentinel=x.shape[1])
    xq = jnp.take(quantize_acts(x).astype(jnp.float32), ui, axis=1,
                  mode="fill", fill_value=0.0)            # (B, Hj, K)
    acc = jnp.einsum("bjk,jkm->bjm", xq, w_q.astype(jnp.float32))
    s3 = acc * (scale * ACT_SCALE)[None, :, None]
    return s3.reshape(x.shape[0], hj * mj) + b.astype(jnp.float32)[None, :]


# --------------------------------------------- padded-dense int8 kernel ----

def _quant_kernel(x_ref, w_ref, b_ref, s_ref, o_ref, acc_ref, *,
                  k_steps: int, n_mc: int, gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Bit-exact int8×int8→int32 on the float unit (see module docstring).
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # Scale-folded dequant straight into the fp32 logit stage: one
        # fused multiply-add per unit, then the standard per-HC softmax.
        s = (acc_ref[...].astype(jnp.float32) * s_ref[...] + b_ref[...]) * gain
        tb, tj = s.shape
        s = s.reshape(tb, tj // n_mc, n_mc)
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        out = e / jnp.sum(e, axis=-1, keepdims=True)
        o_ref[...] = out.reshape(tb, tj).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n_hc", "n_mc", "gain", "block_b", "block_j",
                     "block_k", "interpret"),
)
def quant_fwd_pallas(
    x: jax.Array,      # (B, Ni) fp32 rates
    w_q: jax.Array,    # (Ni, Nj) int8 codes
    bias: jax.Array,   # (Nj,) fp32
    scale: jax.Array,  # (Hj,) fp32 per-post-HC dequant scales
    n_hc: int,
    n_mc: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_j: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """int8 variant of ``bcpnn_fwd_pallas``: fused support matmul over
    int8 operands + per-HC softmax, dequantized in the epilogue.  Output
    is fp32 rates like the fp32 kernel."""
    _check_exact_block(block_k)
    b, ni = x.shape
    nj = w_q.shape[1]
    assert nj == n_hc * n_mc
    assert w_q.dtype == jnp.int8
    bs = pad_spec(b, block_b, INT8_SUBLANE)
    ks = pad_spec(ni, block_k, LANE if ni >= LANE else INT8_SUBLANE)
    js = pad_hc_spec(n_hc, n_mc, block_j)
    xq = quantize_acts(x)
    xp = pad_axis(pad_axis(xq, 1, ks.pad), 0, bs.pad)
    wp = pad_hc_axis(pad_axis(w_q, 0, ks.pad), 1, js)
    bp = pad_hc_axis(bias.reshape(1, nj), 1, js, value=NEG)
    # Per-unit dequant row: scale[j]·(1/127) broadcast over each HC's
    # padded lanes (pad HCs get a harmless 1 — their NEG bias keeps them
    # inert through the softmax regardless).
    srow = jnp.broadcast_to((scale * ACT_SCALE)[:, None],
                            (n_hc, js.mc_padded)).reshape(1, -1)
    sp = pad_axis(srow.reshape(1, n_hc, js.mc_padded), 1,
                  js.hc.pad, value=1.0).reshape(1, js.padded_units)
    grid = (bs.grid, js.grid, ks.grid)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, k_steps=ks.grid,
                          n_mc=js.mc_padded, gain=gain),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs.block, ks.block), lambda i, j, k: (i, k)),
            pl.BlockSpec((ks.block, js.block_units), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, js.block_units), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, js.block_units), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bs.block, js.block_units),
                               lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bs.padded, js.padded_units), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, js.block_units), jnp.int32)],
        interpret=interpret,
    )(xp, wp, bp, sp)
    return unpad_hc_axis(out[:b], 1, js)


# -------------------------------------------- compact-patchy int8 kernel ----

def _quant_patchy_kernel(xg_ref, wg_ref, b_ref, s_ref, o_ref, acc_ref, *,
                         k_steps: int, gain: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        xg_ref[0].astype(jnp.float32),
        wg_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        # One post-HC per tile: dequant by its scalar scale, bias, softmax
        # over the whole (padded) lane.
        s = (acc_ref[...].astype(jnp.float32) * s_ref[0] + b_ref[0]) * gain
        s = s - jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s)
        o_ref[0] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _gather_pre_q(xq: jax.Array, ui: jax.Array, b_pad: int) -> jax.Array:
    """int8 codes (B, Ni) -> compact (Hj, B+b_pad, Kp), zero-code pads."""
    xg = jnp.take(xq, ui, axis=1, mode="fill",
                  fill_value=0)                          # (B, Hj, Kp)
    return pad_axis(xg, 0, b_pad).transpose(1, 0, 2)


@functools.partial(
    jax.jit,
    static_argnames=("mi", "gain", "block_b", "block_k", "interpret"),
)
def quant_compact_forward(
    x: jax.Array,      # (B, Ni) fp32 rates
    w_q: jax.Array,    # (Hj, K, Mj) int8 compact-resident codes
    bias: jax.Array,   # (Hj*Mj,) fp32
    scale: jax.Array,  # (Hj,) fp32
    table: jax.Array,  # (Hj, nact)
    mi: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """int8 variant of ``compact_forward``: the activation gather runs on
    1-byte codes (4× less gather traffic than fp32), the resident weights
    stream as int8, and the per-HC scale dequantizes in the epilogue."""
    _check_exact_block(block_k)
    b, ni = x.shape
    hj, k_units, mj = w_q.shape
    assert w_q.dtype == jnp.int8
    bs = pad_spec(b, block_b, INT8_SUBLANE)
    ks = pad_spec(k_units, block_k,
                  LANE if k_units >= LANE else INT8_SUBLANE)
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, ks.pad, sentinel=ni)
    xg = _gather_pre_q(quantize_acts(x), ui, bs.pad)       # (Hj, Bp, Kp) i8
    wg = pad_axis(pad_axis(w_q, 1, ks.pad), 2, mp - mj)    # (Hj, Kp, Mp) i8
    bg = pad_axis(bias.reshape(hj, 1, mj), 2, mp - mj, value=NEG)
    sg = jnp.broadcast_to((scale * ACT_SCALE)[:, None, None], (hj, 1, mp))
    out = pl.pallas_call(
        functools.partial(_quant_patchy_kernel, k_steps=ks.grid, gain=gain),
        grid=(hj, bs.grid, ks.grid),
        in_specs=[
            pl.BlockSpec((1, bs.block, ks.block), lambda h, i, k: (h, i, k)),
            pl.BlockSpec((1, ks.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs.block, mp), lambda h, i, k: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hj, bs.padded, mp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, mp), jnp.int32)],
        interpret=interpret,
    )(xg, wg, bg, sg)
    return out[:, :b, :mj].transpose(1, 0, 2).reshape(b, hj * mj)


@functools.partial(
    jax.jit,
    static_argnames=("mi", "hj", "mj", "gain", "block_b", "block_k",
                     "interpret"),
)
def quant_patchy_forward(
    x: jax.Array,      # (B, Ni)
    w_q: jax.Array,    # (Ni, Hj*Mj) int8 masked dense codes
    bias: jax.Array,   # (Hj*Mj,)
    scale: jax.Array,  # (Hj,)
    table: jax.Array,  # (Hj, nact)
    mi: int,
    hj: int,
    mj: int,
    gain: float = 1.0,
    block_b: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """int8 patchy forward over DENSE-resident codes: gather the live
    pre-blocks of the int8 weight matrix per call (masked-out weights are
    exactly code 0, so the gather is exact) and run the compact int8
    kernel.  The dense-resident tier's analogue of ``patchy_forward``."""
    _check_exact_block(block_k)
    b, ni = x.shape
    k_units = table.shape[1] * mi
    bs = pad_spec(b, block_b, INT8_SUBLANE)
    ks = pad_spec(k_units, block_k,
                  LANE if k_units >= LANE else INT8_SUBLANE)
    mp = pad_mc(mj)
    ui = unit_indices(table, mi, ks.pad, sentinel=ni)
    xg = _gather_pre_q(quantize_acts(x), ui, bs.pad)
    w3 = w_q.reshape(ni, hj, mj)
    take = lambda idx, col: jnp.take(col, idx, axis=0, mode="fill",
                                     fill_value=0)
    wg = pad_axis(jax.vmap(take, in_axes=(0, 1))(ui, w3), 2, mp - mj)
    bg = pad_axis(bias.reshape(hj, 1, mj), 2, mp - mj, value=NEG)
    sg = jnp.broadcast_to((scale * ACT_SCALE)[:, None, None], (hj, 1, mp))
    out = pl.pallas_call(
        functools.partial(_quant_patchy_kernel, k_steps=ks.grid, gain=gain),
        grid=(hj, bs.grid, ks.grid),
        in_specs=[
            pl.BlockSpec((1, bs.block, ks.block), lambda h, i, k: (h, i, k)),
            pl.BlockSpec((1, ks.block, mp), lambda h, i, k: (h, k, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda h, i, k: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs.block, mp), lambda h, i, k: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hj, bs.padded, mp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bs.block, mp), jnp.int32)],
        interpret=interpret,
    )(xg, wg, bg, sg)
    return out[:, :b, :mj].transpose(1, 0, 2).reshape(b, hj * mj)
