"""Block-size fitting for the Pallas kernels.

The kernels tile (batch, pre, post) with default MXU-friendly blocks, but
real BCPNN geometries are rarely powers of two (e.g. Model 1's pre side is
28*28*2 = 1568 units).  Rather than asserting divisibility, each wrapper
fits its requested block down to the largest divisor of the dimension —
degrading tile efficiency, never correctness.  A badly-aligned fit (not a
multiple of the 8-sublane f32 tile) is warned about once per site: it
works under the CPU interpreter but may not compile, or will run
pathologically, on the Mosaic TPU target — pad the dimension instead.
"""
from __future__ import annotations

import warnings

# Misalignment warnings already issued, keyed on (dim, fitted block).
# ``warnings.warn`` alone fires on every trace — an epoch sweep re-traces
# per shape and would spam one warning per jit — so dedupe here and warn
# truly once per site.
_warned_fits: set = set()


def fit_block(dim: int, block: int) -> int:
    """Largest divisor of ``dim`` that is <= ``block`` (>= 1)."""
    requested = block
    block = max(1, min(block, dim))
    while dim % block:
        block -= 1
    # Tiny toy geometries (tests, examples) are inherently unaligned and
    # only ever run interpreted; warn at sizes someone would put on a TPU.
    if dim >= 64 and block % 8 != 0 and (dim, block) not in _warned_fits:
        _warned_fits.add((dim, block))
        warnings.warn(
            f"Pallas block for dimension {dim} fitted to {block} "
            f"(requested {requested}), which is not 8-sublane aligned; "
            f"fine in interpret mode, but pad the dimension for TPU",
            stacklevel=2)
    return block


def fit_hc_block(n_hc: int, n_mc: int, block_units: int) -> int:
    """Fit a unit-count block for a hypercolumnar axis of n_hc * n_mc
    units: a multiple of n_mc (HCs stay whole, so softmax is block-local)
    that divides the total unit count."""
    return n_mc * fit_block(n_hc, max(1, block_units // n_mc))
