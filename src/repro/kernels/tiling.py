"""Pad-to-aligned tiling plans for the Pallas kernels.

Real BCPNN geometries are rarely powers of two (Model 1's pre side is
28*28*2 = 1568 units; readouts have 2 or 10 classes), and the f32 Mosaic
tile is (8 sublanes x 128 lanes).  Instead of fitting blocks down to
divisors of the raw dimension — which degrades misaligned geometries to
size-1 tiles — every grid axis is planned here as *pad up to an aligned
block*: ``pad_spec`` picks a block that is a multiple of the hardware
tile and rounds the dimension up to a multiple of that block, minimizing
the padding.  The kernel wrappers pad their operands with inert values
(zeros into matmul contractions and trace EMAs, ``NEG`` into softmax
lanes) and slice the outputs back, so padding never changes results —
see DESIGN.md §7 for the pad-semantics table.

Hypercolumnar axes get ``pad_hc_spec``: minicolumn counts are padded to
lane-friendly sizes (``pad_mc``) and hypercolumns stay whole within a
block, so per-HC softmax remains block-local.
"""
from __future__ import annotations

import dataclasses
import math

SUBLANE = 8    # f32 sublane tile (second-to-last block dim)
LANE = 128     # lane tile (last block dim)

# Inert softmax pad: finite (no inf-inf NaNs even in all-pad lanes) but
# exp(NEG - max) underflows to exactly 0.0 in both f32 and bf16, so pad
# lanes contribute nothing to real softmax sums.
NEG = -1e30  # repro: suppress[pad-fill-literal] — this IS the canonical fill


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def pow2_ge(x: int) -> int:
    return 1 << max(0, (x - 1).bit_length())


def lane_multiple(dim: int) -> int:
    """Alignment target for a lane (last) block dim: full 128-lane tiles
    when the dimension supports them, 8 otherwise (small arrays are padded
    internally by Mosaic; sublane alignment still matters)."""
    return LANE if dim >= LANE else SUBLANE


@dataclasses.dataclass(frozen=True)
class PadSpec:
    """Padding plan for one grid axis: ``block`` is a multiple of the
    requested alignment and divides ``padded`` exactly."""

    dim: int      # logical size
    padded: int   # padded size the kernel runs on
    block: int    # fitted, aligned block

    @property
    def pad(self) -> int:
        return self.padded - self.dim

    @property
    def grid(self) -> int:
        return self.padded // self.block


def pad_spec(dim: int, block: int, multiple: int = SUBLANE) -> PadSpec:
    """Plan an axis: among blocks that are multiples of ``multiple`` and at
    most the requested ``block``, pick the one minimizing the padded size
    (tie broken toward the larger block, i.e. the shorter grid)."""
    if dim <= 0:
        raise ValueError(f"dimension must be positive, got {dim}")
    cap = max(multiple, min(round_up(block, multiple), round_up(dim, multiple)))
    best = None
    for cand in range(cap, 0, -multiple):
        padded = round_up(dim, cand)
        if best is None or padded < best.padded:
            best = PadSpec(dim, padded, cand)
    return best


def pad_mc(n_mc: int) -> int:
    """Lane-friendly padded minicolumn count: the next power of two below
    128 (so whole lane tiles hold 128/m_p HCs exactly), whole lane
    multiples above."""
    if n_mc >= LANE:
        return round_up(n_mc, LANE)
    return pow2_ge(n_mc)


@dataclasses.dataclass(frozen=True)
class HCPadSpec:
    """Padding plan for a hypercolumnar unit axis of ``n_hc * n_mc`` units:
    minicolumns pad to ``mc_padded`` per HC, hypercolumns pad per ``hc``,
    and every block holds ``hc.block`` whole HCs (softmax stays
    block-local)."""

    n_hc: int
    n_mc: int
    hc: PadSpec      # plan for the hypercolumn axis
    mc_padded: int   # padded minicolumns per hypercolumn

    @property
    def units(self) -> int:
        return self.n_hc * self.n_mc

    @property
    def padded_units(self) -> int:
        return self.hc.padded * self.mc_padded

    @property
    def block_units(self) -> int:
        return self.hc.block * self.mc_padded

    @property
    def grid(self) -> int:
        return self.hc.grid


def pad_hc_spec(n_hc: int, n_mc: int, block_units: int) -> HCPadSpec:
    """Plan a hypercolumnar axis targeting roughly ``block_units`` units
    per block.  The HC-count block is a multiple of ``128 / gcd(m_p, 128)``
    so each block's lane extent is a whole number of 128-lane tiles."""
    m_p = pad_mc(n_mc)
    hq = LANE // math.gcd(m_p, LANE)
    hc = pad_spec(n_hc, max(1, block_units // m_p), multiple=hq)
    return HCPadSpec(n_hc, n_mc, hc, m_p)

