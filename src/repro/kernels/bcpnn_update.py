"""Pallas TPU kernel: fused BCPNN plasticity stage.

One kernel performs, per (Ni, Nj) tile of the projection:

    co      = XᵀY / B                    (MXU, contraction over batch)
    p_ij'   = (1-α)·p_ij + α·co          (trace EMA)
    w       = (log p_ij' − log p_i − log p_j) · mask   (Bayesian weights)

On the FPGA these are three pipeline stages connected by FIFOs fed from
four partitioned HBM channels (paper Opt #3); here each (ti, tj) tile of
p_ij streams HBM→VMEM once and both outputs stream back once — the joint
trace and the weight matrix never make an extra HBM round-trip.

Grid = (Ni/ti, Nj/tj, B/tk) over the PADDED shapes, contraction
innermost.  Pad semantics (DESIGN.md §7): pad batch rows of x/y are zero,
so they add nothing to XᵀY, and the kernel divides by the REAL batch
size — the co-activation EMA is exact.  Pad rows/columns of pij and mask
are zero, producing inert outputs that are sliced off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .padding import pad_axis
from .tiling import SUBLANE, lane_multiple, pad_spec


def _kernel(x_ref, y_ref, pij_ref, lpi_ref, lpj_ref, mask_ref, alpha_ref,
            pij_out_ref, w_out_ref, acc_ref, *, k_steps: int, batch: int, eps: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # x block: (tk, ti) — pre-transposed so the MXU contracts the batch dim.
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32).T,
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        alpha = alpha_ref[0, 0]
        co = acc_ref[...] / batch
        new_pij = (1.0 - alpha) * pij_ref[...] + alpha * co
        pij_out_ref[...] = new_pij
        logp = jnp.log(jnp.clip(new_pij, eps * eps, 1.0))
        w = logp - (lpi_ref[...].T + lpj_ref[...])
        w_out_ref[...] = w * mask_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("eps", "block_i", "block_j", "block_k", "interpret"),
)
def bcpnn_update_pallas(
    pij: jax.Array,     # (Ni, Nj)
    log_pi: jax.Array,  # (Ni,) log of updated+clipped pre marginals
    log_pj: jax.Array,  # (Nj,)
    x: jax.Array,       # (B, Ni)
    y: jax.Array,       # (B, Nj)
    mask: jax.Array,    # (Ni, Nj)
    alpha: jax.Array,   # scalar
    eps: float = 1e-4,
    block_i: int = 512,
    block_j: int = 512,
    block_k: int = 128,
    interpret: bool = False,
):
    """Returns (new_pij, new_w) — see module docstring."""
    b, ni = x.shape
    nj = y.shape[1]
    # Ni is the lane dim of x blocks AND the sublane dim of pij/w blocks;
    # Nj is a lane dim throughout; the batch is sublane-only.
    is_ = pad_spec(ni, block_i, lane_multiple(ni))
    js = pad_spec(nj, block_j, lane_multiple(nj))
    ks = pad_spec(b, block_k, SUBLANE)
    xp = pad_axis(pad_axis(x, 1, is_.pad), 0, ks.pad)
    yp = pad_axis(pad_axis(y, 1, js.pad), 0, ks.pad)
    pijp = pad_axis(pad_axis(pij, 0, is_.pad), 1, js.pad)
    maskp = pad_axis(pad_axis(mask, 0, is_.pad), 1, js.pad)
    lpip = pad_axis(log_pi.reshape(1, ni), 1, is_.pad)
    lpjp = pad_axis(log_pj.reshape(1, nj), 1, js.pad)
    grid = (is_.grid, js.grid, ks.grid)
    kern = functools.partial(_kernel, k_steps=ks.grid, batch=b, eps=eps)
    new_pij, w = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ks.block, is_.block), lambda i, j, k: (k, i)),   # x
            pl.BlockSpec((ks.block, js.block), lambda i, j, k: (k, j)),    # y
            pl.BlockSpec((is_.block, js.block), lambda i, j, k: (i, j)),   # pij
            pl.BlockSpec((1, is_.block), lambda i, j, k: (0, i)),          # log_pi
            pl.BlockSpec((1, js.block), lambda i, j, k: (0, j)),           # log_pj
            pl.BlockSpec((is_.block, js.block), lambda i, j, k: (i, j)),   # mask
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),                  # alpha
        ],
        out_specs=[
            pl.BlockSpec((is_.block, js.block), lambda i, j, k: (i, j)),
            pl.BlockSpec((is_.block, js.block), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((is_.padded, js.padded), jnp.float32),
            jax.ShapeDtypeStruct((is_.padded, js.padded), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((is_.block, js.block), jnp.float32)],
        interpret=interpret,
    )(xp, yp, pijp, lpip, lpjp, maskp,
      alpha.reshape(1, 1).astype(jnp.float32))
    return new_pij[:ni, :nj], w[:ni, :nj]
