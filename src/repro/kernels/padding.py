"""Operand padding/unpadding for the pad-to-aligned kernel wrappers.

Implements the inert-pad semantics of DESIGN.md §7 on jnp arrays: plain
axes pad with zeros (inert in matmul contractions and trace EMAs),
hypercolumnar unit axes pad *within* each HC (``mc_padded`` lanes) and
then with whole pad-HCs, using ``tiling.NEG`` where the axis feeds a
softmax so pad lanes underflow to zero probability.  All helpers are
no-ops when the plan requires no padding, so aligned geometries trace to
the exact same graphs as before.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tiling import HCPadSpec


def pad_axis(x: jax.Array, axis: int, pad: int, value: float = 0.0) -> jax.Array:
    """Pad one axis of ``x`` at the end with ``pad`` entries of ``value``."""
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis % x.ndim] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pad_hc_axis(x: jax.Array, axis: int, hs: HCPadSpec,
                value: float = 0.0) -> jax.Array:
    """Pad a hypercolumnar unit axis (``n_hc * n_mc`` entries) to the
    planned ``hc.padded * mc_padded`` layout: ``value`` fills both the
    extra minicolumn lanes inside each real HC and the whole pad-HCs."""
    if hs.mc_padded == hs.n_mc and hs.hc.pad == 0:
        return x
    axis = axis % x.ndim
    pre, post = x.shape[:axis], x.shape[axis + 1:]
    x = x.reshape(pre + (hs.n_hc, hs.n_mc) + post)
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, hs.hc.pad)
    widths[axis + 1] = (0, hs.mc_padded - hs.n_mc)
    x = jnp.pad(x, widths, constant_values=value)
    return x.reshape(pre + (hs.padded_units,) + post)


def unpad_hc_axis(x: jax.Array, axis: int, hs: HCPadSpec) -> jax.Array:
    """Slice a padded hypercolumnar unit axis back to its logical size."""
    if hs.mc_padded == hs.n_mc and hs.hc.pad == 0:
        return x
    axis = axis % x.ndim
    pre, post = x.shape[:axis], x.shape[axis + 1:]
    x = x.reshape(pre + (hs.hc.padded, hs.mc_padded) + post)
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, hs.n_hc)
    idx[axis + 1] = slice(0, hs.n_mc)
    x = x[tuple(idx)]
    return x.reshape(pre + (hs.units,) + post)
