"""Operand padding/unpadding for the pad-to-aligned kernel wrappers.

Implements the inert-pad semantics of DESIGN.md §7 on jnp arrays: plain
axes pad with zeros (inert in matmul contractions and trace EMAs),
hypercolumnar unit axes pad *within* each HC (``mc_padded`` lanes) and
then with whole pad-HCs, using ``tiling.NEG`` where the axis feeds a
softmax so pad lanes underflow to zero probability.  All helpers are
no-ops when the plan requires no padding, so aligned geometries trace to
the exact same graphs as before.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tiling import HCPadSpec


def clamp_fill(value: float, dtype) -> float:
    """Clamp a pad fill to the target dtype's finite range.

    The softmax sentinel ``tiling.NEG`` (-1e30) is chosen for f32/bf16; a
    narrower float (the planned bf16/f16 cast-on-fold serving mode,
    ROADMAP §bf16) would overflow it to -inf on cast — and an all-pad HC
    then computes ``-inf - max(-inf) = NaN`` inside the softmax.
    ``finfo(dtype).min`` keeps the fill finite (exp still underflows to
    exactly 0, so pad lanes stay inert) and NaN-free for every dtype.
    """
    dtype = jnp.dtype(dtype)
    if not jnp.issubdtype(dtype, jnp.floating):
        return value
    info = jnp.finfo(dtype)
    return float(min(max(value, float(info.min)), float(info.max)))


def pad_axis(x: jax.Array, axis: int, pad: int, value: float = 0.0) -> jax.Array:
    """Pad one axis of ``x`` at the end with ``pad`` entries of ``value``
    (clamped to the dtype's finite range — see ``clamp_fill``)."""
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis % x.ndim] = (0, pad)
    return jnp.pad(x, widths, constant_values=clamp_fill(value, x.dtype))


def pad_hc_axis(x: jax.Array, axis: int, hs: HCPadSpec,
                value: float = 0.0) -> jax.Array:
    """Pad a hypercolumnar unit axis (``n_hc * n_mc`` entries) to the
    planned ``hc.padded * mc_padded`` layout: ``value`` fills both the
    extra minicolumn lanes inside each real HC and the whole pad-HCs."""
    if hs.mc_padded == hs.n_mc and hs.hc.pad == 0:
        return x
    axis = axis % x.ndim
    pre, post = x.shape[:axis], x.shape[axis + 1:]
    x = x.reshape(pre + (hs.n_hc, hs.n_mc) + post)
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, hs.hc.pad)
    widths[axis + 1] = (0, hs.mc_padded - hs.n_mc)
    x = jnp.pad(x, widths, constant_values=clamp_fill(value, x.dtype))
    return x.reshape(pre + (hs.padded_units,) + post)


def unpad_hc_axis(x: jax.Array, axis: int, hs: HCPadSpec) -> jax.Array:
    """Slice a padded hypercolumnar unit axis back to its logical size."""
    if hs.mc_padded == hs.n_mc and hs.hc.pad == 0:
        return x
    axis = axis % x.ndim
    pre, post = x.shape[:axis], x.shape[axis + 1:]
    x = x.reshape(pre + (hs.hc.padded, hs.mc_padded) + post)
    idx = [slice(None)] * x.ndim
    idx[axis] = slice(0, hs.n_hc)
    idx[axis + 1] = slice(0, hs.n_mc)
    x = x[tuple(idx)]
    return x.reshape(pre + (hs.units,) + post)
