"""AdamW with decoupled weight decay, global-norm clipping and schedules.

Self-contained (no optax in this environment).  Optimizer state is a
pytree shaped like the params, so it inherits the params' FSDP sharding —
this IS the ZeRO partitioning of optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, 1.0) * jnp.where(s < cfg.warmup_steps, 1.0, cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> Tuple[Any, dict]:
    """One AdamW step.  Gradients may arrive in bf16; moments are f32."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias excluded)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
