"""Int8 gradient compression with error feedback.

Before the data-parallel all-reduce, gradients are quantized to int8 with
a per-tensor scale; the quantization error is carried to the next step
(error feedback), which keeps SGD/Adam convergence unbiased in practice.
At 1000+ node scale this cuts DP gradient traffic 4x (bf16->int8 would be
2x; we quantize from the f32 grads, 4x) at the cost of two cheap
elementwise passes.

Note on mechanics: under GSPMD the all-reduce is implicit (gradients of
FSDP-sharded params come out of autodiff already reduce-scattered), so we
expose compression as a *gradient transform* applied inside the train
step: quantize -> dequantize with error feedback.  The wire-format win is
realized when the transform is placed around an explicit shard_map psum
(see launch/train.py --compress-grads); the transform itself is identical.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as seen by the optimizer, new error)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize(gf)
        deq = dequantize(q, s)
        return deq, gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
