from .adamw import AdamWConfig, apply_updates, global_norm, init_opt_state, schedule
from .compression import compress_grads, init_error_state
