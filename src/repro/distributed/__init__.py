from .sharding import (DEFAULT_RULES, data_shards, make_rules, named_sharding,
                       projection_shardings, set_context, shard,
                       sharding_context, spec_for)
from .data_parallel import (make_data_parallel_projection_epoch,
                            make_data_parallel_supervised_epoch,
                            make_data_parallel_supervised_step,
                            make_data_parallel_unsupervised_step)
from .fault import (StepTimer, WorkerLost, describe_failure_domains,
                    elastic_mesh, fit_mesh_shape, order_devices_host_major)
