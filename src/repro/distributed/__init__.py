from .sharding import (DEFAULT_RULES, data_shards, make_rules, named_sharding,
                       set_context, shard, sharding_context, spec_for)
from .fault import StepTimer, describe_failure_domains, elastic_mesh
