"""shard_map data-parallel streaming train steps with an EXACT trace
all-reduce (StreamBrain-style multi-device BCPNN, DESIGN.md §7).

Why this is exact — and exact *to the bit*, not just in exact arithmetic:
batch-mean co-activation traces are linear, so per-device partial traces
sum to the true global trace (the StreamBrain observation, PAPERS.md).
But a batch-SPLIT decomposition (each device contracting its own rows,
then psum) reassociates the f32 reduction — partial1 + partial2 is not
bit-identical to the single-device gemm's accumulation order.  We instead
decompose over POST COLUMNS: the global batch of activations is
all-gathered (O(B·(Ni+Nj)) traffic — tiny next to the O(Ni·Nj) trace
matrices), and each device contracts the FULL batch against its own
post-HC column block.  Every output element is then produced by exactly
one device with the same per-element contraction order as the
single-device gemm, so the trace all-reduce — a ``psum`` of
disjoint-support partials — is a sum of one real value and zeros per
element: exact to the bit.  The forward pass is sharded the same way
(column slices of the support matmul, per-HC softmax block-local), and
exploration noise is generated from the replicated key at full batch
shape and column-sliced, so the whole step reproduces the single-device
``unsupervised_layer_step`` / ``supervised_readout_step`` bit-for-bit.
``tests/test_distributed.py`` asserts exactly that on a ≥2-device CPU
mesh, for dense and compact-resident projections.

Compact-resident projections (``ProjSpec.compact``) shard along the
leading post-HC axis of their (Hj, K, Mj) leaves, which shrinks the
all-reduced partials by the same nact/Hi factor as the resident state —
the distributed win of the compact layout.

Scope: the steps run the jnp reference compute path regardless of
``ProjSpec.backend`` (the fused Pallas kernels tile their grids in ways
that reassociate accumulation, so a kernel-fused DP step is a TPU
follow-up — see ROADMAP).  The readout projection (a single output HC)
replicates its tiny learn instead of sharding it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..core.bcpnn_layer import (
    Projection,
    ProjSpec,
    apply_dense_stats,
    is_compact,
    masked_inputs,
)
from ..core.compact import apply_compact_stats, compact_co_stats, compact_support
from ..core.hypercolumns import LayerGeom, hc_softmax
from ..core.network import DeepState, NetworkSpec


def _check_geometry(spec: NetworkSpec, layer: int, n_shards: int) -> None:
    """The column decomposition needs whole HCs per shard on every
    projection the step touches (readout excluded — it replicates)."""
    for l in range(layer + 1):
        h = spec.projs[l].post.H
        if h % n_shards != 0:
            raise ValueError(
                f"data-parallel step: stack projection {l} has {h} post-HCs,"
                f" not divisible by the {n_shards}-way data axis — the "
                f"column-sharded decomposition needs whole HCs per shard")


def _axis_offset(axis: str, size: int):
    return jax.lax.axis_index(axis) * size


def _support_cols(proj: Projection, pspec: ProjSpec, xf: jax.Array,
                  axis: str, n_shards: int) -> jax.Array:
    """This device's post-column slice of the log-domain support, computed
    with the FULL-batch contraction (bit-identical to the same columns of
    the single-device support)."""
    if is_compact(pspec) and proj.table is not None:
        hj_l = pspec.post.H // n_shards
        off = _axis_offset(axis, hj_l)
        tbl = jax.lax.dynamic_slice_in_dim(proj.table, off, hj_l, 0)
        w_l = jax.lax.dynamic_slice_in_dim(proj.w, off, hj_l, 0)
        b_l = jax.lax.dynamic_slice_in_dim(proj.b, off * pspec.post.M,
                                           hj_l * pspec.post.M, 0)
        # the canonical contraction on sliced leaves — sharing the helper
        # keeps the single-device/DP identical-arithmetic guarantee
        # structural
        return compact_support(xf, w_l, b_l, tbl, pspec.pre.M)
    nj_l = pspec.post.N // n_shards
    off = _axis_offset(axis, nj_l)
    w_l = jax.lax.dynamic_slice_in_dim(proj.w, off, nj_l, 1)
    b_l = jax.lax.dynamic_slice_in_dim(proj.b, off, nj_l, 0)
    return b_l[None, :] + xf @ w_l


def _softmax_cols(s_l: jax.Array, pspec: ProjSpec, n_shards: int) -> jax.Array:
    """Per-HC softmax on a whole-HC column slice: block-local, so it is
    per-element identical to the same columns of the full softmax."""
    geom_l = LayerGeom(pspec.post.H // n_shards, pspec.post.M)
    return hc_softmax(s_l, geom_l, pspec.gain)


def _gather_cols(y_l: jax.Array, axis: str) -> jax.Array:
    return jax.lax.all_gather(y_l, axis, axis=1, tiled=True)


def _forward_cols(proj: Projection, pspec: ProjSpec, xf: jax.Array,
                  axis: str, n_shards: int) -> jax.Array:
    """Full post rates via column-sharded forward + gather."""
    return _gather_cols(_softmax_cols(
        _support_cols(proj, pspec, xf, axis, n_shards), pspec, n_shards),
        axis)


def _co_allreduce_dense(xf: jax.Array, y_l: jax.Array, nj: int, axis: str,
                        n_shards: int) -> jax.Array:
    """Disjoint-support trace all-reduce, dense layout: this device's
    full-batch column gemm scattered into zeros, psum'd.  Each element of
    the result is one real partial plus zeros — bit-exact."""
    xf, y_l = jax.lax.optimization_barrier((xf, y_l))
    part = xf.T @ y_l                                  # (Ni, Nj/n_shards)
    off = _axis_offset(axis, nj // n_shards)
    padded = jax.lax.dynamic_update_slice(
        jnp.zeros((xf.shape[1], nj), part.dtype), part, (0, off))
    return jax.lax.psum(padded, axis)


def _co_allreduce_compact(xf: jax.Array, y_l: jax.Array, proj: Projection,
                          pspec: ProjSpec, axis: str, n_shards: int,
                          n_valid=None) -> jax.Array:
    """Disjoint-support trace all-reduce, compact layout: partials are
    (Hj/n_shards, K, Mj) — nact/Hi smaller than the dense all-reduce.
    The partial is the canonical ``compact_co_stats`` contraction on this
    device's table rows and post columns (already batch-mean, or
    real-row-mean when ``n_valid`` is given), so the reduced result is
    bit-identical to the single-device stat."""
    hj, k_units, mj = proj.traces.pij.shape
    hj_l = hj // n_shards
    off = _axis_offset(axis, hj_l)
    tbl = jax.lax.dynamic_slice_in_dim(proj.table, off, hj_l, 0)
    part = compact_co_stats(xf, y_l, tbl, pspec.pre.M, mj, n_valid=n_valid)
    padded = jax.lax.dynamic_update_slice(
        jnp.zeros((hj, k_units, mj), part.dtype), part, (off, 0, 0))
    return jax.lax.psum(padded, axis)


def _learn_sharded(proj: Projection, pspec: ProjSpec, xf: jax.Array,
                   yf: jax.Array, y_l: jax.Array, axis: str,
                   n_shards: int, valid=None) -> Projection:
    """One plasticity step from all-reduced stats — the replicated EMA +
    fold applies the identical ops as the single-device jnp learn.

    ``valid`` (optional, (B,) 0/1, replicated) is the zero-padded
    tail-batch mask: it mirrors ``core.bcpnn_layer.learn_masked`` —
    pad rows are zeroed before any stat, and every divisor is the real
    row count.  The column slice of the masked full activations equals
    the masked column slice elementwise, so the disjoint-support
    all-reduce stays bit-exact against the single-device masked learn."""
    if valid is None:
        b = xf.shape[0]
        xf, yf = jax.lax.optimization_barrier((xf, yf))
        xm = jnp.mean(xf, axis=0)
        ym = jnp.mean(yf, axis=0)
        if is_compact(pspec) and proj.table is not None:
            # already batch-mean: compact_co_stats divides inside the partial
            co_c = _co_allreduce_compact(xf, y_l, proj, pspec, axis, n_shards)
            return apply_compact_stats(proj, pspec, xm, ym, co_c)
        co = _co_allreduce_dense(xf, y_l, pspec.post.N, axis, n_shards) / b
        return apply_dense_stats(proj, pspec, xm, ym, co)
    xv, yv, n = masked_inputs(xf, yf, valid)
    v = valid.astype(y_l.dtype)
    yv_l = y_l * v[:, None]
    xv, yv, yv_l = jax.lax.optimization_barrier((xv, yv, yv_l))
    xm = jnp.sum(xv, axis=0) / n
    ym = jnp.sum(yv, axis=0) / n
    if is_compact(pspec) and proj.table is not None:
        co_c = _co_allreduce_compact(xv, yv_l, proj, pspec, axis, n_shards,
                                     n_valid=n)
        return apply_compact_stats(proj, pspec, xm, ym, co_c)
    co = _co_allreduce_dense(xv, yv_l, pspec.post.N, axis, n_shards) / n
    return apply_dense_stats(proj, pspec, xm, ym, co)


def _learn_replicated(proj: Projection, pspec: ProjSpec, xf: jax.Array,
                      yf: jax.Array, valid=None) -> Projection:
    """Tiny projections (the single-HC readout) learn replicated: every
    device runs the identical full gemm — trivially bit-exact."""
    from ..core.bcpnn_layer import _learn_jnp, learn_masked
    if valid is not None:
        return learn_masked(proj, pspec, xf, yf, valid)
    return _learn_jnp(proj, pspec, xf, yf)


def _train_projection_body(state: DeepState, spec: NetworkSpec, layer: int,
                           h: jax.Array, axis: str, n_shards: int,
                           valid=None) -> DeepState:
    """The column-sharded equivalent of
    ``core.network.train_projection_step`` on the layer's DIRECT input
    rates ``h`` (full batch, replicated) — shared by the per-batch step
    factory (which derives ``h`` from row-sharded input via the frozen
    column forwards) and the scan-over-batches epoch factories, so both
    compile the identical barrier-pinned arithmetic."""
    pspec = spec.projs[layer]
    proj = state.projs[layer]
    key, sub = jax.random.split(state.key)
    s_l = _support_cols(proj, pspec, h, axis, n_shards)
    t = proj.traces.t.astype(jnp.float32)
    amp = pspec.support_noise * jnp.maximum(
        0.0, 1.0 - t / max(1, pspec.noise_steps))
    # Mirror _noisy_rates' pins: one materialized noise buffer, pinned
    # scaled product — the column slice then adds the same bits.
    noise = jax.lax.optimization_barrier(jax.random.normal(
        sub, (h.shape[0], pspec.post.N), s_l.dtype))
    nj_l = pspec.post.N // n_shards
    noise_l = jax.lax.dynamic_slice_in_dim(
        noise, _axis_offset(axis, nj_l), nj_l, 1)
    y_l = _softmax_cols(
        s_l + jax.lax.optimization_barrier(amp * noise_l), pspec,
        n_shards)
    yf = _gather_cols(y_l, axis)
    proj = _learn_sharded(proj, pspec, h, yf, y_l, axis, n_shards,
                          valid=valid)
    if pspec.struct_every > 0:
        from ..core.bcpnn_layer import rewire
        proj = jax.lax.cond(
            proj.traces.t % pspec.struct_every == 0,
            lambda p: rewire(p, pspec), lambda p: p, proj)
    projs = state.projs[:layer] + (proj,) + state.projs[layer + 1:]
    return DeepState(projs=projs, readout=state.readout,
                     step=state.step + 1, key=key)


def _supervised_body(state: DeepState, spec: NetworkSpec, xf: jax.Array,
                     labels: jax.Array, axis: str, n_shards: int,
                     valid=None) -> DeepState:
    """Column-sharded frozen stack forward + replicated readout learn on
    full-batch inputs — shared by the supervised step and epoch."""
    h = xf
    for l in range(spec.depth):
        h = _forward_cols(state.projs[l], spec.projs[l], h, axis, n_shards)
    y = jax.nn.one_hot(labels, spec.n_classes, dtype=h.dtype)
    ro = _learn_replicated(state.readout, spec.readout, h, y, valid=valid)
    return DeepState(projs=state.projs, readout=ro,
                     step=state.step + 1, key=state.key)


def make_data_parallel_unsupervised_step(spec: NetworkSpec, mesh: Mesh,
                                         layer: int = 0, axis: str = "data"):
    """Build the jitted shard_map equivalent of
    ``core.network.unsupervised_layer_step`` for a data mesh.

    Inputs: ``state`` replicated, ``x`` (B, Ni) sharded over rows on
    ``axis`` (B divisible by the axis size).  Output state is replicated
    and matches the single-device step bit-for-bit.
    """
    n_shards = mesh.shape[axis]
    _check_geometry(spec, layer, n_shards)

    def step(state: DeepState, x_l: jax.Array) -> DeepState:
        xf = jax.lax.all_gather(x_l, axis, tiled=True)
        h = xf
        for l in range(layer):
            h = _forward_cols(state.projs[l], spec.projs[l], h, axis,
                              n_shards)
        return _train_projection_body(state, spec, layer, h, axis, n_shards)

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P(), P(axis)), out_specs=P(),
        check_rep=False))


def make_data_parallel_supervised_step(spec: NetworkSpec, mesh: Mesh,
                                       axis: str = "data"):
    """Build the jitted shard_map equivalent of
    ``core.network.supervised_readout_step``: column-sharded frozen stack
    forward, replicated readout learn (one output HC — nothing to shard).
    ``labels`` (B,) int32, sharded over ``axis`` like ``x``."""
    n_shards = mesh.shape[axis]
    _check_geometry(spec, spec.depth - 1, n_shards)

    def step(state: DeepState, x_l: jax.Array,
             labels_l: jax.Array) -> DeepState:
        xf = jax.lax.all_gather(x_l, axis, tiled=True)
        labels = jax.lax.all_gather(labels_l, axis, tiled=True)
        return _supervised_body(state, spec, xf, labels, axis, n_shards)

    return jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P(), P(axis), P(axis)), out_specs=P(),
        check_rep=False))


# ------------------------------------------- scan-over-batches epochs ----

def make_data_parallel_projection_epoch(spec: NetworkSpec, mesh: Mesh,
                                        layer: int = 0, axis: str = "data",
                                        masked: bool = False):
    """Build the jitted shard_map equivalent of
    ``core.trainer._train_projection_epoch``: one ``lax.scan`` over
    batch-major PRECOMPUTED layer-input rates ``hs`` (nb, B, N_layer),
    sharded over batch rows on ``axis`` — a whole greedy-phase epoch is
    one device program, like the single-device trainer.

    With ``masked=True`` the epoch takes an extra ``valid`` (nb, B) 0/1
    operand (replicated — every device needs the full-batch mask because
    stats contract the full gathered batch) and runs the real-row-count
    masked learn on every batch; the trainer passes it only when the
    data actually has a padded tail.  Per-step arithmetic is the same
    barrier-pinned body as ``make_data_parallel_unsupervised_step``, so
    the epoch is bit-for-bit equal to the single-device epoch program.
    """
    n_shards = mesh.shape[axis]
    _check_geometry(spec, layer, n_shards)

    if masked:
        def epoch(state: DeepState, hs_l: jax.Array,
                  valid: jax.Array) -> DeepState:
            def body(st, hv):
                h_l, v = hv
                hf = jax.lax.all_gather(h_l, axis, tiled=True)
                return _train_projection_body(
                    st, spec, layer, hf, axis, n_shards, valid=v), None
            state, _ = jax.lax.scan(body, state, (hs_l, valid))
            return state

        in_specs = (P(), P(None, axis), P())
    else:
        def epoch(state: DeepState, hs_l: jax.Array) -> DeepState:
            def body(st, h_l):
                hf = jax.lax.all_gather(h_l, axis, tiled=True)
                return _train_projection_body(
                    st, spec, layer, hf, axis, n_shards), None
            state, _ = jax.lax.scan(body, state, hs_l)
            return state

        in_specs = (P(), P(None, axis))

    return jax.jit(shard_map(
        epoch, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False))


def make_data_parallel_supervised_epoch(spec: NetworkSpec, mesh: Mesh,
                                        axis: str = "data",
                                        masked: bool = False):
    """Build the jitted shard_map equivalent of
    ``core.trainer._supervised_epoch``: one scan over batch-major
    ``(xs, ys)`` (row-sharded on ``axis``), plus a replicated ``valid``
    operand when ``masked``."""
    n_shards = mesh.shape[axis]
    _check_geometry(spec, spec.depth - 1, n_shards)

    def _step(st, x_l, labels_l, v):
        xf = jax.lax.all_gather(x_l, axis, tiled=True)
        labels = jax.lax.all_gather(labels_l, axis, tiled=True)
        return _supervised_body(st, spec, xf, labels, axis, n_shards,
                                valid=v)

    if masked:
        def epoch(state: DeepState, xs_l: jax.Array, ys_l: jax.Array,
                  valid: jax.Array) -> DeepState:
            def body(st, xyv):
                x_l, labels_l, v = xyv
                return _step(st, x_l, labels_l, v), None
            state, _ = jax.lax.scan(body, state, (xs_l, ys_l, valid))
            return state

        in_specs = (P(), P(None, axis), P(None, axis), P())
    else:
        def epoch(state: DeepState, xs_l: jax.Array,
                  ys_l: jax.Array) -> DeepState:
            def body(st, xy):
                x_l, labels_l = xy
                return _step(st, x_l, labels_l, None), None
            state, _ = jax.lax.scan(body, state, (xs_l, ys_l))
            return state

        in_specs = (P(), P(None, axis), P(None, axis))

    return jax.jit(shard_map(
        epoch, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False))
