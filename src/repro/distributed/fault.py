"""Fault-tolerance utilities: straggler detection and elastic meshes.

Straggler mitigation at 1000+ nodes is observability first: per-step wall
times are tracked online (median + MAD), outlier steps are attributed and
logged so the scheduler can drain/replace slow hosts.  Elastic restart is
mesh rebuilding from whatever devices remain + checkpoint resharding
(checkpoint/ckpt.py restores onto the new mesh's shardings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass
class StepTimer:
    """Online step-time tracker with robust outlier detection."""

    window: int = 50
    threshold: float = 3.0  # MADs above median = straggler event
    _times: List[float] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None
    events: List[dict] = dataclasses.field(default_factory=list)

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, tag: Optional[str] = None) -> float:
        """Close the started window; ``tag`` attributes the step to an
        owner (the serving engine passes the model name, so an injected
        or genuine straggler batch names WHOSE microbatch stalled).

        A ``stop()`` with no open window (no prior ``start()``, or a
        double stop) is a caller bug — raise a clear error instead of
        the bare ``TypeError`` that ``None`` arithmetic used to produce.
        """
        if self._t0 is None:
            raise RuntimeError(
                f"StepTimer.stop(step={step}, tag={tag!r}) called without "
                f"a prior start() — every timed window must be opened "
                f"with start() before it is closed")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        hist = self._times[-self.window:]
        if len(hist) >= 8:
            med = float(np.median(hist))
            mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
            if dt > med + self.threshold * 1.4826 * mad:
                ev = {"step": step, "time": dt, "median": med}
                if tag is not None:
                    ev["tag"] = tag
                self.events.append(ev)
        self._times.append(dt)
        return dt

    @property
    def median(self) -> float:
        return float(np.median(self._times)) if self._times else 0.0


def elastic_mesh(preferred_shape, axis_names, devices=None) -> Mesh:
    """Build the largest mesh of `preferred_shape`'s aspect that fits the
    currently-available devices (drop data-parallel rows for lost hosts).
    """
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    shape = list(preferred_shape)
    # shrink the data axis (first non-model axis) until the mesh fits
    total = int(np.prod(shape))
    while total > n and shape[0] > 1:
        shape[0] -= 1
        total = int(np.prod(shape))
    if total > n:
        raise RuntimeError(f"cannot build mesh {preferred_shape} from {n} devices")
    use = np.asarray(devices[:total]).reshape(shape)
    return Mesh(use, axis_names)


def describe_failure_domains(mesh: Mesh) -> dict:
    """Summarize how mesh axes map to failure domains (host/pod)."""
    hosts = {}
    for d in mesh.devices.flat:
        hosts.setdefault(getattr(d, "process_index", 0), []).append(d.id)
    return {"n_devices": mesh.devices.size, "n_hosts": len(hosts),
            "axis_names": list(mesh.axis_names),
            "axis_sizes": list(mesh.devices.shape)}
