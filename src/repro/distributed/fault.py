"""Fault-tolerance utilities: straggler detection and elastic meshes.

Straggler mitigation at 1000+ nodes is observability first: per-step wall
times are tracked online (median + MAD), outlier steps are attributed and
logged so the scheduler can drain/replace slow hosts.  Elastic restart is
mesh rebuilding from whatever devices remain + checkpoint resharding
(checkpoint/ckpt.py restores onto the new mesh's shardings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import numpy as np
from jax.sharding import Mesh


class WorkerLost(RuntimeError):
    """A data-parallel worker (device/host) dropped out of the mesh.

    Raised by fault-injection hooks (``Trainer.fit(on_chunk=...)``) and by
    real loss detectors; the recovery ladder is: rebuild the largest
    fitting mesh with ``elastic_mesh`` from the survivors, restore the
    latest checkpoint, and resume the fit from its stored cursor
    (DESIGN.md §12)."""


@dataclasses.dataclass
class StepTimer:
    """Online step-time tracker with robust outlier detection.

    ``_times`` is trimmed to the last ``window`` entries on every
    ``stop`` — the tracker is O(window) memory no matter how long the
    serving engine or fit runs (it used to append forever and only
    *slice* the window at read time, a leak on multi-day runs).
    ``median`` is therefore the median of the retained window, which is
    also exactly the statistic the outlier test uses.
    """

    window: int = 50
    threshold: float = 3.0  # MADs above median = straggler event
    _times: List[float] = dataclasses.field(default_factory=list)
    _t0: Optional[float] = None
    events: List[dict] = dataclasses.field(default_factory=list)

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int, tag: Optional[str] = None) -> float:
        """Close the started window; ``tag`` attributes the step to an
        owner (the serving engine passes the model name, so an injected
        or genuine straggler batch names WHOSE microbatch stalled).

        A ``stop()`` with no open window (no prior ``start()``, or a
        double stop) is a caller bug — raise a clear error instead of
        the bare ``TypeError`` that ``None`` arithmetic used to produce.
        """
        if self._t0 is None:
            raise RuntimeError(
                f"StepTimer.stop(step={step}, tag={tag!r}) called without "
                f"a prior start() — every timed window must be opened "
                f"with start() before it is closed")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        hist = self._times  # already at most `window` entries
        if len(hist) >= 8:
            med = float(np.median(hist))
            mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
            if dt > med + self.threshold * 1.4826 * mad:
                ev = {"step": step, "time": dt, "median": med}
                if tag is not None:
                    ev["tag"] = tag
                self.events.append(ev)
        self._times.append(dt)
        if len(self._times) > self.window:
            del self._times[: -self.window]
        return dt

    @property
    def median(self) -> float:
        """Median over the retained window (the last ``window`` steps)."""
        return float(np.median(self._times)) if self._times else 0.0


def order_devices_host_major(devices) -> list:
    """Stable host-major device order: group by ``process_index``, then by
    device id within a host.  A mesh built over this order keeps each
    host's devices contiguous along the leading (data) axis, so losing a
    host removes WHOLE data-axis rows instead of leaving surviving rows
    that straddle processes (which would put a dead device inside a live
    shard_map row)."""
    return sorted(devices, key=lambda d: (getattr(d, "process_index", 0),
                                          getattr(d, "id", 0)))


def fit_mesh_shape(preferred_shape, n_devices: int) -> list:
    """Shrink the data axis (axis 0) of ``preferred_shape`` until the mesh
    fits ``n_devices``; raises when even a single data row does not."""
    shape = list(preferred_shape)
    total = int(np.prod(shape))
    while total > n_devices and shape[0] > 1:
        shape[0] -= 1
        total = int(np.prod(shape))
    if total > n_devices:
        raise RuntimeError(
            f"cannot build mesh {tuple(preferred_shape)} from "
            f"{n_devices} devices")
    return shape


def elastic_mesh(preferred_shape, axis_names, devices=None) -> Mesh:
    """Build the largest mesh of `preferred_shape`'s aspect that fits the
    currently-available devices (drop data-parallel rows for lost hosts).

    Devices are ordered host-major (``order_devices_host_major``) before
    the prefix is taken, so the devices dropped by a shrink are whole
    trailing hosts — not an id-ordered prefix that can split a surviving
    host across data rows.
    """
    devices = order_devices_host_major(
        list(devices if devices is not None else jax.devices()))
    shape = fit_mesh_shape(preferred_shape, len(devices))
    total = int(np.prod(shape))
    use = np.empty(total, dtype=object)
    use[:] = devices[:total]
    return Mesh(use.reshape(shape), axis_names)


def describe_failure_domains(mesh: Mesh) -> dict:
    """Summarize how mesh axes map to failure domains (host/pod)."""
    hosts = {}
    for d in mesh.devices.flat:
        hosts.setdefault(getattr(d, "process_index", 0), []).append(d.id)
    return {"n_devices": mesh.devices.size, "n_hosts": len(hosts),
            "axis_names": list(mesh.axis_names),
            "axis_sizes": list(mesh.devices.shape)}
