"""Logical-axis sharding: models annotate tensors with *logical* axis names
(batch/seq/embed/heads/...), the launcher binds them to physical mesh axes.

This keeps every model mesh-agnostic: the same code runs on 1 CPU device
(no context -> constraints are no-ops), a 16x16 pod, or the 2x16x16
multi-pod mesh.  Rules drop to replication automatically when a dimension
does not divide the mesh axis (e.g. 8 KV heads over a 16-way model axis).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

_CTX: dict = {"mesh": None, "rules": {}}

# Default logical -> physical bindings for the production meshes.
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),   # pod axis absent on single-pod meshes
    "seq": None,                # residual-stream sequence axis (SP binds to model)
    "act_seq": None,            # block-internal sequence axis (never on model with SP)
    "embed": "model",           # residual stream d_model — shards remat saves
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "tp": "model",              # generic tensor-parallel weight dim
    "row_in": "model",          # row-parallel contraction dim
    "row_out": "data",          # row-parallel output dim
    "vocab": "model",
    "expert": "model",
    "fsdp": "data",             # parameter/optimizer-state sharding (ZeRO-3)
    "conv": None,
    "state": None,
    "cache_seq": None,          # KV-cache sequence axis (bind to model for long ctx)
    # BCPNN projections: the (Ni, Nj) joint trace / weight matrices shard
    # along the pre-synaptic rows (the contraction dim of the support
    # matmul); the post axis stays whole so each device's HC softmax and
    # trace EMA are local (no cross-device normalization traffic).
    "proj_pre": "model",
    "proj_post": None,
    # Compact-resident patchy leaves (ProjSpec.compact): the (Hj, K, Mj)
    # trace/weight tensors and the (Hj, nact) index table shard along the
    # leading post-HC axis — each device owns whole post-HCs with their
    # full compact synapse windows, like the FPGA's per-HC datapath; K and
    # Mj stay whole so the gather and per-HC softmax are device-local.
    "proj_hj": "model",
}


def set_context(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    _CTX["mesh"] = mesh
    _CTX["rules"] = dict(rules or {})


@contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    old = (_CTX["mesh"], _CTX["rules"])
    set_context(mesh, rules)
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["rules"] = old


def make_rules(mesh: Mesh, overrides: Optional[Dict[str, Axis]] = None) -> Dict[str, Axis]:
    """Resolve DEFAULT_RULES against the mesh's actual axis names."""
    names = set(mesh.axis_names)
    rules: Dict[str, Axis] = {}
    for k, v in {**DEFAULT_RULES, **(overrides or {})}.items():
        if isinstance(v, tuple):
            kept = tuple(a for a in v if a in names)
            rules[k] = kept if kept else None
        else:
            rules[k] = v if (v is None or v in names) else None
    return rules


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def spec_for(dims: Sequence[Axis], shape: Sequence[int]) -> Optional[P]:
    """Build a PartitionSpec, dropping axes that don't divide the dim."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return None
    rules = _CTX["rules"]
    parts = []
    for logical, size in zip(dims, shape):
        phys = rules.get(logical) if isinstance(logical, str) else None
        if phys is not None and size % _axis_size(mesh, phys) != 0:
            phys = None
        parts.append(phys)
    return P(*parts)


def shard(x: jax.Array, *dims: Axis) -> jax.Array:
    """Apply a logical sharding constraint (no-op without a context)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    assert len(dims) == x.ndim, (dims, x.shape)
    spec = spec_for(dims, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(dims: Sequence[Axis], shape: Sequence[int]) -> Optional[NamedSharding]:
    mesh = _CTX["mesh"]
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(dims, shape))


def projection_shardings(state) -> Optional[object]:
    """NamedSharding pytree for a BCPNN ``DeepState`` (or any pytree of
    ``Projection``s): dense 2-D leaves — w, p_ij, the HC mask — shard
    along the pre-synaptic axis ("proj_pre"); compact-resident leaves —
    3-D (Hj, K, Mj) traces/weights and the integer (Hj, nact) index
    table — shard along the post-HC axis ("proj_hj"); vectors and scalars
    replicate.  Feed the result to ``CheckpointManager.restore`` or
    ``jax.device_put`` for per-projection placement.  Returns None
    outside a sharding context."""
    import numpy as np

    mesh = _CTX["mesh"]
    if mesh is None:
        return None

    def leaf_sharding(x):
        if getattr(x, "ndim", 0) == 3:
            return named_sharding(("proj_hj", None, None), x.shape)
        if getattr(x, "ndim", 0) == 2:
            if np.issubdtype(x.dtype, np.integer):
                return named_sharding(("proj_hj", None), x.shape)
            return named_sharding(("proj_pre", "proj_post"), x.shape)
        return NamedSharding(mesh, P())

    return jax.tree.map(leaf_sharding, state)


def current_mesh() -> Optional[Mesh]:
    return _CTX["mesh"]


def data_shards() -> int:
    """Number of data-parallel shards (MoE dispatch group count)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n
