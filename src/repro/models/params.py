"""Logical sharding dims for every parameter leaf, by leaf name.

Convention-based: the leaf's dict key determines its logical axes; extra
leading dimensions (layer-stacking for scan) map to None.  Anything
unknown is replicated — safe, and the dry-run memory analysis flags it if
that ever matters.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..distributed.sharding import named_sharding

# leaf name -> logical dims of the UNSTACKED parameter
LEAF_DIMS = {
    "tok_embed": ("vocab", "fsdp"),
    "pos_embed": (None, "fsdp"),
    "lm_head": ("fsdp", "vocab"),
    # attention / mlp projections
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wi": ("fsdp", "tp"), "wg": ("fsdp", "tp"),
    # row-parallel weights: contraction dim on row_in (default model),
    # output dim on row_out (default data).  The "colshard" perf variant
    # flips these so the model axis never holds a contraction dim (kills
    # the f32-upcast partial-sum all-reduces; see EXPERIMENTS.md §Perf).
    "wo": ("row_in", "row_out"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    "q_norm": (None,), "k_norm": (None,),
    # moe
    "router": ("fsdp", None),
    "wi_e": ("expert", "fsdp", None), "wg_e": ("expert", "fsdp", None),
    "wo_e": ("expert", None, "fsdp"),
    # mamba
    "in_proj": ("fsdp", "tp"), "out_proj": ("row_in", "row_out"),
    "conv_w": (None, "tp"), "conv_b": ("tp",),
    "x_proj": ("tp", None), "dt_w": (None, "tp"), "dt_bias": ("tp",),
    "A_log": ("tp", None), "D": ("tp",),
    # rg-lru
    "w_in": ("fsdp", "tp"), "w_gate": ("fsdp", "tp"),
    "w_out": ("row_in", "row_out"),
    "w_r": ("fsdp", "tp"), "w_i": ("fsdp", "tp"),
    "b_r": ("tp",), "b_i": ("tp",), "Lambda": ("tp",),
    # norms
    "scale": (None,), "bias": (None,),
}


def leaf_dims(path, leaf) -> Tuple:
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    dims = LEAF_DIMS.get(name, tuple(None for _ in leaf.shape))
    extra = leaf.ndim - len(dims)
    if extra < 0:  # scalar or reduced leaf
        return tuple(None for _ in leaf.shape)
    return tuple([None] * extra) + tuple(dims)


def param_shardings(params):
    """Pytree of NamedShardings (or None outside a mesh context)."""
    def one(path, leaf):
        return named_sharding(leaf_dims(path, leaf), leaf.shape)
    return jax.tree_util.tree_map_with_path(one, params)


def cache_dims(path, leaf) -> Tuple:
    """KV/recurrent cache leaves: batch-sharded, head/feature dims on TP."""
    name = None
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            name = entry.key
            break
    table = {
        "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
        "conv": ("batch", None, "tp"),
        "ssm": ("batch", "tp", None),
        "state": ("batch", "tp"),
        "enc_out": ("batch", None, "embed"),
        "pos": (),
    }
    dims = table.get(name)
    if dims is None:
        return tuple(None for _ in leaf.shape)
    extra = leaf.ndim - len(dims)
    if extra < 0:
        return tuple(None for _ in leaf.shape)
    return tuple([None] * extra) + tuple(dims)


def cache_shardings(cache):
    def one(path, leaf):
        return named_sharding(cache_dims(path, leaf), leaf.shape)
    return jax.tree_util.tree_map_with_path(one, cache)
