"""Grouped-query attention with every variant the zoo needs:

  * GQA / MQA / MHA (n_kv_heads <= n_heads)
  * causal, sliding-window (local) or bidirectional (encoder) masking
  * query-chunked streaming softmax — scores never materialize for the
    full (S, S) square, which is what makes prefill_32k representable
  * gemma2 tanh logit soft-capping, qwen3 per-head qk RMSNorm, qwen1.5
    QKV biases, cross-attention (whisper decoder)
  * ring-buffer KV cache decode for local layers, flat cache for global
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from ..kernels.tiling import NEG
from .common import Params, dense_init, rms_norm, rope


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> Params:
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": dense_init(ks[0], (d, hq * hd), 0, dtype),
        "wk": dense_init(ks[1], (d, hk * hd), 0, dtype),
        "wv": dense_init(ks[2], (d, hk * hd), 0, dtype),
        "wo": dense_init(ks[3], (hq * hd, d), 0, dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hk * hd,), dtype)
        p["bv"] = jnp.zeros((hk * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array,
                 kv_x: Optional[jax.Array] = None):
    """x: (B, S, d) -> q (B,S,Hq,D), k/v (B,Skv,Hk,D)."""
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ params["wq"]
    k = kv_x @ params["wk"]
    v = kv_x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, skv, hk, hd)
    v = v.reshape(b, skv, hk, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps, plus_one=True)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps, plus_one=True)
    q = shard(q, "batch", "act_seq", "heads", "head_dim")
    k = shard(k, "batch", "act_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "act_seq", "kv_heads", "head_dim")
    return q, k, v


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap > 0.0:
        return cap * jnp.tanh(scores / cap)
    return scores


def _chunk_attend(q_chunk, k, v, q_pos, k_pos, cfg: ModelConfig,
                  causal: bool) -> jax.Array:
    """q_chunk: (B,C,Hk,G,D); k,v: (B,S,Hk,D); positions: (C,), (S,)."""
    scale = cfg.head_dim ** -0.5
    scores = jnp.einsum("bchgd,bshd->bhgcs", q_chunk, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, cfg.attn_softcap)
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if cfg.window > 0 and causal:
        mask &= k_pos[None, :] > q_pos[:, None] - cfg.window
    # ADDITIVE mask, not where(): where()'s vjp saves the predicate at the
    # broadcast (B,H,G,C,S) shape per chunk; add's vjp saves nothing, and
    # the (C,S) where-pred below is batch-free (perf iteration §Perf-0).
    scores = scores + jnp.where(mask, 0.0, NEG)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q_chunk.dtype)
    out = jnp.einsum("bhgcs,bshd->bchgd", probs, v)
    return out


def fill_cache(cfg: ModelConfig, k: jax.Array, v: jax.Array, local: bool,
               cache_size: int) -> Dict[str, jax.Array]:
    """Lay prompt K/V (B,S,Hk,D) out in decode-cache format (flat or ring)."""
    b, s, hk, hd = k.shape
    use_ring = local and cfg.window > 0 and cache_size <= cfg.window
    if not use_ring:
        pad = cache_size - s
        if pad > 0:
            zeros = jnp.zeros((b, pad, hk, hd), k.dtype)
            return {"k": jnp.concatenate([k, zeros], 1),
                    "v": jnp.concatenate([v, zeros], 1)}
        return {"k": k[:, -cache_size:], "v": v[:, -cache_size:]}
    w = cache_size
    kw, vw = k[:, -w:], v[:, -w:]
    start = max(0, s - w)
    slots = (start + jnp.arange(kw.shape[1])) % w
    buf_k = jnp.zeros((b, w, hk, hd), k.dtype).at[:, slots].set(kw)
    buf_v = jnp.zeros((b, w, hk, hd), v.dtype).at[:, slots].set(vw)
    return {"k": buf_k, "v": buf_v}


def attend(params: Params, cfg: ModelConfig, x: jax.Array,
           positions: jax.Array, causal: bool = True, local: bool = False,
           kv_x: Optional[jax.Array] = None,
           q_chunk: int = 512,
           return_kv: bool = False):
    """Full-sequence attention (training / prefill), query-chunked.

    x: (B, S, d); positions: (S,) int32.  Returns (B, S, d)
    (plus raw (k, v) when return_kv, for prefill cache priming).
    """
    cfg_l = cfg if local else cfg.with_(window=0)
    b, s, d = x.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hk
    q, k, v = _project_qkv(params, cfg_l, x, kv_x)
    q = q.reshape(b, s, hk, g, hd)
    skv = k.shape[1]
    kv_pos = positions if kv_x is None else jnp.arange(skv, dtype=jnp.int32)
    if cfg.rope_theta > 0 and kv_x is None:  # no rope on cross-attention
        q = rope(q.reshape(b, s, hk * g, hd), positions[None], cfg.rope_theta
                 ).reshape(b, s, hk, g, hd)
        k = rope(k, kv_pos[None], cfg.rope_theta)

    nchunk = max(1, s // q_chunk)
    if s % q_chunk != 0:
        nchunk = 1
    if nchunk == 1:
        out = _chunk_attend(q, k, v, positions, kv_pos, cfg_l, causal)
    else:
        qc = q.reshape(b, nchunk, s // nchunk, hk, g, hd)
        pc = positions.reshape(nchunk, s // nchunk)

        def body(_, qp):
            qi, pi = qp
            return None, _chunk_attend(qi, k, v, pi, kv_pos, cfg_l, causal)

        _, outs = jax.lax.scan(
            body, None, (jnp.moveaxis(qc, 1, 0), pc))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, nchunk, s // nchunk, hk, g, hd)
    out = out.reshape(b, s, hq * hd)
    out = out @ params["wo"]
    out = shard(out, "batch", "seq", "embed")
    if return_kv:
        return out, (k, v)
    return out


# ------------------------------------------------------------- decoding --

def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int, local: bool,
                  dtype) -> Dict[str, jax.Array]:
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    size = min(seq_len, cfg.window) if (local and cfg.window > 0) else seq_len
    return {
        "k": jnp.zeros((batch, size, hk, hd), dtype),
        "v": jnp.zeros((batch, size, hk, hd), dtype),
    }


def decode_attend(params: Params, cfg: ModelConfig, x: jax.Array,
                  cache: Dict[str, jax.Array], pos: jax.Array,
                  local: bool = False,
                  cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode.  x: (B, 1, d); pos: scalar int32 (current index).

    Global layers use a flat cache written at `pos`; local layers use a
    ring buffer of size `window`.  Cross-attention reads precomputed
    encoder K/V and writes nothing.
    """
    b = x.shape[0]
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hk
    scale = hd ** -0.5

    if cross_kv is not None:
        k, v = cross_kv
        q = (x @ params["wq"]).reshape(b, 1, hk, g, hd)
        if cfg.qk_norm:
            q = rms_norm(q, params["q_norm"], cfg.norm_eps, plus_one=True)
        scores = jnp.einsum("bhgd,bshd->bhgs", q[:, 0], k,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(_softcap(scores, cfg.attn_softcap), -1).astype(x.dtype)
        out = jnp.einsum("bhgs,bshd->bhgd", probs, v).reshape(b, 1, hq * hd)
        return out @ params["wo"], cache

    q, k_new, v_new = _project_qkv(params, cfg, x)
    if cfg.rope_theta > 0:
        posv = pos[None, None] if pos.ndim == 0 else pos[:, None]
        q = rope(q, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)
        k_new = rope(k_new, jnp.broadcast_to(posv, (b, 1)), cfg.rope_theta)

    size = cache["k"].shape[1]
    slot = jnp.mod(pos, size) if (local and cfg.window > 0) else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    k = shard(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "cache_seq", "kv_heads", "head_dim")

    idx = jnp.arange(size, dtype=jnp.int32)
    if local and cfg.window > 0:
        # slot i holds absolute position p_i = pos - ((pos - i) mod size)
        p_i = pos - jnp.mod(pos - idx, size)
        valid = (p_i >= 0) & (p_i <= pos) & (p_i > pos - cfg.window)
    else:
        valid = idx <= pos

    qh = q.reshape(b, hk, g, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", qh, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, cfg.attn_softcap)
    scores = scores + jnp.where(valid, 0.0, NEG)[None, None, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v).reshape(b, 1, hq * hd)
    out = out @ params["wo"]
    return out, {"k": k, "v": v}
