"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is group-local: tokens are viewed as (G groups, T/G tokens) with
G = number of data shards, so the rank-within-expert cumsum never crosses
a shard boundary (no cross-device scan).  Expert buffers (G, E, C, d) are
sharded E->model (expert parallelism); the token->expert scatter is where
GSPMD inserts the all-to-all.

Top-k choices beyond an expert's capacity C = k*T_g/E * capacity_factor
are dropped (standard capacity dispatch); the residual connection carries
dropped tokens through unchanged.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import data_shards, shard
from .common import Params, dense_init


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "wi_e": dense_init(ks[1], (e, d, f), 1, dtype),
        "wg_e": dense_init(ks[2], (e, d, f), 1, dtype),
        "wo_e": dense_init(ks[3], (e, f, d), 1, dtype),
    }


def moe_ffn(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    groups = cfg.moe_groups or data_shards()
    t = b * s
    if t % groups != 0:
        groups = 1
    tg = t // groups
    if s == 1:  # decode: tiny token count — dropless (cap covers worst case)
        cap = tg
    else:
        cap = max(1, int(k * tg / e * cfg.capacity_factor))
        cap = min(cap, tg)

    xt = x.reshape(groups, tg, d)
    xt = shard(xt, "batch", None, "embed")

    # --- routing (f32 for a stable softmax) ---------------------------
    logits = xt.astype(jnp.float32) @ params["router"]          # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # --- rank within expert (group-local, sort-based) ------------------
    # A one-hot cumsum materializes (G, Tk, E) int32 — 268 GB/chip/layer
    # at qwen3-moe train_4k scale.  A stable argsort gives identical
    # first-come-first-served ranks with O(G, Tk) tensors (§Perf).
    flat_ids = expert_ids.reshape(groups, tg * k)               # (G, Tk)
    sort_idx = jnp.argsort(flat_ids, axis=1, stable=True)       # (G, Tk)
    sorted_ids = jnp.take_along_axis(flat_ids, sort_idx, axis=1)
    first = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(e, dtype=s.dtype)))(
            sorted_ids)                                         # (G, E)
    pos = jnp.arange(tg * k, dtype=jnp.int32)[None]
    rank_sorted = pos - jnp.take_along_axis(first, sorted_ids, axis=1)
    inv = jnp.argsort(sort_idx, axis=1)                         # inverse perm
    rank = jnp.take_along_axis(rank_sorted, inv, axis=1)        # (G, Tk)
    keep = rank < cap
    # flat slot in the (E*C [+1 overflow]) buffer; dropped -> overflow row
    slot = jnp.where(keep, flat_ids * cap + rank, e * cap)      # (G, Tk)

    # --- index-based dispatch (§Perf): scatter slot->token INDICES (int32,
    # a few MB) instead of token VECTORS (Tk x d, k-fold duplicated —
    # GSPMD turned that scatter into a (Tk, d) f32 all-reduce per layer).
    # Unused slots point at the zero pad row tg: they gather a zero token,
    # the (bias-free) experts map it to zero, and it combines into the
    # discarded pad row with a zero gate.
    tok_src = jnp.arange(tg * k, dtype=jnp.int32)[None] // k    # (1, Tk)
    tok_src = jnp.broadcast_to(tok_src, (groups, tg * k))

    def scatter_idx(slots, src):
        buf = jnp.full((e * cap + 1,), tg, jnp.int32)
        return buf.at[slots].set(src)[: e * cap]

    idx_buf = jax.vmap(scatter_idx)(slot, tok_src)              # (G, E*C)
    gates_flat = (gate_vals * keep.reshape(groups, tg, k)).reshape(groups, tg * k)

    def scatter_gate(slots, gvals):
        buf = jnp.zeros((e * cap + 1,), jnp.float32)
        return buf.at[slots].set(gvals)[: e * cap]

    gate_buf = jax.vmap(scatter_gate)(slot, gates_flat.astype(jnp.float32))

    xt_pad = jnp.concatenate([xt, jnp.zeros((groups, 1, d), xt.dtype)], 1)
    buf = jnp.take_along_axis(xt_pad, idx_buf[..., None], axis=1)
    buf = buf.reshape(groups, e, cap, d)
    buf = shard(buf, "batch", "expert", None, None)

    # --- expert computation (batched over E on the MXU) ---------------
    h = jnp.einsum("gecd,edf->gecf", buf, params["wi_e"])
    g_ = jnp.einsum("gecd,edf->gecf", buf, params["wg_e"])
    h = jax.nn.silu(g_) * h
    out = jnp.einsum("gecf,efd->gecd", h, params["wo_e"])       # (G, E, C, d)
    out = shard(out, "batch", "expert", None, None)
    out = out.reshape(groups, e * cap, d)

    # --- combine: gate-weighted scatter-add back to tokens -------------
    weighted = out * gate_buf[..., None].astype(out.dtype)      # (G, E*C, d)

    def combine_group(w_g, idx_g):
        acc = jnp.zeros((tg + 1, d), w_g.dtype)
        return acc.at[idx_g].add(w_g)[:tg]

    y = jax.vmap(combine_group)(weighted, idx_buf)              # (G, Tg, d)
    y = y.reshape(b, s, d)
    return shard(y, "batch", "seq", "embed")


def aux_load_balance_loss(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction * prob)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    _, ids = jax.lax.top_k(probs, cfg.n_experts_active)
    frac = jnp.mean(
        jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32), axis=(0, 1, 2))
    return cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))
