"""Shared building blocks for the LM zoo (pure functional, pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard

Params = Dict[str, Any]


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (stddev = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (0.02 * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


# ----------------------------------------------------------------- norms --

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm: statistics in f32, application in the compute dtype.

    Only the (B, S, 1) stats are f32 — upcasting the whole tensor makes
    every norm backward produce f32 (B, S, d) cotangents, which doubles
    the bytes of the model-axis gradient collectives (§Perf-2: this one
    change cut qwen3-32b train collective traffic ~2x).
    """
    d = x.shape[-1]
    # f32-ACCUMULATING einsum of bf16 inputs: the f32 lives only in the
    # (B, S) stats; the vjp cotangent to x stays bf16 (an explicit
    # x.astype(f32) node would receive an f32 (B, S, d) cotangent and
    # drag every gradient collective up to 4 bytes/elem).
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None] / d
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return x * inv * s.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    d = x.shape[-1]
    mu = (jnp.sum(x, axis=-1, keepdims=True, dtype=jnp.float32) / d)
    e2 = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None] / d
    var = e2 - mu * mu
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    mu = mu.astype(x.dtype)
    return (x - mu) * inv * scale.astype(x.dtype) + bias.astype(x.dtype)


# ------------------------------------------------------------------ rope --

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- mlp --

def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": dense_init(k1, (d_model, d_ff), 0, dtype),
            "wg": dense_init(k2, (d_model, d_ff), 0, dtype),
            "wo": dense_init(k3, (d_ff, d_model), 0, dtype),
        }
    return {
        "wi": dense_init(k1, (d_model, d_ff), 0, dtype),
        "wo": dense_init(k3, (d_ff, d_model), 0, dtype),
    }


def mlp(params: Params, x: jax.Array, kind: str) -> jax.Array:
    """x: (B, S, d)."""
    h = x @ params["wi"]
    h = shard(h, "batch", "act_seq", "ffn")
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * h
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["wg"], approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    out = h @ params["wo"]
    return shard(out, "batch", "seq", "embed")


def sharded_params_spec(params, fn):
    """Map a pytree of params to NamedShardings via a leaf-path function."""
    return jax.tree_util.tree_map_with_path(fn, params)
