"""Recurrent sequence mixers: Mamba-1 selective SSM and RG-LRU (Griffin /
RecurrentGemma).  Both expose a full-sequence path (lax.scan over time,
used for train/prefill) and a single-step decode path carrying
(conv window, recurrent state) — these are the sub-quadratic trunks that
make the long_500k cells representable.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .common import Params, dense_init


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C), w: (K, C) -> (B, S, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K is 4 — unrolled taps beat a conv op at this size
        out = out + pad[:, i : i + x.shape[1]] * w[i]
    return out + b


def _conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """state: (B, K-1, C) previous inputs; x_t: (B, C)."""
    window = jnp.concatenate([state, x_t[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:], y


# ------------------------------------------------------------- mamba-1 --

def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    n, k, r = cfg.ssm_state, cfg.d_conv, cfg.dt_rank_eff
    ks = jax.random.split(key, 6)
    # S4D-real A initialization: A_n = -(n+1)
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), 0, dtype),
        "conv_w": dense_init(ks[1], (k, di), 0, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, r + 2 * n), 0, dtype),
        "dt_w": dense_init(ks[3], (r, di), 0, dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),  # softplus^-1(0.01)
        "A_log": jnp.log(a_init),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), 0, dtype),
    }


def _mamba_coeffs(params: Params, cfg: ModelConfig, xc: jax.Array):
    """xc: (..., di) post-conv activations -> per-step SSM coefficients."""
    n, r = cfg.ssm_state, cfg.dt_rank_eff
    proj = xc @ params["x_proj"]                       # (..., R+2N)
    dt_low, bc = proj[..., :r], proj[..., r:]
    b_in, c_out = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(dt_low @ params["dt_w"] + params["dt_bias"])
    return dt.astype(jnp.float32), b_in.astype(jnp.float32), c_out.astype(jnp.float32)


def mamba_mixer(params: Params, cfg: ModelConfig, x: jax.Array,
                return_state: bool = False):
    """Full-sequence selective scan.  x: (B, S, d) -> (B, S, d)."""
    b, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ params["in_proj"]
    xz = shard(xz, "batch", "act_seq", "tp")
    x_br, z = jnp.split(xz, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(x_br, params["conv_w"], params["conv_b"]))
    a = -jnp.exp(params["A_log"])                      # (di, N)

    def step(h, inputs):
        xc_t, dt_t, b_t, c_t = inputs                  # (B,di),(B,di),(B,N),(B,N)
        da = jnp.exp(dt_t[..., None] * a)              # (B, di, N)
        dbx = (dt_t * xc_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
        h = da * h + dbx
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    dt, b_in, c_out = _mamba_coeffs(params, cfg, xc)
    h0 = jnp.zeros((b, di, n), jnp.float32)
    ck = cfg.ssm_chunk
    if ck > 1 and s % ck == 0:
        # Chunked scan (§Perf): the sequential scan saves the (B, di, N)
        # carry EVERY step for backward — S x state bytes of HBM traffic.
        # Scanning over chunks of ck steps with a rematerialized inner
        # (unrolled) loop saves only chunk-BOUNDARY states (1/ck of the
        # traffic); the inner steps are recomputed from the cheap
        # per-token streams during backward.
        def chunk_body(h, inputs):
            xc_c, dt_c, b_c, c_c = inputs              # (ck, B, ...)
            ys_c = []
            for i in range(ck):
                h, y_i = step(h, (xc_c[i], dt_c[i], b_c[i], c_c[i]))
                ys_c.append(y_i)
            return h, jnp.stack(ys_c)

        xs = tuple(
            jnp.moveaxis(t, 1, 0).reshape(s // ck, ck, *t.shape[0:1], *t.shape[2:])
            for t in (xc, dt, b_in, c_out))
        h_last, ys = jax.lax.scan(
            jax.checkpoint(chunk_body,
                           policy=jax.checkpoint_policies.nothing_saveable),
            h0, xs)
        ys = ys.reshape(s, b, di)
    else:
        xs = (
            jnp.moveaxis(xc, 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(b_in, 1, 0),
            jnp.moveaxis(c_out, 1, 0),
        )
        h_last, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)         # (B, S, di)
    y = y + xc * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    out = shard(out, "batch", "seq", "embed")
    if return_state:
        k = cfg.d_conv - 1
        conv_state = x_br[:, -k:] if s >= k else jnp.pad(
            x_br, ((0, 0), (k - s, 0), (0, 0)))
        return out, {"conv": conv_state, "ssm": h_last}
    return out


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, 1, d) -> (B, 1, d), updated cache."""
    xz = x[:, 0] @ params["in_proj"]
    x_br, z = jnp.split(xz, 2, axis=-1)
    conv_state, xc = _conv_step(cache["conv"], x_br, params["conv_w"],
                                params["conv_b"])
    xc = jax.nn.silu(xc)
    dt, b_in, c_out = _mamba_coeffs(params, cfg, xc)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[..., None] * a)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * b_in[:, None, :]
    h = da * cache["ssm"] + dbx
    y = jnp.einsum("bdn,bn->bd", h, c_out).astype(x.dtype)
    y = y + xc * params["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": conv_state, "ssm": h}


# -------------------------------------------------------------- rg-lru --

_LRU_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key, cfg: ModelConfig, dtype) -> Params:
    d, w = cfg.d_model, cfg.lru_width_eff
    ks = jax.random.split(key, 6)
    # Lambda init so a ~ U(0.9, 0.999)^c  (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _LRU_C))  # softplus^-1
    return {
        "w_in": dense_init(ks[0], (d, w), 0, dtype),
        "w_gate": dense_init(ks[1], (d, w), 0, dtype),
        "conv_w": dense_init(ks[2], (cfg.d_conv, w), 0, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[3], (w, w), 0, dtype),
        "w_i": dense_init(ks[4], (w, w), 0, dtype),
        "b_r": jnp.zeros((w,), jnp.float32),
        "b_i": jnp.zeros((w,), jnp.float32),
        "Lambda": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), 0, dtype),
    }


def _rglru_gates(params: Params, xc: jax.Array):
    r = jax.nn.sigmoid((xc @ params["w_r"]).astype(jnp.float32) + params["b_r"])
    i = jax.nn.sigmoid((xc @ params["w_i"]).astype(jnp.float32) + params["b_i"])
    log_a = -_LRU_C * jax.nn.softplus(params["Lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9))
    return a, beta, i


def rglru_mixer(params: Params, cfg: ModelConfig, x: jax.Array,
                return_state: bool = False):
    """Full-sequence RG-LRU block.  x: (B, S, d) -> (B, S, d)."""
    gate = jax.nn.gelu(x @ params["w_gate"], approximate=True)
    xr = x @ params["w_in"]
    xr = shard(xr, "batch", "act_seq", "tp")
    xc = _causal_conv(xr, params["conv_w"], params["conv_b"])
    a, beta, i = _rglru_gates(params, xc)
    drive = beta * i * xc.astype(jnp.float32)

    def step(h, inputs):
        a_t, d_t = inputs
        h = a_t * h + d_t
        return h, h

    b, s, w = xc.shape
    h0 = jnp.zeros((b, w), jnp.float32)
    h_last, hs = jax.lax.scan(
        step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(drive, 1, 0)))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)         # (B, S, W)
    out = (h * gate) @ params["w_out"]
    out = shard(out, "batch", "seq", "embed")
    if return_state:
        k = cfg.d_conv - 1
        conv_state = xr[:, -k:] if s >= k else jnp.pad(
            xr, ((0, 0), (k - s, 0), (0, 0)))
        return out, {"conv": conv_state, "state": h_last}
    return out


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    w = cfg.lru_width_eff
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, w), dtype),
        "state": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode(params: Params, cfg: ModelConfig, x: jax.Array,
                 cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"], approximate=True)
    xr = x[:, 0] @ params["w_in"]
    conv_state, xc = _conv_step(cache["conv"], xr, params["conv_w"], params["conv_b"])
    a, beta, i = _rglru_gates(params, xc)
    h = a * cache["state"] + beta * i * xc.astype(jnp.float32)
    out = ((h.astype(x.dtype) * gate) @ params["w_out"])[:, None]
    return out, {"conv": conv_state, "state": h}
