"""Generic decoder LM assembling the zoo's sequence mixers.

One model covers all ten assigned architectures through ModelConfig:
  * layer_pattern — a repeating unit over {g: global attn, l: local attn,
    r: RG-LRU, m: mamba}; `n_layers // len(pattern)` repeats are scanned
    with stacked params (small HLO, fast 512-device compiles), the
    remainder runs unrolled as tail layers.
  * enc_layers > 0 — adds a whisper-style bidirectional encoder and
    cross-attention in every decoder block.
  * vision_patches > 0 — the first P sequence positions take precomputed
    patch embeddings (stub ViT frontend, per the assignment).

Exposes: init_params, forward (train/prefill), lm_loss, init_cache,
prefill, decode_step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .attention import attend, decode_attend, fill_cache, init_attention, init_kv_cache
from .common import Params, dense_init, embed_init, layer_norm, mlp, init_mlp, rms_norm
from .moe import init_moe, moe_ffn
from .recurrent import (
    init_mamba, init_mamba_cache, init_rglru, init_rglru_cache,
    mamba_decode, mamba_mixer, rglru_decode, rglru_mixer,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _norm(cfg: ModelConfig, x: jax.Array, p: Params) -> jax.Array:
    if cfg.family == "audio":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    plus_one = cfg.post_norms or cfg.embed_scale  # gemma-style norm
    return rms_norm(x, p["scale"], cfg.norm_eps, plus_one=plus_one)


def _init_norm(cfg: ModelConfig, dtype) -> Params:
    if cfg.family == "audio":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    init = jnp.zeros if (cfg.post_norms or cfg.embed_scale) else jnp.ones
    return {"scale": init((cfg.d_model,), dtype)}


# ------------------------------------------------------------ block init --

def _init_block(key, cfg: ModelConfig, char: str, dtype,
                with_cross: bool) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": _init_norm(cfg, dtype)}
    if char in ("g", "l"):
        p["attn"] = init_attention(ks[0], cfg, dtype)
    elif char == "r":
        p["rglru"] = init_rglru(ks[0], cfg, dtype)
    elif char == "m":
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    else:
        raise ValueError(char)
    if cfg.post_norms:
        p["norm1_post"] = _init_norm(cfg, dtype)
    if with_cross:
        p["norm_cross"] = _init_norm(cfg, dtype)
        p["cross"] = init_attention(ks[1], cfg, dtype, cross=True)
    if cfg.d_ff > 0 or cfg.n_experts > 0:
        p["norm2"] = _init_norm(cfg, dtype)
        if cfg.n_experts > 0:
            p["moe"] = init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp, dtype)
        if cfg.post_norms:
            p["norm2_post"] = _init_norm(cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    dtype = _dtype(cfg)
    n_blocks, n_tail = cfg.pattern_blocks
    keys = jax.random.split(key, 8)
    with_cross = cfg.enc_layers > 0

    def blocks_for(pos_char: str, k) -> Params:
        if cfg.scan_layers and n_blocks > 1:
            ks = jax.random.split(k, n_blocks)
            return jax.vmap(
                lambda kk: _init_block(kk, cfg, pos_char, dtype, with_cross)
            )(ks)
        return _init_block(k, cfg, pos_char, dtype, with_cross)

    params: Params = {
        "tok_embed": embed_init(keys[0], (cfg.vocab_padded, cfg.d_model), dtype),
        "final_norm": _init_norm(cfg, dtype),
        "blocks": {
            f"pos{i}_{c}": blocks_for(c, jax.random.fold_in(keys[1], i))
            for i, c in enumerate(cfg.layer_pattern)
        },
        "tail": {
            f"tail{i}_{cfg.layer_pattern[i % len(cfg.layer_pattern)]}":
                _init_block(jax.random.fold_in(keys[2], i), cfg,
                            cfg.layer_pattern[i % len(cfg.layer_pattern)],
                            dtype, with_cross)
            for i in range(n_tail)
        },
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[3], (cfg.d_model, cfg.vocab_padded),
                                       0, dtype)
    if cfg.rope_theta == 0:  # learned positional embeddings (whisper)
        params["pos_embed"] = embed_init(keys[4], (32768, cfg.d_model), dtype)
    if cfg.enc_layers > 0:
        ek = jax.random.split(keys[5], cfg.enc_layers + 2)
        params["encoder"] = {
            "pos_embed": embed_init(ek[0], (cfg.enc_seq, cfg.d_model), dtype),
            "layers": {
                f"enc{i}": _init_block(ek[i + 1], cfg, "g", dtype, False)
                for i in range(cfg.enc_layers)
            },
            "final_norm": _init_norm(cfg, dtype),
        }
    return params


# ----------------------------------------------------------- block apply --

def _apply_block(p: Params, cfg: ModelConfig, char: str, x: jax.Array,
                 positions: jax.Array, causal: bool,
                 cross_kv: Optional[Tuple[jax.Array, jax.Array]],
                 cache_size: int = 0):
    """One residual block.  cache_size > 0 -> also return a primed cache."""
    collect = cache_size > 0
    entry = None
    h = _norm(cfg, x, p["norm1"])
    if char in ("g", "l"):
        out = attend(p["attn"], cfg, h, positions, causal=causal,
                     local=(char == "l"), return_kv=collect)
        if collect:
            h, (k, v) = out
            entry = fill_cache(cfg, k, v, char == "l", cache_size if char != "l"
                               or cfg.window == 0 else min(cache_size, cfg.window))
        else:
            h = out
    elif char == "r":
        out = rglru_mixer(p["rglru"], cfg, h, return_state=collect)
        h, entry = out if collect else (out, None)
    else:
        out = mamba_mixer(p["mamba"], cfg, h, return_state=collect)
        h, entry = out if collect else (out, None)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm1_post"])
    x = x + h
    if cross_kv is not None and "cross" in p:
        h = _norm(cfg, x, p["norm_cross"])
        h = _cross_attend(p["cross"], cfg, h, cross_kv)
        x = x + h
    if "norm2" in p:
        h = _norm(cfg, x, p["norm2"])
        if "moe" in p:
            h = moe_ffn(p["moe"], cfg, h)
        else:
            h = mlp(p["mlp"], h, cfg.mlp)
        if cfg.post_norms:
            h = _norm(cfg, h, p["norm2_post"])
        x = x + h
    x = shard(x, "batch", "seq", "embed")
    if collect:
        return x, entry
    return x


def _cross_attend(p: Params, cfg: ModelConfig, h: jax.Array,
                  cross_kv: Tuple[jax.Array, jax.Array]) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (B, Senc, Hk, D)."""
    b, s, _ = h.shape
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hk
    k, v = cross_kv
    q = (h @ p["wq"]).reshape(b, s, hk, g, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps, plus_one=True)
    scores = jnp.einsum("bchgd,bshd->bhgcs", q, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    probs = jax.nn.softmax(scores, -1).astype(h.dtype)
    out = jnp.einsum("bhgcs,bshd->bchgd", probs, v).reshape(b, s, hq * hd)
    return out @ p["wo"]


# ----------------------------------------------------------- embeddings --

def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 patches: Optional[jax.Array]) -> jax.Array:
    x = jnp.take(params["tok_embed"], tokens, axis=0).astype(_dtype(cfg))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if patches is not None and cfg.vision_patches > 0:
        p = patches.astype(x.dtype)
        x = jnp.concatenate([p, x[:, cfg.vision_patches:]], axis=1)
    return shard(x, "batch", "seq", "embed")


# -------------------------------------------------------------- encoder --

def encode(params: Params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Whisper encoder over stub frame embeddings (B, Senc, d)."""
    enc = params["encoder"]
    x = frames.astype(_dtype(cfg)) + enc["pos_embed"][None, : frames.shape[1]]
    pos = jnp.arange(frames.shape[1], dtype=jnp.int32)
    for name in sorted(enc["layers"]):
        x = _apply_block(enc["layers"][name], cfg, "g", x, pos,
                         causal=False, cross_kv=None)
    return _norm(cfg, x, enc["final_norm"])


def cross_kv_from_encoder(cfg: ModelConfig, enc_out: jax.Array,
                          block_params: Params) -> Tuple[jax.Array, jax.Array]:
    b, s, _ = enc_out.shape
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ block_params["cross"]["wk"]).reshape(b, s, hk, hd)
    v = (enc_out @ block_params["cross"]["wv"]).reshape(b, s, hk, hd)
    return k, v


# -------------------------------------------------------------- forward --

def forward(params: Params, cfg: ModelConfig, tokens: jax.Array,
            patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            cache_size: int = 0):
    """Training/prefill forward.  tokens: (B, S) -> hidden (B, S, d).

    cache_size > 0 also returns per-layer primed decode caches (prefill).
    """
    x = embed_tokens(params, cfg, tokens, patches)
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    if cfg.rope_theta == 0 and "pos_embed" in params:
        x = x + params["pos_embed"][None, :s]

    enc_out = encode(params, cfg, frames) if cfg.enc_layers > 0 else None
    n_blocks, _ = cfg.pattern_blocks
    pattern = cfg.layer_pattern
    block_names = [f"pos{i}_{c}" for i, c in enumerate(pattern)]
    collect = cache_size > 0

    def one_repeat(x, rep_params):
        caches = {}
        for name, char in zip(block_names, pattern):
            ckv = (cross_kv_from_encoder(cfg, enc_out, rep_params[name])
                   if enc_out is not None else None)
            out = _apply_block(rep_params[name], cfg, char, x, positions,
                               causal=True, cross_kv=ckv,
                               cache_size=cache_size)
            if collect:
                x, caches[name] = out
            else:
                x = out
        return x, caches

    body = one_repeat
    if cfg.remat and not collect:
        body = jax.checkpoint(one_repeat,
                              policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers and n_blocks > 1:
        x, block_caches = jax.lax.scan(
            lambda carry, rep: body(carry, rep), x, params["blocks"])
    else:
        x, block_caches = body(x, params["blocks"])

    tail_caches = {}
    for name in sorted(params["tail"]):
        char = name.split("_")[-1]
        ckv = (cross_kv_from_encoder(cfg, enc_out, params["tail"][name])
               if enc_out is not None else None)
        out = _apply_block(params["tail"][name], cfg, char, x, positions,
                           causal=True, cross_kv=ckv, cache_size=cache_size)
        if collect:
            x, tail_caches[name] = out
        else:
            x = out
    x = _norm(cfg, x, params["final_norm"])
    if collect:
        return x, block_caches, tail_caches, enc_out
    return x


def logits_for(params: Params, cfg: ModelConfig, hidden: jax.Array) -> jax.Array:
    head = params.get("lm_head")
    if head is None:
        head = params["tok_embed"].T
    logits = hidden @ head.astype(hidden.dtype)
    if cfg.final_softcap > 0:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


def lm_loss(params: Params, cfg: ModelConfig, tokens: jax.Array,
            patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross-entropy, seq-chunked so (B,S,V) never materializes."""
    hidden = forward(params, cfg, tokens, patches, frames)
    b, s, d = hidden.shape
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros((b, 1), tokens.dtype)], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((b, s - 1), jnp.float32), jnp.zeros((b, 1), jnp.float32)], 1)

    chunk = min(cfg.lmhead_chunk, s)
    if s % chunk != 0:
        chunk = s
    nchunk = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nchunk, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nchunk, chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(b, nchunk, chunk), 1, 0)

    def chunk_loss(_, hmt):
        h, t, m = hmt
        logits = logits_for(params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return None, jnp.sum((logz - gold) * m)

    _, losses = jax.lax.scan(chunk_loss, None, (hc, tc, mc))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------- decode --

def init_cache(params: Params, cfg: ModelConfig, batch: int, seq_len: int,
               frames: Optional[jax.Array] = None) -> Dict[str, Any]:
    """Decode cache for every layer (+ encoder cross K/V for enc-dec)."""
    dtype = _dtype(cfg)
    n_blocks, n_tail = cfg.pattern_blocks

    def cache_for(char: str):
        if char in ("g", "l"):
            return init_kv_cache(cfg, batch, seq_len, char == "l", dtype)
        if char == "r":
            return init_rglru_cache(cfg, batch, dtype)
        return init_mamba_cache(cfg, batch, dtype)

    def stacked(char: str):
        c = cache_for(char)
        if cfg.scan_layers and n_blocks > 1:
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_blocks,) + a.shape), c)
        return c

    cache: Dict[str, Any] = {
        "blocks": {f"pos{i}_{c}": stacked(c)
                   for i, c in enumerate(cfg.layer_pattern)},
        "tail": {
            f"tail{i}_{cfg.layer_pattern[i % len(cfg.layer_pattern)]}":
                cache_for(cfg.layer_pattern[i % len(cfg.layer_pattern)])
            for i in range(n_tail)
        },
        "pos": jnp.zeros((), jnp.int32),
    }
    if cfg.enc_layers > 0:
        enc_out = encode(params, cfg, frames) if frames is not None else \
            jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype)
        cache["enc_out"] = enc_out
    return cache


def _decode_block(p: Params, cfg: ModelConfig, char: str, x: jax.Array,
                  c: Dict[str, jax.Array], pos: jax.Array,
                  enc_out: Optional[jax.Array]):
    h = _norm(cfg, x, p["norm1"])
    if char in ("g", "l"):
        h, c = decode_attend(p["attn"], cfg, h, c, pos, local=(char == "l"))
    elif char == "r":
        h, c = rglru_decode(p["rglru"], cfg, h, c)
    else:
        h, c = mamba_decode(p["mamba"], cfg, h, c)
    if cfg.post_norms:
        h = _norm(cfg, h, p["norm1_post"])
    x = x + h
    if enc_out is not None and "cross" in p:
        h = _norm(cfg, x, p["norm_cross"])
        ckv = cross_kv_from_encoder(cfg, enc_out, p)
        h, _ = decode_attend(p["cross"], cfg, h, c, pos, cross_kv=ckv)
        x = x + h
    if "norm2" in p:
        h = _norm(cfg, x, p["norm2"])
        if "moe" in p:
            h = moe_ffn(p["moe"], cfg, h)
        else:
            h = mlp(p["mlp"], h, cfg.mlp)
        if cfg.post_norms:
            h = _norm(cfg, h, p["norm2_post"])
        x = x + h
    return x, c


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One serving step: tokens (B,) -> logits (B, V), updated cache."""
    pos = cache["pos"]
    x = embed_tokens(params, cfg, tokens[:, None], None)
    if cfg.rope_theta == 0 and "pos_embed" in params:
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1)[None]
    enc_out = cache.get("enc_out")
    n_blocks, _ = cfg.pattern_blocks
    pattern = cfg.layer_pattern
    block_names = [f"pos{i}_{c}" for i, c in enumerate(pattern)]

    def one_repeat(x, rep):
        rep_params, rep_cache = rep
        new_cache = {}
        for name, char in zip(block_names, pattern):
            x, new_cache[name] = _decode_block(
                rep_params[name], cfg, char, x, rep_cache[name], pos, enc_out)
        return x, new_cache

    if cfg.scan_layers and n_blocks > 1:
        x, new_block_cache = jax.lax.scan(
            one_repeat, x, (params["blocks"], cache["blocks"]))
    else:
        x, new_block_cache = one_repeat(x, (params["blocks"], cache["blocks"]))

    new_tail = {}
    for name in sorted(cache["tail"]):
        char = name.split("_")[-1]
        x, new_tail[name] = _decode_block(
            params["tail"][name], cfg, char, x, cache["tail"][name], pos, enc_out)

    x = _norm(cfg, x, params["final_norm"])
    logits = logits_for(params, cfg, x)[:, 0]
    new_cache = dict(cache, blocks=new_block_cache, tail=new_tail, pos=pos + 1)
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            seq_len: int, patches: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None) -> Tuple[jax.Array, Dict[str, Any]]:
    """Prompt processing: last-position logits + a fully primed cache."""
    hidden, block_caches, tail_caches, enc_out = forward(
        params, cfg, tokens, patches, frames, cache_size=seq_len)
    logits = logits_for(params, cfg, hidden[:, -1:])[:, 0]
    cache: Dict[str, Any] = {
        "blocks": block_caches,
        "tail": tail_caches,
        "pos": jnp.asarray(tokens.shape[1], jnp.int32),
    }
    if enc_out is not None:
        cache["enc_out"] = enc_out
    return logits, cache
