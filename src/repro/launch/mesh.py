"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (1 CPU device in tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
