import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove memory fits, and extract roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Results (memory analysis, cost analysis, HLO-derived roofline terms) are
written as JSON under experiments/dryrun/ for EXPERIMENTS.md.
"""
import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, SHAPES, get_config
from ..distributed.sharding import make_rules, sharding_context
from ..optim import AdamWConfig
from .mesh import make_production_mesh
from . import roofline as rf
from . import steps as st

# Cells skipped by design (see DESIGN.md §4): long_500k needs a
# sub-quadratic trunk; full-attention archs cannot represent a 524k-token
# KV pass without changing the architecture.
def cell_skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "full-attention arch: 524k-token cache is quadratic (skip per brief)"
    return ""


# variants that transform the model config instead of the sharding rules
CFG_VARIANTS = {
    "ssmchunk": lambda cfg: cfg.with_(ssm_chunk=16),
}

VARIANTS = {
    # Megatron-style sequence parallelism: residual stream sharded over
    # the model axis on SEQ (not d_model) — §Perf iteration.
    "sp": {"seq": "model", "embed": None},
    # activations fully replicated across model axis (ablation)
    "replicated": {"embed": None},
    # column-only weight sharding: model axis never holds a contraction
    # dim -> no partial-sum (f32-upcast) all-reduces, only bf16 gathers
    "colshard": {"row_in": "data", "row_out": "model"},
}


def rules_for(cfg, shape, mesh, variant: str = ""):
    """Per-cell logical->physical overrides."""
    overrides = {}
    if variant and variant in VARIANTS:
        overrides.update(VARIANTS[variant])
    if shape.kind == "decode":
        # shard the KV cache over the model axis: heads when divisible,
        # else the sequence dim (long-context sequence sharding)
        if cfg.n_kv_heads and cfg.n_kv_heads % mesh.shape["model"] != 0:
            overrides["cache_seq"] = "model"
            overrides["kv_heads"] = None
        else:
            overrides["cache_seq"] = None
    return make_rules(mesh, overrides)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             variant: str = ""):
    cfg = get_config(arch)
    if variant in CFG_VARIANTS:
        cfg = CFG_VARIANTS[variant](cfg)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape_name}_{mesh_name}" + (f"_{variant}" if variant else "")
    skip = cell_skip_reason(cfg, shape)
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "variant": variant}
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        _write(out_dir, tag, record)
        print(f"[dryrun] {tag}: SKIPPED ({skip})")
        return record

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = rules_for(cfg, shape, mesh, variant)
    try:
        with sharding_context(mesh, rules), mesh:
            specs = st.input_specs(cfg, shape)
            shardings = st.input_shardings(cfg, shape, specs)
            if shape.kind == "train":
                fn = st.make_train_step(cfg, AdamWConfig())
                args = (specs["params"], specs["opt_state"], specs["batch"])
                in_sh = (shardings["params"], shardings["opt_state"],
                         shardings["batch"])
            elif shape.kind == "prefill":
                fn = st.make_prefill_step(cfg, shape.seq_len)
                args = (specs["params"], specs["batch"])
                in_sh = (shardings["params"], shardings["batch"])
            else:
                fn = st.make_serve_step(cfg)
                args = (specs["params"], specs["cache"], specs["tokens"])
                in_sh = (shardings["params"], shardings["cache"],
                         shardings["tokens"])
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            record["memory"] = _mem_dict(mem)
            ca = compiled.cost_analysis()
            record["xla_cost"] = {k: float(v) for k, v in (ca or {}).items()
                                  if isinstance(v, (int, float))
                                  and k in ("flops", "bytes accessed")}
            hlo = compiled.as_text()
            mflops = rf.model_flops(cfg, shape)
            roof = rf.analyze(hlo, model_flops_global=mflops, n_chips=n_chips)
            record["roofline"] = roof.to_dict()
            record["model_flops_global"] = mflops
            record["n_chips"] = n_chips
            record["lower_s"] = round(t_lower, 1)
            record["compile_s"] = round(t_compile, 1)
            record["status"] = "ok"
            print(f"[dryrun] {tag}: OK  lower={t_lower:.0f}s "
                  f"compile={t_compile:.0f}s bottleneck={roof.bottleneck} "
                  f"terms(ms): c={roof.compute_s*1e3:.2f} "
                  f"m={roof.memory_s*1e3:.2f} coll={roof.collective_s*1e3:.2f} "
                  f"useful={roof.useful_ratio:.2f}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = "failed"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {tag}: FAILED {record['error']}")
    _write(out_dir, tag, record)
    return record


def _mem_dict(mem):
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _write(out_dir, tag, record):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="",
                    choices=[""] + list(VARIANTS) + list(CFG_VARIANTS))
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))
    ok = fail = skip = 0
    for a, s, mp in cells:
        r = run_cell(a, s, mp, args.out, args.variant)
        ok += r["status"] == "ok"
        fail += r["status"] == "failed"
        skip += r["status"] == "skipped"
    print(f"[dryrun] done: {ok} ok, {skip} skipped, {fail} failed")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
