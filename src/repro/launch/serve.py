"""Batched serving driver: prefill a batch of prompts, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, smoke
from ..data.pipeline import TokenStream
from ..distributed.sharding import make_rules, sharding_context
from ..models import lm
from .mesh import make_local_mesh, make_production_mesh
from .steps import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=(args.mesh == "multi")))
    seq_len = args.prompt_len + args.gen

    with sharding_context(mesh, make_rules(mesh)), mesh:
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
        stream = TokenStream(cfg.vocab, seed=args.seed)
        prompts = jnp.asarray(stream.batch(0, args.batch, args.prompt_len))
        frames = (jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (args.batch, cfg.enc_seq, cfg.d_model)).astype(np.float32))
            if cfg.enc_layers else None)
        patches = (jnp.asarray(np.random.default_rng(1).normal(
            0, 1, (args.batch, cfg.vision_patches, cfg.d_model)).astype(np.float32))
            if cfg.vision_patches else None)

        t0 = time.perf_counter()
        logits, cache = jax.jit(
            lambda p, t: lm.prefill(p, cfg, t, seq_len, patches=patches,
                                    frames=frames))(params, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        step_fn = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        tokens = jnp.argmax(logits, -1)
        out = [tokens]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = step_fn(params, cache, tokens)
            tokens = jnp.argmax(logits, -1)
            out.append(tokens)
        jax.block_until_ready(tokens)
        t_decode = time.perf_counter() - t0
        gen = np.stack([np.asarray(t) for t in out], 1)
        print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
              f"{t_prefill*1e3:.1f}ms; decode {args.gen - 1} steps in "
              f"{t_decode*1e3:.1f}ms "
              f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
        print(f"[serve] sample continuation: {gen[0][:12].tolist()}")
        # gen holds integer token ids — isnan on it is vacuously false; the
        # meaningful health check is on the final decode-step logits.
        assert np.all(np.isfinite(np.asarray(logits))), "non-finite logits"


if __name__ == "__main__":
    main()
