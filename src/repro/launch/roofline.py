"""Roofline analysis from compiled (SPMD-partitioned) HLO.

XLA's built-in cost analysis counts a while-loop body ONCE and reports
per-device numbers post-partitioning (verified empirically on this jax
build) — useless for scanned-layer models.  This module parses the
optimized HLO text instead:

  * computations are parsed into blocks; `while` ops multiply their body's
    cost by the trip count recovered from the loop condition's constant;
  * FLOPs come from `dot(`/`convolution(` lines (2 x result x contraction);
  * HBM bytes are approximated as the result bytes of every materializing
    op (fusions, dots, copies, slices, collectives) — fused interiors
    excluded, mirroring what actually hits HBM;
  * collective bytes take the largest shape on each collective line
    (local, i.e. per-device), x2 for all-reduce (reduce + broadcast
    phases of a ring).

All numbers are per-chip.  Hardware constants per the brief: 197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI (TPU v5e-class).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# Serving-dtype aliases (the repo's ProjSpec.infer_dtype vocabulary and
# numpy-style names) onto the HLO dtype table above.
_DTYPE_ALIASES = {
    "fp32": "f32", "float32": "f32", "int8": "s8", "bfloat16": "bf16",
    "float16": "f16",
}


def dtype_bytes(dtype: str) -> int:
    """Bytes per element for an HLO dtype name OR a serving-dtype alias
    (fp32/bf16/int8...)."""
    key = _DTYPE_ALIASES.get(dtype, dtype)
    try:
        return _DTYPE_BYTES[key]
    except KeyError:
        raise ValueError(f"unknown dtype {dtype!r}; known: "
                         f"{sorted(_DTYPE_BYTES)} + aliases "
                         f"{sorted(_DTYPE_ALIASES)}") from None


def bcpnn_fwd_traffic(batch: int, n_in: int, n_out: int,
                      weight_dtype: str = "fp32",
                      act_dtype: str = "fp32",
                      n_hc: int = 1) -> Dict[str, float]:
    """First-principles HBM traffic/FLOPs of one inference-only fused
    BCPNN forward (support matmul + bias + per-HC softmax), parameterized
    by the serving dtype — the paper's Eq. 2-5 methodology with
    bytes-per-element as a free variable, so bf16/int8 roofline rows are
    honest about their bandwidth win instead of assuming f32.

    Model (weights stream once, activations once, output written f32):
      FLOPs = 2·B·Ni·Nj (support) + ~6·B·Nj (bias + softmax epilogue)
      Bytes = act·B·Ni (x) + w·(Ni·Nj + Nj) (weights + bias)
              + 4·n_hc (int8 per-HC scale vector, else 0) + 4·B·Nj (out)

    The EMA/learn traffic is deliberately NOT parameterized: trace state
    is always fp32 (DESIGN.md §8) — only the inference path changes
    dtype.
    """
    wb = dtype_bytes(weight_dtype)
    ab = dtype_bytes(act_dtype)
    flops = 2.0 * batch * n_in * n_out + 6.0 * batch * n_out
    bytes_ = (ab * batch * n_in + wb * (n_in * n_out + n_out)
              + (4.0 * n_hc if wb == 1 else 0.0) + 4.0 * batch * n_out)
    return {"flops": flops, "bytes": bytes_,
            "intensity": flops / bytes_}


_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather(", "all-reduce(", "reduce-scatter(",
                "all-to-all(", "collective-permute(")
_MATERIALIZING = re.compile(
    r"= \w+\[[\d,]*\][^ ]* (fusion|dot|convolution|copy|dynamic-slice|"
    r"dynamic-update-slice|gather|scatter|slice|concatenate|broadcast|"
    r"transpose|reduce|select-and-scatter|sort|iota|rng|pad|reshape|"
    r"all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"custom-call)\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _shapes_on(line: str) -> List[int]:
    return [_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line)]


def _result_shape(line: str) -> Optional[Tuple[str, List[int]]]:
    m = re.search(r"= (\w+)\[([\d,]*)\]", line)
    if not m:
        return None
    dims = [int(x) for x in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


_OPERANDS_RE = re.compile(r"\((%[\w\.\-]+(?:, %[\w\.\-]+)*)\)")
_DEF_RE = re.compile(r"^(?:ROOT )?%([\w\.\-]+) = (\w+)\[([\d,]*)\]")


def _dot_flops(line: str, shapes: Dict[str, Tuple[str, List[int]]]) -> float:
    res = _result_shape(line)
    if res is None:
        return 0.0
    _, rdims = res
    result_elems = math.prod(rdims) if rdims else 1
    # contraction size from the (name-resolved) lhs shape
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contraction = 1
    op_part = line.split(" dot(", 1)[1] if " dot(" in line else line
    names = re.findall(r"%([\w\.\-]+)", op_part)
    if m and names and names[0] in shapes:
        lhs_dims = shapes[names[0]][1]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contraction *= lhs_dims[idx]
    return 2.0 * result_elems * contraction


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, float] = dataclasses.field(default_factory=dict)


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self.shapes: Dict[str, Tuple[str, List[int]]] = {}
        self._parse(hlo_text)
        self._memo: Dict[str, CompCost] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            m = re.match(r"(ENTRY )?%([\w\.\-]+)[ ]*\(.*\) -> .* \{", line)
            if m:
                cur = m.group(2)
                self.comps[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is not None:
                stripped = line.strip()
                self.comps[cur].append(stripped)
                d = _DEF_RE.match(stripped)
                if d:
                    dims = ([int(x) for x in d.group(3).split(",")]
                            if d.group(3) else [])
                    self.shapes[d.group(1)] = (d.group(2), dims)

    def _operand_bytes(self, line: str, op: str) -> List[int]:
        """Byte sizes of an op's named operands (resolved via the def map)."""
        part = line.split(" " + op, 1)
        if len(part) < 2:
            return []
        out = []
        m = re.match(r"\(([^)]*)\)", part[1])
        if not m:
            return []
        for name in re.findall(r"%([\w\.\-]+)", m.group(1)):
            if name in self.shapes:
                dt, dims = self.shapes[name]
                out.append(math.prod(dims or [1]) * _DTYPE_BYTES.get(dt, 4))
        return out

    def _trip_count(self, cond: str) -> int:
        best = 1
        for line in self.comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
        return best

    def cost(self, comp: Optional[str] = None) -> CompCost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = CompCost()
        for line in self.comps.get(comp, []):
            if " while(" in line:
                mb = re.search(r"body=%([\w\.\-]+)", line)
                mc = re.search(r"condition=%([\w\.\-]+)", line)
                if mb and mc:
                    trips = self._trip_count(mc.group(1))
                    sub = self.cost(mb.group(1))
                    total.flops += trips * sub.flops
                    total.bytes += trips * sub.bytes
                    total.coll_bytes += trips * sub.coll_bytes
                    for k, v in sub.coll_detail.items():
                        total.coll_detail[k] = total.coll_detail.get(k, 0) + trips * v
                continue
            if " dot(" in line or " convolution(" in line:
                total.flops += _dot_flops(line, self.shapes)
            m = re.search(r"(?:calls|to_apply)=%([\w\.\-]+)", line)
            if m and " fusion(" in line:
                total.flops += self.cost(m.group(1)).flops
            elif m and (" call(" in line or " conditional(" in line):
                sub = self.cost(m.group(1))
                total.flops += sub.flops
                total.bytes += sub.bytes
                total.coll_bytes += sub.coll_bytes
            for cname in _COLLECTIVES:
                if " " + cname in line:
                    shapes = _shapes_on(line) + self._operand_bytes(line, cname)
                    if shapes:
                        b = max(shapes)
                        factor = 2.0 if cname == "all-reduce(" else 1.0
                        total.coll_bytes += factor * b
                        key = cname.rstrip("(")
                        total.coll_detail[key] = total.coll_detail.get(key, 0) + factor * b
                    break
            if _MATERIALIZING.search(line):
                res = _result_shape(line)
                if res:
                    dt, dims = res
                    total.bytes += math.prod(dims or [1]) * _DTYPE_BYTES.get(dt, 4)
        self._memo[comp] = total
        return total


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes: float
    coll_bytes: float
    coll_detail: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_chip: float = 0.0
    useful_ratio: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(hlo_text: str, model_flops_global: float = 0.0,
            n_chips: int = 1) -> Roofline:
    c = HloAnalyzer(hlo_text).cost()
    # bytes counted result-side only; reads roughly double the traffic
    hbm_bytes = 2.0 * c.bytes
    compute_s = c.flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    coll_s = c.coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_global / max(1, n_chips)
    return Roofline(
        flops=c.flops, bytes=hbm_bytes, coll_bytes=c.coll_bytes,
        coll_detail=c.coll_detail, compute_s=compute_s, memory_s=memory_s,
        collective_s=coll_s, bottleneck=bottleneck,
        model_flops_per_chip=mf,
        useful_ratio=(mf / c.flops) if c.flops else 0.0,
    )


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode: D = batch
    tokens; train: x3 is already in the 6 (fwd+bwd)."""
    n = param_count_active(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # one token per sequence


def param_count_active(cfg) -> float:
    """Active parameters per token (MoE counts top-k experts + router)."""
    d, v = cfg.d_model, cfg.vocab_padded
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    per_layer = {}
    attn = d * cfg.n_heads * cfg.head_dim * 2 + d * cfg.n_kv_heads * cfg.head_dim * 2
    glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    mlp_p = glu * d * cfg.d_ff
    total = emb
    for i, ch in enumerate(cfg.layer_pattern):
        n_of_this = cfg.n_layers // len(cfg.layer_pattern) + (
            1 if i < cfg.n_layers % len(cfg.layer_pattern) else 0)
        if ch in ("g", "l"):
            layer = attn + (cfg.n_experts_active * mlp_p + d * cfg.n_experts
                            if cfg.n_experts else mlp_p)
        elif ch == "m":
            di = cfg.d_inner
            layer = (d * 2 * di + di * d + cfg.d_conv * di
                     + di * (cfg.dt_rank_eff + 2 * cfg.ssm_state)
                     + cfg.dt_rank_eff * di + di * cfg.ssm_state)
        else:  # rg-lru
            w = cfg.lru_width_eff
            layer = d * w * 2 + w * d + w * w * 2 + cfg.d_conv * w + mlp_p
        total += n_of_this * layer
    if cfg.enc_layers:
        total += cfg.enc_layers * (attn + mlp_p)
    return float(total)
