"""End-to-end training driver with checkpoint/restart and straggler logs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Runs on whatever devices exist (1 CPU here; the production meshes via
--mesh single|multi on real hardware).  Restart resumes from the latest
checkpoint, on a possibly different device count (elastic restore), and
the data pipeline reproduces the exact batch sequence from the step id.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get_config, smoke
from ..data.pipeline import Prefetcher, TokenStream
from ..distributed.fault import StepTimer, describe_failure_domains
from ..distributed.sharding import make_rules, sharding_context
from ..checkpoint import CheckpointManager
from ..models import lm
from ..models.params import param_shardings
from ..optim import AdamWConfig, init_error_state, init_opt_state
from .mesh import make_local_mesh, make_production_mesh
from .steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", choices=["local", "single", "multi"],
                    default="local")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"[train] arch={cfg.name} mesh={describe_failure_domains(mesh)}")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    stream = TokenStream(cfg.vocab, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with sharding_context(mesh, make_rules(mesh)), mesh:
        params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = init_opt_state(params)
        if args.compress_grads:
            opt_state["err"] = init_error_state(params)
        start_step = 0
        if mgr is not None and mgr.latest_step() is not None:
            start_step = mgr.latest_step()
            shardings = {"params": param_shardings(params),
                         "opt": jax.tree.map(lambda _: None, opt_state)}
            state = mgr.restore(start_step, {"params": params, "opt": opt_state},
                                shardings)
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, compress=args.compress_grads),
            donate_argnums=(0, 1))

        def make_batch(step):
            b = {"tokens": stream.batch(step, args.batch, args.seq)}
            if cfg.vision_patches:
                rng = np.random.default_rng(step)
                b["patches"] = rng.normal(0, 1, (args.batch, cfg.vision_patches,
                                                 cfg.d_model)).astype(np.float32)
            if cfg.enc_layers:
                rng = np.random.default_rng(step + 1)
                b["frames"] = rng.normal(0, 1, (args.batch, cfg.enc_seq,
                                                cfg.d_model)).astype(np.float32)
            return b

        prefetch = Prefetcher(make_batch, start_step)
        timer = StepTimer()
        losses = []
        try:
            for _ in range(start_step, args.steps):
                step_id, batch = prefetch.next()
                batch = jax.tree.map(jnp.asarray, batch)
                timer.start()
                loss, params, opt_state = step_fn(params, opt_state, batch)
                loss = float(loss)
                dt = timer.stop(step_id)
                losses.append(loss)
                if step_id % args.log_every == 0 or step_id == args.steps - 1:
                    tps = args.batch * args.seq / dt
                    print(f"[train] step {step_id} loss={loss:.4f} "
                          f"{dt*1e3:.0f}ms ({tps:.0f} tok/s)")
                if mgr is not None and (step_id + 1) % args.ckpt_every == 0:
                    mgr.save(step_id + 1, {"params": params, "opt": opt_state})
        finally:
            prefetch.close()
        if not losses:  # resumed at or past --steps: nothing left to run,
            # and saving here would mislabel step-`start_step` params as
            # a step-`args.steps` checkpoint
            print(f"[train] checkpoint already at step {start_step}; "
                  f"no steps to run")
            return
        if mgr is not None:
            mgr.save(args.steps, {"params": params, "opt": opt_state},
                     blocking=True)
        if timer.events:
            print(f"[train] straggler events: {timer.events}")
        print(f"[train] median step {timer.median*1e3:.0f}ms; "
              f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
        # Progress check on windowed means: single-step losses are noisy,
        # and a resumed run may only execute a handful of steps (its
        # losses start from the already-trained level), so the strict
        # last < first comparison only applies to runs long enough to
        # average over.
        if len(losses) >= 8:
            w = max(1, len(losses) // 4)
            head_loss = float(np.mean(losses[:w]))
            tail_loss = float(np.mean(losses[-w:]))
            assert tail_loss < head_loss, \
                f"loss did not decrease ({head_loss:.4f} -> {tail_loss:.4f})"


if __name__ == "__main__":
    main()
