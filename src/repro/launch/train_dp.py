"""Fault-tolerant data-parallel training driver (DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.train_dp --smoke

Phases:
  1. synthetic task + single-device baseline fit (the bit-exactness
     reference; the default --train-n does NOT divide the batch, so the
     padded-tail masked path is exercised end to end);
  2. data-parallel fit on the full mesh: the SAME layerwise-greedy
     schedule driven through the shard_map scan-over-batches epoch
     programs — ``--smoke`` asserts the final state is bit-identical to
     the single-device fit, and reports images/s + scaling;
  3. kill-resume: a fresh DP fit checkpoints every ``--ckpt-every``
     batches and a fault hook raises ``WorkerLost`` mid-schedule; the
     driver then rebuilds the largest surviving mesh with
     ``elastic_mesh`` (one device is "lost"), restores the latest
     checkpoint, and resumes from its cursor — ``--smoke`` asserts the
     recovered state is STILL bit-identical to the uninterrupted run
     (column-sharded DP is exact for any shard count), and the recovery
     overhead is reported.

``--devices N`` forces an N-way CPU mesh (via
``--xla_force_host_platform_device_count``, so it must act before jax
initializes — this module therefore imports jax inside ``main``).
``--json PATH`` writes the measured numbers for benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    p.add_argument("--smoke", action="store_true",
                   help="assert bit-exactness + recovery, tiny workload")
    p.add_argument("--devices", type=int, default=2,
                   help="CPU device count to force (data-axis width)")
    p.add_argument("--side", type=int, default=12)
    p.add_argument("--depth", type=int, default=2)
    p.add_argument("--classes", type=int, default=5)
    p.add_argument("--train-n", type=int, default=328,
                   help="train samples (default leaves a padded tail)")
    p.add_argument("--test-n", type=int, default=256)
    p.add_argument("--epochs", type=int, default=2)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--ckpt-every", type=int, default=2,
                   help="checkpoint cadence in batches for the kill phase")
    p.add_argument("--kill-at-chunk", type=int, default=3,
                   help="which chunk boundary raises the simulated loss")
    p.add_argument("--warmup", action="store_true",
                   help="one untimed fit first (compile outside timings)")
    p.add_argument("--no-single", action="store_true",
                   help="skip the single-device reference (bench mode)")
    p.add_argument("--no-kill", action="store_true",
                   help="skip the kill-resume phase (pure scaling rows)")
    p.add_argument("--json", type=str, default=None,
                   help="write measured numbers to this path")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count="
            f"{args.devices}").strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax
    import numpy as np

    from ..configs.bcpnn_models import deep_synth_spec
    from ..core import Trainer
    from ..data.synthetic import encode_images, make_synthetic
    from ..distributed.fault import (WorkerLost, describe_failure_domains,
                                     elastic_mesh)

    spec = deep_synth_spec(side=args.side, depth=args.depth,
                           n_classes=args.classes, backend="jnp")
    ds = make_synthetic(args.train_n, args.test_n, args.side, args.classes,
                        seed=0)
    xtr, xte = encode_images(ds.x_train), encode_images(ds.x_test)
    ytr, yte = ds.y_train, ds.y_test
    n_img = len(xtr) * args.epochs * spec.depth

    def fit_once(trainer, **kw):
        t0 = time.perf_counter()
        stats = trainer.fit(xtr, ytr, epochs=args.epochs, batch=args.batch,
                            **kw)
        return time.perf_counter() - t0, stats

    out = {"devices": args.devices, "train_n": len(xtr),
           "batch": args.batch, "epochs": args.epochs,
           "depth": spec.depth}

    # ---- phase 1: single-device reference ------------------------------
    t_single = None
    ref = None
    if not args.no_single:
        tr1 = Trainer(spec, seed=0)
        if args.warmup:
            fit_once(tr1)
            tr1.reset(seed=0)
        t_single, _ = fit_once(tr1)
        ref = tr1.state
        acc1 = tr1.evaluate(xte, yte, batch=args.batch)
        out["single_s"] = t_single
        out["single_images_per_s"] = n_img / t_single
        out["single_acc"] = float(acc1)
        print(f"[train-dp] single-device: {t_single:.2f}s "
              f"({n_img / t_single:.0f} img/s), acc {acc1:.3f}")

    # ---- phase 2: data-parallel fit on the full mesh -------------------
    mesh = elastic_mesh((args.devices,), ("data",))
    print(f"[train-dp] mesh: {describe_failure_domains(mesh)}")
    tr2 = Trainer(spec, seed=0, mesh=mesh)
    if args.warmup:
        fit_once(tr2)
        tr2.reset(seed=0)
    t_dp, _ = fit_once(tr2)
    acc2 = tr2.evaluate(xte, yte, batch=args.batch)
    out["dp_s"] = t_dp
    out["dp_images_per_s"] = n_img / t_dp
    out["dp_acc"] = float(acc2)
    if t_single is not None:
        out["scaling_x"] = t_single / t_dp
    print(f"[train-dp] {args.devices}-way DP: {t_dp:.2f}s "
          f"({n_img / t_dp:.0f} img/s), acc {acc2:.3f}"
          + (f", scaling {t_single / t_dp:.2f}x" if t_single else ""))
    if ref is not None:
        same = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(ref),
                            jax.tree_util.tree_leaves(tr2.state)))
        print(f"[train-dp] DP state bit-identical to single-device: {same}")
        if args.smoke:
            assert same, "DP fit diverged from the single-device fit"
            assert abs(acc1 - acc2) == 0.0

    # ---- phase 3: kill-resume via elastic_mesh -------------------------
    if args.no_kill:
        if args.json:
            with open(args.json, "w") as f:
                json.dump(out, f, indent=2)
        print("[train-dp] smoke OK" if args.smoke else "[train-dp] done")
        return 0
    with tempfile.TemporaryDirectory() as ckpt_dir:
        chunks = {"n": 0}

        def fault_hook(cursor):
            chunks["n"] += 1
            if chunks["n"] == args.kill_at_chunk:
                raise WorkerLost(
                    f"simulated device loss at chunk {chunks['n']} "
                    f"(cursor {cursor})")

        tr3 = Trainer(spec, seed=0, mesh=mesh)
        t_kill0 = time.perf_counter()
        try:
            tr3.fit(xtr, ytr, epochs=args.epochs, batch=args.batch,
                    ckpt_dir=ckpt_dir, ckpt_every_batches=args.ckpt_every,
                    on_chunk=fault_hook)
            raise SystemExit("[train-dp] fault hook never fired — "
                             "lower --kill-at-chunk")
        except WorkerLost as e:
            t_killed = time.perf_counter() - t_kill0
            print(f"[train-dp] {e} after {t_killed:.2f}s")
        # Recovery ladder: largest mesh from the survivors, restore the
        # latest checkpoint, resume from its cursor.
        survivors = jax.devices()[:-1] if args.devices > 1 else jax.devices()
        mesh_r = elastic_mesh((args.devices,), ("data",), devices=survivors)
        print(f"[train-dp] rebuilt mesh from {len(survivors)} survivors: "
              f"{describe_failure_domains(mesh_r)}")
        t_rec0 = time.perf_counter()
        tr_r = Trainer(spec, seed=0, mesh=mesh_r)
        tr_r.fit(xtr, ytr, epochs=args.epochs, batch=args.batch,
                 ckpt_dir=ckpt_dir, ckpt_every_batches=args.ckpt_every,
                 resume=True)
        t_resume = time.perf_counter() - t_rec0
        acc_r = tr_r.evaluate(xte, yte, batch=args.batch)
        overhead = t_killed + t_resume - t_dp
        out["kill_resume_s"] = t_killed + t_resume
        out["recovery_overhead_s"] = overhead
        out["resumed_acc"] = float(acc_r)
        same_r = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(tr2.state),
                            jax.tree_util.tree_leaves(tr_r.state)))
        out["resumed_bit_identical"] = bool(same_r)
        print(f"[train-dp] kill-resume on {len(survivors)} device(s): "
              f"{t_killed + t_resume:.2f}s total "
              f"({overhead:+.2f}s vs uninterrupted), acc {acc_r:.3f}, "
              f"bit-identical {same_r}")
        if args.smoke:
            assert same_r, ("resumed fit diverged from the uninterrupted "
                            "run")
            assert float(acc_r) == float(acc2)
        if tr_r.timer is not None and tr_r.timer.events:
            print(f"[train-dp] straggler events: "
                  f"{len(tr_r.timer.events)} (last: "
                  f"{tr_r.timer.events[-1]})")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[train-dp] wrote {args.json}")
    print("[train-dp] smoke OK" if args.smoke else "[train-dp] done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
