"""jit-able train / prefill / serve step builders + abstract input specs.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the real train/serve drivers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import named_sharding
from ..models import lm
from ..models.params import cache_shardings, param_shardings
from ..optim import AdamWConfig, apply_updates, compress_grads, init_opt_state


# ------------------------------------------------------------- factories --

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    compress: bool = False):
    """Returns train_step(params, opt_state, batch) -> (loss, params, opt)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return lm.lm_loss(p, cfg, batch["tokens"],
                              patches=batch.get("patches"),
                              frames=batch.get("frames"))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        if compress:
            grads, new_err = compress_grads(grads, opt_state["err"])
        new_params, new_opt = apply_updates(
            opt_cfg, params, grads,
            {k: v for k, v in opt_state.items() if k != "err"})
        if compress:
            new_opt["err"] = new_err
        return loss, new_params, new_opt

    return train_step


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, tokens) -> (logits, cache)."""

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cfg, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ModelConfig, seq_len: int):
    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch["tokens"], seq_len,
                          patches=batch.get("patches"),
                          frames=batch.get("frames"))
    return prefill_step


# ----------------------------------------------------------- input specs --

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract specs for one host batch (training / prefill)."""
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.vision_patches > 0:
        specs["patches"] = _sds((b, cfg.vision_patches, cfg.d_model), jnp.bfloat16)
    if cfg.enc_layers > 0:
        specs["frames"] = _sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return specs


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    out = {"tokens": named_sharding(("batch", None),
                                    (shape.global_batch, shape.seq_len))}
    if cfg.vision_patches > 0:
        out["patches"] = named_sharding(
            ("batch", None, "embed"),
            (shape.global_batch, cfg.vision_patches, cfg.d_model))
    if cfg.enc_layers > 0:
        out["frames"] = named_sharding(
            ("batch", None, "embed"),
            (shape.global_batch, cfg.enc_seq, cfg.d_model))
    return out


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(aparams):
    return jax.eval_shape(init_opt_state, aparams)


def abstract_cache(cfg: ModelConfig, batch: int, seq_len: int):
    def build(params):
        return lm.init_cache(params, cfg, batch, seq_len, frames=None)
    return jax.eval_shape(build, abstract_params(cfg))


def opt_shardings(aopt, pshardings):
    """Optimizer moments inherit the param shardings; step is replicated."""
    return {
        "mu": pshardings,
        "nu": pshardings,
        "step": named_sharding((), ()),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Everything the lowered step consumes, as ShapeDtypeStructs."""
    aparams = abstract_params(cfg)
    if shape.kind == "train":
        return {
            "params": aparams,
            "opt_state": abstract_opt_state(aparams),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": aparams, "batch": batch_specs(cfg, shape)}
    # decode: one new token against a seq_len cache
    return {
        "params": aparams,
        "cache": abstract_cache(cfg, shape.global_batch, shape.seq_len),
        "tokens": _sds((shape.global_batch,), jnp.int32),
    }


def input_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    specs: Dict[str, Any]) -> Dict[str, Any]:
    ps = param_shardings(specs["params"])
    if shape.kind == "train":
        return {
            "params": ps,
            "opt_state": opt_shardings(specs["opt_state"], ps),
            "batch": batch_shardings(cfg, shape),
        }
    if shape.kind == "prefill":
        return {"params": ps, "batch": batch_shardings(cfg, shape)}
    return {
        "params": ps,
        "cache": cache_shardings(specs["cache"]),
        "tokens": named_sharding(("batch",), (shape.global_batch,)),
    }
