"""BCPNN serving driver: train-or-load a checkpointed deep network, serve
an open-loop synthetic request stream through the microbatched engine, and
report latency/throughput — optionally with the online-learning mode
folding a label stream into the readout while traffic flows.

    PYTHONPATH=src python -m repro.launch.serve_bcpnn --smoke

Phases:
  1. obtain a network — restore from --ckpt-dir when a checkpoint exists
     (the spec rides in the manifest), else train on the synthetic task
     and checkpoint it;
  2. inference-only serving: open-loop Poisson load, p50/p99 + images/s;
  3. online learning (unless --no-online): the readout is re-initialized
     (cold), then RELEARNED from the feedback stream between inference
     microbatches — served accuracy recovers toward the trained baseline
     while requests keep completing (the runtime analogue of switching
     the paper's training bitstream in, without un-deploying inference).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile

import jax

from ..checkpoint import CheckpointManager
from ..configs.bcpnn_models import deep_synth_spec
from ..core import (
    Trainer, evaluate_padded, init_deep, init_projection, spec_from_dict,
)
from ..data.synthetic import encode_images, make_synthetic
from ..serve import BCPNNService, run_open_loop


def _report(tag: str, snap: dict, extra: str = "") -> None:
    print(f"[serve-bcpnn] {tag}: {snap['completed']:.0f}/"
          f"{snap['submitted']:.0f} served, {snap['images_per_s']:.1f} img/s, "
          f"p50 {snap['p50_ms']:.1f}ms p99 {snap['p99_ms']:.1f}ms, "
          f"batch occupancy {snap['batch_occupancy']*100:.0f}%, "
          f"{snap['learn_steps']:.0f} learn steps{extra}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config + assertions; what CI runs")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore from here if a checkpoint exists, else "
                         "train and save here (default: temp dir)")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="pallas")
    ap.add_argument("--nact", type=int, default=None,
                    help="patchy connectivity budget for the input "
                         "projection: with backend=pallas the serving "
                         "infer path streams only the live pre-blocks "
                         "(kernels/patchy.py)")
    ap.add_argument("--compact", action="store_true",
                    help="with --nact: train and serve the input "
                         "projection in the compact-resident (Hj, K, Mj) "
                         "state layout (scatter-free patchy plasticity, "
                         "DESIGN.md §7)")
    ap.add_argument("--side", type=int, default=8)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--hidden-hc", type=int, default=8)
    ap.add_argument("--hidden-mc", type=int, default=16)
    ap.add_argument("--train-n", type=int, default=768)
    ap.add_argument("--test-n", type=int, default=256)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="offered open-loop arrival rate (req/s)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--no-online", action="store_true",
                    help="skip the online-learning phase")
    ap.add_argument("--feedback-frac", type=float, default=0.8)
    ap.add_argument("--feedback-batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.compact and not args.nact:
        raise SystemExit("--compact requires --nact (only nact-budgeted "
                         "projections have a compact form)")
    ds = make_synthetic(args.train_n, args.test_n, args.side, args.classes,
                        seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.mkdtemp(prefix="bcpnn_serve_"), "ckpt")

    # ---- phase 1: obtain a checkpointed network -------------------------
    mgr = CheckpointManager(ckpt_dir)
    step = mgr.latest_step()
    if step is None:
        nact = ([args.nact] + [None] * (args.depth - 1)
                if args.nact else None)
        spec = deep_synth_spec(side=args.side, depth=args.depth,
                               n_classes=args.classes,
                               hidden_hc=args.hidden_hc,
                               hidden_mc=args.hidden_mc,
                               nact=nact,
                               patchy_traces=args.compact,
                               compact=args.compact,
                               # patchy receptive fields must refine toward
                               # high-MI inputs or they stay random init
                               struct_every=25 if args.nact else 0,
                               backend=args.backend)
        print(f"[serve-bcpnn] no checkpoint under {ckpt_dir}; training "
              f"depth-{spec.depth} {args.backend} network "
              f"({args.epochs} epochs x {args.train_n} images)")
        tr = Trainer(spec, seed=args.seed)
        tr.fit(xt, ds.y_train, epochs=args.epochs, batch=args.batch)
        tr.save(ckpt_dir)
        step = mgr.latest_step()
    extra = mgr.read_extra(step)
    if extra is None or "spec" not in extra:
        raise SystemExit(f"checkpoint step_{step} has no spec metadata; "
                         f"re-save it with Trainer.save")
    spec = spec_from_dict(extra["spec"])
    if args.compact and not any(p.compact for p in spec.projs):
        # The spec comes from the checkpoint manifest, not the CLI flags:
        # serving a pre-existing dense checkpoint with --compact would
        # silently run the dense layout.
        raise SystemExit(
            f"--compact: checkpoint under {ckpt_dir} stores a dense-layout "
            f"network; migrate it first (scripts/migrate_ckpt.py) or point "
            f"--ckpt-dir at an empty directory to train a compact one")
    state = mgr.restore(step, init_deep(spec, jax.random.PRNGKey(args.seed)))
    print(f"[serve-bcpnn] restored step {step} from {ckpt_dir} "
          f"(depth {spec.depth}, backends "
          f"{[p.backend for p in spec.projs] + [spec.readout.backend]})")
    acc_base = evaluate_padded(state, spec, xe, ds.y_test, args.batch)
    print(f"[serve-bcpnn] checkpoint eval accuracy: {acc_base*100:.1f}%")

    # ---- phase 2: inference-only serving --------------------------------
    svc = BCPNNService(state, spec, max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms).start()
    rep = run_open_loop(svc, xe, ds.y_test, n_requests=args.requests,
                        rate_hz=args.rate, seed=args.seed)
    svc.stop()
    snap = svc.snapshot()
    _report("inference", snap,
            extra=f", served accuracy {rep.accuracy()*100:.1f}%")
    if args.smoke:
        assert snap["completed"] == snap["submitted"], "dropped requests"
        assert snap["p99_ms"] > 0, "no latency recorded"

    if args.no_online:
        if args.smoke:
            print("[serve-bcpnn] smoke OK (inference only)")
        return

    # ---- phase 3: online learning under live traffic --------------------
    cold = dataclasses.replace(
        state, readout=init_projection(spec.readout,
                                       jax.random.PRNGKey(args.seed + 99)))
    acc_cold = evaluate_padded(cold, spec, xe, ds.y_test, args.batch)
    svc2 = BCPNNService(cold, spec, max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms, online_learning=True,
                        feedback_batch=args.feedback_batch).start()
    rep2 = run_open_loop(svc2, xe, ds.y_test, n_requests=args.requests,
                         rate_hz=args.rate, seed=args.seed + 1,
                         feedback_frac=args.feedback_frac,
                         fb_x=xt, fb_y=ds.y_train)
    svc2.stop()
    snap2 = svc2.snapshot()
    acc_online = evaluate_padded(svc2.state, spec, xe, ds.y_test, args.batch)
    early, late = rep2.accuracy(0, 0.3), rep2.accuracy(0.7, 1.0)
    _report("online-learning", snap2,
            extra=f", served accuracy {early*100:.1f}% (early) -> "
                  f"{late*100:.1f}% (late)")
    print(f"[serve-bcpnn] readout eval accuracy: cold {acc_cold*100:.1f}% "
          f"-> after feedback {acc_online*100:.1f}% "
          f"(trained baseline {acc_base*100:.1f}%)")

    if args.smoke:
        assert snap2["completed"] == snap2["submitted"], \
            "online learning degraded availability (dropped requests)"
        assert snap2["learn_steps"] > 0, "no learn steps folded"
        # Recovery is bounded by what the frozen representation supports:
        # require the online readout to close a third of the gap between
        # the cold readout and the trained baseline (a fixed +10pt bar is
        # unreachable for configs whose baseline sits near the cold
        # accuracy, e.g. tightly nact-budgeted smoke stacks).
        floor = acc_cold + 0.3 * max(0.0, acc_base - acc_cold)
        assert acc_online > floor, (
            f"online learning did not measurably improve the readout "
            f"({acc_cold:.3f} -> {acc_online:.3f}, needed > {floor:.3f} "
            f"toward the {acc_base:.3f} baseline)")
        print("[serve-bcpnn] smoke OK")


if __name__ == "__main__":
    main()
