"""BCPNN serving driver: train-or-load checkpointed deep networks, serve
an open-loop synthetic request stream through the microbatched multi-model
engine, and report latency/throughput — optionally with the
online-learning mode folding a label stream into the deployed network
(readout-only, or full stack plasticity with in-deployment rewiring)
while traffic flows.

    PYTHONPATH=src python -m repro.launch.serve_bcpnn --smoke

Phases:
  1. obtain a network — restore from --ckpt-dir when a checkpoint exists
     (the spec rides in the manifest), else train on the synthetic task
     and checkpoint it;
  2. inference-only serving: open-loop Poisson load, p50/p99 + images/s;
  3. online learning (unless --no-online): the readout is re-initialized
     (cold), then RELEARNED from the feedback stream between inference
     microbatches — served accuracy recovers toward the trained baseline
     while requests keep completing (the runtime analogue of switching
     the paper's training bitstream in, without un-deploying inference);
  4. multi-model + structural plasticity (--smoke, or --ckpt given): two
     checkpointed models behind ONE admission front under a 10:1 skewed
     Poisson mix — per-model fairness — with stack-projection learning
     and the struct_every rewire cold path running on the deployed
     patchy model (receptive fields keep refining in deployment);
  5. router failover (--smoke, unless --no-router): the checkpoint is
     replicated across a 3-engine ``BCPNNRouter``, one replica-hosting
     engine is KILLED mid-stream, and the smoke asserts the DESIGN.md
     §11 ladder end to end — every admitted request resolves exactly
     once (served or typed), the loss is detected and the placement
     re-established on a survivor, post-loss traffic still serves, and
     reconcile() finds the replicas bit-consistent.

Passing ``--ckpt DIR`` (repeatable) instead serves the given checkpoint
directories as a multi-model deployment directly (names = dir basenames).
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import tempfile
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager, load_model, load_models
from ..configs.bcpnn_models import deep_synth_spec
from ..core import Trainer, evaluate_padded, init_projection
from ..data.synthetic import encode_images, make_synthetic
from ..serve import (
    BCPNNRouter, BCPNNService, Overloaded, ServeError, StreamSpec,
    run_multi_open_loop, run_open_loop,
)


def _report(tag: str, snap: dict, extra: str = "") -> None:
    robust = ""
    if snap.get("rejected") or snap.get("shed") or snap.get("failed"):
        robust = (f", {snap['rejected']:.0f} rejected / "
                  f"{snap['shed']:.0f} shed / {snap['failed']:.0f} failed")
    print(f"[serve-bcpnn] {tag}: {snap['completed']:.0f}/"
          f"{snap['submitted']:.0f} served, {snap['images_per_s']:.1f} img/s, "
          f"p50 {snap['p50_ms']:.1f}ms p99 {snap['p99_ms']:.1f}ms, "
          f"batch occupancy {snap['batch_occupancy']*100:.0f}%, "
          f"{snap['learn_steps']:.0f} learn steps{robust}{extra}")


def _accounted(snap: dict) -> bool:
    """Robustness-aware availability check: every admitted request must
    have RESOLVED (served, shed on deadline, or failed typed) — nothing
    silently dropped."""
    return (snap["completed"] + snap["shed"] + snap["failed"]
            == snap["submitted"])


def _pool_for(spec, n: int, seed: int):
    """(x_pool, y_pool) matching one model's input geometry: the synthetic
    task when the input is a square complement-pair image encoding, else
    a random rate pool (latency-only traffic)."""
    h = spec.input_geom.H
    side = int(round(math.sqrt(h)))
    if side * side == h and spec.input_geom.M == 2:
        ds = make_synthetic(n, n, side, spec.n_classes, seed=seed,
                            max_shift=1)
        return encode_images(ds.x_test), ds.y_test
    rng = np.random.default_rng(seed)
    hc = rng.random((n, spec.input_geom.H,
                     spec.input_geom.M)).astype(np.float32)
    hc /= hc.sum(axis=-1, keepdims=True)   # per-HC rate distributions
    x = hc.reshape(n, spec.input_geom.N)
    y = rng.integers(0, spec.n_classes, size=n).astype(np.int64)
    return x, y


def _deadline_s(args):
    return args.deadline_ms * 1e-3 if args.deadline_ms is not None else None


def serve_checkpoints(args) -> None:
    """--ckpt mode: host every given checkpoint dir in one engine and
    drive a uniform-rate multi-model mix."""
    models = load_models(args.ckpt, seed=args.seed)
    svc = BCPNNService.multi(
        models, max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        online_learning=not args.no_online, learn_stack=args.learn_stack,
        feedback_batch=args.feedback_batch,
        infer_dtype=args.infer_dtype, max_queue=args.max_queue,
        default_deadline_s=_deadline_s(args)).start()
    streams = {}
    for i, (name, (_, spec)) in enumerate(models.items()):
        x, y = _pool_for(spec, max(64, args.requests), args.seed + i)
        streams[name] = StreamSpec(x_pool=x, y_pool=y,
                                   rate_hz=args.rate / len(models))
    reports = run_multi_open_loop(svc, streams,
                                  n_requests=args.requests, seed=args.seed)
    svc.stop()
    snap = svc.snapshot()
    per = snap.get("per_model", {list(models)[0]: snap})
    for name, rep in reports.items():
        _report(f"model {name!r}", per[name],
                extra=f", served accuracy {rep.accuracy()*100:.1f}%")
    _report("aggregate", snap)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small config + assertions; what CI runs")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore from here if a checkpoint exists, else "
                         "train and save here (default: temp dir)")
    ap.add_argument("--ckpt", action="append", default=None,
                    help="serve this pre-trained checkpoint directory as "
                         "one model of a multi-model deployment "
                         "(repeatable; model name = dir basename); "
                         "skips the train/eval phases")
    ap.add_argument("--learn-stack", action="store_true",
                    help="with online learning: deterministic plasticity "
                         "on the stack projections (+ struct_every "
                         "rewiring) in deployment, not just the readout")
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--backend", choices=["jnp", "pallas"], default="pallas")
    ap.add_argument("--nact", type=int, default=None,
                    help="patchy connectivity budget for the input "
                         "projection: with backend=pallas the serving "
                         "infer path streams only the live pre-blocks "
                         "(kernels/patchy.py)")
    ap.add_argument("--compact", action="store_true",
                    help="with --nact: train and serve the input "
                         "projection in the compact-resident (Hj, K, Mj) "
                         "state layout (scatter-free patchy plasticity, "
                         "DESIGN.md §7)")
    ap.add_argument("--side", type=int, default=8)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--hidden-hc", type=int, default=8)
    ap.add_argument("--hidden-mc", type=int, default=16)
    ap.add_argument("--train-n", type=int, default=768)
    ap.add_argument("--test-n", type=int, default=256)
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="offered open-loop arrival rate (req/s)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=5.0)
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request queueing deadline: requests still "
                         "queued past it are shed (DeadlineExceeded) "
                         "before any compute; default = no deadline")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="per-model admission-queue bound: submits past "
                         "it are rejected with a typed Overloaded "
                         "instead of queueing unboundedly; default = "
                         "unbounded")
    ap.add_argument("--no-online", action="store_true",
                    help="skip the online-learning phase")
    ap.add_argument("--no-multi", action="store_true",
                    help="skip the multi-model + rewire phase in --smoke")
    ap.add_argument("--no-router", action="store_true",
                    help="skip the replicated-router failover phase in "
                         "--smoke")
    ap.add_argument("--feedback-frac", type=float, default=0.8)
    ap.add_argument("--feedback-batch", type=int, default=16)
    ap.add_argument("--infer-dtype", choices=["fp32", "bf16", "int8"],
                    default=None,
                    help="serving precision override for every hosted "
                         "model: weights are cast (bf16) or per-HC "
                         "quantized (int8) from the fp32 state at fold "
                         "boundaries; default honors each checkpoint "
                         "manifest's own infer_dtype tag")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.ckpt:
        serve_checkpoints(args)
        return
    if args.compact and not args.nact:
        raise SystemExit("--compact requires --nact (only nact-budgeted "
                         "projections have a compact form)")
    ds = make_synthetic(args.train_n, args.test_n, args.side, args.classes,
                        seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    ckpt_dir = args.ckpt_dir or os.path.join(
        tempfile.mkdtemp(prefix="bcpnn_serve_"), "ckpt")

    # ---- phase 1: obtain a checkpointed network -------------------------
    mgr = CheckpointManager(ckpt_dir)
    step = mgr.latest_step()
    if step is None:
        nact = ([args.nact] + [None] * (args.depth - 1)
                if args.nact else None)
        spec = deep_synth_spec(side=args.side, depth=args.depth,
                               n_classes=args.classes,
                               hidden_hc=args.hidden_hc,
                               hidden_mc=args.hidden_mc,
                               nact=nact,
                               patchy_traces=args.compact,
                               compact=args.compact,
                               # patchy receptive fields must refine toward
                               # high-MI inputs or they stay random init
                               struct_every=25 if args.nact else 0,
                               backend=args.backend)
        print(f"[serve-bcpnn] no checkpoint under {ckpt_dir}; training "
              f"depth-{spec.depth} {args.backend} network "
              f"({args.epochs} epochs x {args.train_n} images)")
        tr = Trainer(spec, seed=args.seed)
        tr.fit(xt, ds.y_train, epochs=args.epochs, batch=args.batch)
        tr.save(ckpt_dir)
        step = mgr.latest_step()
    try:
        state, spec, step = load_model(ckpt_dir, seed=args.seed)
    except ValueError as e:
        raise SystemExit(str(e))
    if args.compact and not any(p.compact for p in spec.projs):
        # The spec comes from the checkpoint manifest, not the CLI flags:
        # serving a pre-existing dense checkpoint with --compact would
        # silently run the dense layout.
        raise SystemExit(
            f"--compact: checkpoint under {ckpt_dir} stores a dense-layout "
            f"network; migrate it first (scripts/migrate_ckpt.py) or point "
            f"--ckpt-dir at an empty directory to train a compact one")
    print(f"[serve-bcpnn] restored step {step} from {ckpt_dir} "
          f"(depth {spec.depth}, backends "
          f"{[p.backend for p in spec.projs] + [spec.readout.backend]})")
    acc_base = evaluate_padded(state, spec, xe, ds.y_test, args.batch)
    print(f"[serve-bcpnn] checkpoint eval accuracy: {acc_base*100:.1f}%")

    # ---- phase 2: inference-only serving --------------------------------
    svc = BCPNNService(state, spec, max_batch=args.max_batch,
                       max_wait_ms=args.max_wait_ms,
                       infer_dtype=args.infer_dtype,
                       max_queue=args.max_queue,
                       default_deadline_s=_deadline_s(args)).start()
    rep = run_open_loop(svc, xe, ds.y_test, n_requests=args.requests,
                        rate_hz=args.rate, seed=args.seed)
    svc.stop()
    snap = svc.snapshot()
    _report("inference", snap,
            extra=f", served accuracy {rep.accuracy()*100:.1f}%")
    if args.smoke:
        assert _accounted(snap), f"requests silently dropped: {snap}"
        if args.deadline_ms is None and args.max_queue is None:
            assert snap["completed"] == snap["submitted"], "dropped requests"
        assert snap["p99_ms"] > 0, "no latency recorded"

    # ---- phase 3: online learning under live traffic --------------------
    if not args.no_online:
        cold = dataclasses.replace(
            state, readout=init_projection(spec.readout,
                                           jax.random.PRNGKey(args.seed + 99)))
        acc_cold = evaluate_padded(cold, spec, xe, ds.y_test, args.batch)
        svc2 = BCPNNService(cold, spec, max_batch=args.max_batch,
                            max_wait_ms=args.max_wait_ms,
                            online_learning=True,
                            feedback_batch=args.feedback_batch,
                            infer_dtype=args.infer_dtype,
                            max_queue=args.max_queue,
                            default_deadline_s=_deadline_s(args)).start()
        rep2 = run_open_loop(svc2, xe, ds.y_test, n_requests=args.requests,
                             rate_hz=args.rate, seed=args.seed + 1,
                             feedback_frac=args.feedback_frac,
                             fb_x=xt, fb_y=ds.y_train)
        svc2.stop()
        snap2 = svc2.snapshot()
        acc_online = evaluate_padded(svc2.state, spec, xe, ds.y_test,
                                     args.batch)
        early, late = rep2.accuracy(0, 0.3), rep2.accuracy(0.7, 1.0)
        _report("online-learning", snap2,
                extra=f", served accuracy {early*100:.1f}% (early) -> "
                      f"{late*100:.1f}% (late)")
        print(f"[serve-bcpnn] readout eval accuracy: cold {acc_cold*100:.1f}% "
              f"-> after feedback {acc_online*100:.1f}% "
              f"(trained baseline {acc_base*100:.1f}%)")

        if args.smoke:
            assert _accounted(snap2), f"requests silently dropped: {snap2}"
            if args.deadline_ms is None and args.max_queue is None:
                assert snap2["completed"] == snap2["submitted"], \
                    "online learning degraded availability (dropped requests)"
            assert snap2["learn_steps"] > 0, "no learn steps folded"
            # Recovery is bounded by what the frozen representation
            # supports: require the online readout to close a third of the
            # gap between the cold readout and the trained baseline (a
            # fixed +10pt bar is unreachable for configs whose baseline
            # sits near the cold accuracy, e.g. tightly nact-budgeted
            # smoke stacks).
            floor = acc_cold + 0.3 * max(0.0, acc_base - acc_cold)
            assert acc_online > floor, (
                f"online learning did not measurably improve the readout "
                f"({acc_cold:.3f} -> {acc_online:.3f}, needed > {floor:.3f} "
                f"toward the {acc_base:.3f} baseline)")

    # ---- phase 4: multi-model serving + in-deployment rewiring ----------
    if args.smoke and not args.no_multi:
        # Second tenant: a quickly-trained patchy compact network with a
        # SHORT rewire period, so structural plasticity demonstrably runs
        # while the engine serves both models from one admission front.
        spec_p = deep_synth_spec(side=args.side, depth=1,
                                 n_classes=args.classes, hidden_hc=4,
                                 hidden_mc=8,
                                 nact=[max(2, args.side * args.side // 2)],
                                 patchy_traces=True, compact=True,
                                 struct_every=5, backend=args.backend)
        tr_p = Trainer(spec_p, seed=args.seed + 5)
        tr_p.fit(xt, ds.y_train, epochs=2, batch=args.batch)
        t_before = int(tr_p.state.projs[0].traces.t)
        msvc = BCPNNService.multi(
            {"dense": (state, spec), "patchy": (tr_p.state, spec_p)},
            max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
            online_learning=True, learn_stack=True,
            feedback_batch=8, infer_dtype=args.infer_dtype,
            max_queue=args.max_queue,
            default_deadline_s=_deadline_s(args)).start()
        reports = run_multi_open_loop(
            msvc,
            {"dense": StreamSpec(xe, ds.y_test, rate_hz=args.rate),
             "patchy": StreamSpec(xe, ds.y_test, rate_hz=args.rate / 10)},
            n_requests=args.requests, seed=args.seed + 2)
        # A deterministic feedback burst large enough to cross several
        # struct_every boundaries on the patchy model's trace clock.
        for i in range(6 * 8):
            j = i % len(xt)
            msvc.feedback(xt[j], int(ds.y_train[j]), model="patchy")
        msvc.stop()
        msnap = msvc.snapshot()
        for name in ("dense", "patchy"):
            _report(f"multi-model {name!r}", msnap["per_model"][name])
        served_p = msvc.model_state("patchy")
        t_after = int(served_p.projs[0].traces.t)
        msvc.revalidate()  # mask/table invariants hold after rewires
        assert _accounted(msnap), f"requests silently dropped: {msnap}"
        if args.deadline_ms is None and args.max_queue is None:
            assert msnap["completed"] == msnap["submitted"], \
                "multi-model serving dropped requests"
        for name, rep_m in reports.items():
            assert len(rep_m.results) > 0, f"model {name!r} starved"
        assert msnap["per_model"]["patchy"]["learn_steps"] >= 6, msnap
        assert t_after > t_before, "stack plasticity did not advance"
        assert t_after // 5 > t_before // 5, \
            "no struct_every boundary crossed: rewire cannot have run"
        print("[serve-bcpnn] multi-model + rewire phase OK")

    # ---- phase 5: router failover under an engine loss ------------------
    if args.smoke and not args.no_router:
        _router_phase(args, state, spec, xe)

    if args.smoke:
        print("[serve-bcpnn] smoke OK")


def _router_phase(args, state, spec, xe) -> None:
    """Replicated serving through the cross-engine router with a chaos
    kill mid-stream: the deterministic end-to-end form of the DESIGN.md
    §11 ladder (the randomized soak lives in tests/test_router.py)."""
    print("[serve-bcpnn] router phase: 3 engines, replicas=2, one engine "
          "killed mid-stream")
    router = BCPNNRouter.local(3, max_batch=args.max_batch,
                               max_wait_ms=args.max_wait_ms,
                               max_queue=args.max_queue)
    router.add_model("m", state, spec, replicas=2)
    router.start()
    victim = router.placement("m")["replicas"][0]
    n = max(64, args.requests)
    ids, rejected = [], 0
    for i in range(n):
        if i == n // 2:  # deterministic mid-stream engine loss
            router._engines[victim].kill("smoke: engine loss")
            # wait for the maintenance probe to notice (the submit loop
            # is far faster than the worker's death, so without this the
            # whole second half would land in the dead engine's queue —
            # typed failures, but nothing left to prove post-loss serving)
            t_end = time.perf_counter() + 30.0
            while victim in router.placement("m")["replicas"]:
                router.check_engines()
                if time.perf_counter() > t_end:
                    raise SystemExit("engine loss never detected")
                time.sleep(0.005)
        try:
            ids.append(router.submit(xe[i % len(xe)], model="m",
                                     deadline_s=10.0))
        except Overloaded:
            rejected += 1
    served = failed = 0
    for rid in ids:
        try:
            router.result(rid, timeout=60.0)
            served += 1
        except ServeError:
            failed += 1  # typed resolution — the loss was not silent
    rec = router.reconcile("m")["m"]
    snap = router.metrics.snapshot()
    place = router.placement("m")
    errs = router.stop()
    print(f"[serve-bcpnn] router: {served} served / {failed} failed typed "
          f"/ {rejected} rejected of {n} offered, "
          f"{snap['reroutes']:.0f} reroutes, "
          f"{snap['engine_losses']:.0f} engine losses, "
          f"{snap['replacements']:.0f} replacements, "
          f"recovery {snap.get('recovery_s_max', 0.0)*1e3:.0f}ms, "
          f"replicas now {place['replicas']}")
    # every admitted request resolved exactly once, at the router
    assert served + failed == len(ids), "router lost a request id"
    assert snap["submitted"] == snap["completed"] + snap["failed"], \
        f"router accounting does not close: {snap}"
    assert snap["engine_losses"] >= 1, "the engine loss went undetected"
    assert snap["replacements"] >= 1, "no replacement replica was placed"
    assert victim not in place["replicas"], "dead engine still placed"
    assert len(place["replicas"]) == 2, "placement not re-established"
    assert served > n // 2, "post-loss traffic did not keep serving"
    assert rec.get("consistent", False), f"replicas diverged: {rec}"
    assert victim in errs, "stop() did not surface the killed engine"
    print("[serve-bcpnn] router failover phase OK")


if __name__ == "__main__":
    main()
