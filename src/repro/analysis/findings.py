"""Findings, inline suppressions, and the committed baseline.

The invariant linter (``repro.analysis``) reports machine-checked
contract violations as ``Finding``s with a stable rule id and a
``file:line`` anchor.  Two escape hatches exist, both auditable:

* **Inline suppression** — ``# repro: suppress[rule-id] — reason`` on
  the finding's line (or the line directly above it).  The reason is
  REQUIRED: a suppression without one is itself reported
  (``suppress-needs-reason``), so every waived contract carries its
  justification in the diff.
* **Committed baseline** — a JSON file of accepted pre-existing
  findings (``.analysis-baseline.json`` at the repo root).  Baseline
  entries match on (rule, path, source-line text), NOT on line numbers,
  so unrelated edits above a baselined finding do not resurrect it.

``--strict`` fails on any finding that is neither suppressed inline nor
in the baseline.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

SEVERITIES = ("error", "warning")

# ``# repro: suppress[rule-a,rule-b] — reason`` (hyphen/en/em dash all
# accepted as the reason separator; the reason itself is mandatory and
# validated by the linter, not the regex).
_SUPPRESS_RE = re.compile(
    r"#\s*repro:\s*suppress\[(?P<rules>[A-Za-z0-9_,\- ]+)\]"
    r"(?:\s*(?:—|–|--|-)\s*(?P<reason>.*\S))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a file:line anchor."""

    rule: str
    path: str          # repo-relative, '/'-separated
    line: int          # 1-indexed
    message: str
    severity: str = "error"
    snippet: str = ""  # stripped source line (baseline fingerprint)

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity}[{self.rule}] "
                f"{self.message}")

    def fingerprint(self) -> Tuple[str, str, str]:
        """Line-number-free identity used by the baseline: unrelated
        edits that shift a finding do not invalidate its entry."""
        return (self.rule, self.path, self.snippet)


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One parsed inline suppression comment."""

    rules: Tuple[str, ...]
    line: int
    reason: Optional[str]


def parse_suppressions(source_lines: List[str]) -> List[Suppression]:
    out = []
    for i, text in enumerate(source_lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        out.append(Suppression(rules=rules, line=i, reason=m.group("reason")))
    return out


def apply_suppressions(findings: List[Finding],
                       suppressions: List[Suppression],
                       path: str) -> List[Finding]:
    """Drop findings covered by an inline suppression on their own line
    or the line directly above; emit ``suppress-needs-reason`` for any
    suppression missing its reason."""
    by_line: Dict[int, List[Suppression]] = {}
    for s in suppressions:
        by_line.setdefault(s.line, []).append(s)

    def covered(f: Finding) -> bool:
        for line in (f.line, f.line - 1):
            for s in by_line.get(line, ()):
                if f.rule in s.rules and s.reason:
                    return True
        return False

    kept = [f for f in findings if not covered(f)]
    for s in suppressions:
        if not s.reason:
            kept.append(Finding(
                rule="suppress-needs-reason", path=path, line=s.line,
                message=(f"suppression of {list(s.rules)} has no reason; "
                         f"write '# repro: suppress[rule] — why'"),
                snippet=f"suppress[{','.join(s.rules)}]"))
    return kept


# ------------------------------------------------------------ baseline ----

def load_baseline(path: Path) -> List[Dict[str, str]]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    return list(data.get("findings", []))


def save_baseline(path: Path, findings: Iterable[Finding]) -> None:
    entries = [{"rule": f.rule, "path": f.path, "snippet": f.snippet,
                "message": f.message}
               for f in sorted(findings, key=lambda f: (f.path, f.line))]
    path.write_text(json.dumps(
        {"comment": "accepted pre-existing findings; regenerate with "
                    "`python -m repro.analysis --write-baseline`",
         "findings": entries}, indent=2) + "\n")


def split_baselined(findings: List[Finding],
                    baseline: List[Dict[str, str]]
                    ) -> Tuple[List[Finding], List[Finding]]:
    """(new, baselined) partition by line-free fingerprint.  Each
    baseline entry absorbs at most one finding, so a *second* instance
    of a baselined pattern in the same file is still new."""
    budget: Dict[Tuple[str, str, str], int] = {}
    for e in baseline:
        key = (e["rule"], e["path"], e.get("snippet", ""))
        budget[key] = budget.get(key, 0) + 1
    new, old = [], []
    for f in findings:
        key = f.fingerprint()
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old
