"""AST lint engine: file walking, rule registry, suppression plumbing.

Rules are plain classes with a stable ``id`` and a ``check(module)``
method returning raw findings; the engine owns everything rule authors
should not re-implement — parsing, repo-relative paths, snippet capture
for baseline fingerprints, and inline-suppression filtering.  All rules
use only stdlib ``ast``: the linter must run in any environment that can
run the repo (no new hard dependencies).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Type

from .findings import Finding, apply_suppressions, parse_suppressions

# Directories never scanned: the lint fixture corpus is known-bad by
# design, and caches/VCS internals are not source.
SKIP_DIRS = {"__pycache__", ".git", "fixtures", ".pytest_cache",
             "node_modules", ".mypy_cache"}


@dataclasses.dataclass
class Module:
    """One parsed source file handed to every rule."""

    path: str                # repo-relative, '/'-separated
    abspath: Path
    tree: ast.Module
    lines: List[str]         # 1-indexed via lines[line - 1]

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node: ast.AST, message: str,
                severity: str = "error") -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.path, line=line, message=message,
                      severity=severity, snippet=self.snippet(line))


class Rule:
    """Base class: subclasses define ``id``, ``contract`` (one-line,
    rendered in ``--explain`` and DESIGN.md §9) and ``check``."""

    id: str = ""
    contract: str = ""

    def check(self, module: Module) -> List[Finding]:  # pragma: no cover
        raise NotImplementedError


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _REGISTRY:
        raise ValueError(f"duplicate rule id {cls.id!r}")
    _REGISTRY[cls.id] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    from . import rules  # noqa: F401  (import populates the registry)
    return dict(_REGISTRY)


def parse_module(abspath: Path, root: Path) -> Optional[Module]:
    try:
        text = abspath.read_text(encoding="utf-8")
        tree = ast.parse(text, filename=str(abspath))
    except (OSError, SyntaxError, UnicodeDecodeError):
        return None
    try:
        rel = abspath.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = abspath.as_posix()
    return Module(path=rel, abspath=abspath, tree=tree,
                  lines=text.splitlines())


def iter_py_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in f.parts):
                    yield f


def lint_paths(paths: Sequence[Path], root: Path,
               rule_ids: Optional[Sequence[str]] = None,
               honor_suppressions: bool = True) -> List[Finding]:
    """Run the (selected) rules over every ``*.py`` under ``paths``.

    ``honor_suppressions=False`` reports raw findings — the fixture
    tests use it to pin each rule's exact output independently of any
    suppression comments a fixture might also exercise.
    """
    registry = all_rules()
    ids = list(rule_ids) if rule_ids else sorted(registry)
    unknown = [i for i in ids if i not in registry]
    if unknown:
        raise ValueError(f"unknown rule ids {unknown}; "
                         f"known: {sorted(registry)}")
    rules = [registry[i]() for i in ids]
    out: List[Finding] = []
    for f in iter_py_files(paths):
        module = parse_module(f, root)
        if module is None:
            continue
        found: List[Finding] = []
        for rule in rules:
            found.extend(rule.check(module))
        if honor_suppressions:
            found = apply_suppressions(
                found, parse_suppressions(module.lines), module.path)
        out.extend(found)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


# Small shared AST helpers used by several rules ------------------------

def dotted_name(node: ast.AST) -> str:
    """'jax.lax.psum' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def walk_scoped(node: ast.AST,
                enter: Callable[[ast.AST], bool]) -> None:
    """ast.walk that lets the callback prune subtrees (return False)."""
    if not enter(node):
        return
    for child in ast.iter_child_nodes(node):
        walk_scoped(child, enter)
