"""repro.analysis — the repo's invariant lint + jaxpr contract checker.

Two layers (ISSUE 7 / DESIGN.md §9):

* **AST lint rules** (``lint.py``, ``rules.py``) — stdlib-``ast`` rules
  encoding the contracts that previously lived only in prose: donated-jit
  discipline, pad-fill hygiene, serve-lock discipline, jit-purity, and
  the fp32-learning/packed-serving dtype split.  Findings carry
  file:line anchors, inline suppressions require a reason, and a
  committed baseline (``.analysis-baseline.json``) absorbs accepted
  pre-existing findings.
* **jaxpr/contract checks** (``contracts.py``, ``plans.py``) — runtime
  sanitizers: the serving recompilation sentinel, the DP
  ``optimization_barrier`` seam checker, the Pallas pad-plan auditor,
  and the donated-buffer ``cached_table`` guard probe.

CLI: ``python -m repro.analysis [--strict] [--contracts]`` (also
``scripts/check.py``).  See each module's docstring for details.
"""
from .findings import Finding, load_baseline, save_baseline, split_baselined
from .lint import Module, Rule, all_rules, lint_paths

__all__ = [
    "Finding", "Module", "Rule", "all_rules", "lint_paths",
    "load_baseline", "save_baseline", "split_baselined",
]
