"""Runtime jaxpr/contract checks — the sanitizer layer of repro.analysis.

Where the AST rules (rules.py) catch violations at the source level,
these checks run tiny real programs and inspect what jax actually built:

* ``recompile-sentinel`` — serves a smoke workload through
  ``BCPNNService`` and asserts every per-(model, bucket) jit compiled
  EXACTLY once during warmup and never again (``_cache_size()`` on the
  slot jits): any cache-key churn — a spec that stopped being hashable,
  a shape leak past the bucket padding — shows up as a growing count.
* ``dp-seams`` — canonicalizes the ``optimization_barrier`` equations of
  the single-device step jaxpr and the shard_map data-parallel step
  jaxpr and asserts the PR 4 seam set is present in both (the
  precondition for the bit-exact DP equivalence; see
  core/traces.py, core/network.py, distributed/data_parallel.py).
* ``masked-seams`` — same discipline for the masked tail-batch learn
  (DESIGN.md §12): the ``masked_inputs`` pin (x, y, valid) and the
  masked-product pins must appear in the single-device masked step AND
  in the shard_map masked epoch program (where the product pin carries
  the column-sharded ``yv_l`` as well) — the precondition for padded
  fits staying bit-exact across meshes.
* ``donation-guard`` — replays the PR 6 bug: a ``cached_table`` result
  whose buffer is consumed by a donating jit must be REBUILT on the next
  call, never returned dead (core/compact.py's ``_deleted`` guard).
* ``pallas-plans`` — the kernel pad-plan/shape/accumulator audit
  (plans.py).
* ``router-exactly-once`` — kills an engine under a live
  ``BCPNNRouter`` and asserts every router-issued id resolves EXACTLY
  once (result or typed error — never lost, never twice), accounting
  closes, and the reroute budget bounds admission attempts
  (DESIGN.md §11).
* ``replica-merge`` — the disjoint-support merge of agreeing replica
  states is bit-identical to each replica on a REAL folded model state,
  and a diverged replica set cannot merge clean (serve/reconcile.py).

Every check returns a list of problem strings; empty means the contract
holds.  ``run_contracts`` drives any subset by name.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

from .plans import check_pallas_plans


def ensure_host_devices(n: int = 2) -> None:
    """Give the process an ``n``-device CPU mesh if jax is not yet
    initialized (the DP-seam check needs >= 2 devices; tests get this
    from conftest.py, the CLI from here)."""
    import sys
    if "jax" in sys.modules:
        return  # too late to change platform flags — use what exists
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


# ------------------------------------------------- recompile sentinel ----

def check_recompile_sentinel() -> List[str]:
    """Per-(model, bucket) compile counts stay fixed across a serving
    smoke: warmup compiles every bucket (plus one learn shape), and no
    request, feedback fold, or drain may add an entry."""
    import jax.numpy as jnp  # noqa: F401 — ensures jax is importable first
    import numpy as np
    from ..core.network import init_network, make_network_spec
    from ..serve.engine import BCPNNService
    import jax

    buckets = (1, 2, 4)
    spec = make_network_spec((2, 2), [(1, 4)], 2, backend="jnp")
    state = init_network(spec, jax.random.PRNGKey(0))
    svc = BCPNNService(state, spec, buckets=buckets, max_wait_ms=0.5,
                      online_learning=True, feedback_batch=4,
                      adaptive_buckets=True)
    slot = svc._slot(None)
    cache_size = getattr(slot.infer_fn, "_cache_size", None)
    if cache_size is None:
        return ["jit tracing-cache introspection (_cache_size) is "
                "unavailable in this jax version — sentinel cannot run"]

    problems: List[str] = []
    svc.start(warmup=True)
    try:
        n_infer0 = slot.infer_fn._cache_size()
        n_learn0 = slot.learn_fn._cache_size()
        if n_infer0 != len(buckets):
            problems.append(
                f"warmup compiled {n_infer0} infer entries for "
                f"{len(buckets)} buckets — bucket set and compile set "
                f"disagree")
        if n_learn0 != 1:
            problems.append(f"warmup compiled {n_learn0} learn entries, "
                            f"expected exactly 1 (the feedback_batch shape)")
        rng = np.random.default_rng(0)
        ni = spec.input_geom.N
        # mixed singles/bursts so every bucket actually serves traffic
        ids = [svc.submit(rng.random(ni).astype(np.float32))
               for _ in range(17)]
        for rid in ids:
            svc.result(rid, timeout=30.0)
        for i in range(9):
            svc.feedback(rng.random(ni).astype(np.float32), i % 2)
    finally:
        svc.stop()
    n_infer1 = slot.infer_fn._cache_size()
    n_learn1 = slot.learn_fn._cache_size()
    if n_infer1 != n_infer0:
        problems.append(
            f"infer jit recompiled during serving: {n_infer0} -> "
            f"{n_infer1} cache entries — a request escaped its shape "
            f"bucket or the spec's jit key churned")
    if n_learn1 != n_learn0:
        problems.append(
            f"learn jit recompiled during serving: {n_learn0} -> "
            f"{n_learn1} cache entries — a feedback fold escaped the "
            f"fixed feedback_batch shape")
    return problems


# --------------------------------------------------------- DP seams ----

def _barrier_signatures(closed_jaxpr: Any) -> List[tuple]:
    """Every ``optimization_barrier`` equation in a jaxpr (recursing
    through call/scan/cond/shard_map sub-jaxprs), canonicalized as the
    sorted tuple of its outputs' "dtype[shape]" strings — a seam identity
    that survives variable renaming and eqn reordering."""
    out: List[tuple] = []
    seen = set()

    def walk(jx: Any) -> None:
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            if eqn.primitive.name == "optimization_barrier":
                out.append(tuple(sorted(
                    f"{v.aval.dtype}[{','.join(str(d) for d in v.aval.shape)}]"
                    for v in eqn.outvars)))
            for val in eqn.params.values():
                for sub in _subjaxprs(val):
                    walk(sub)
    walk(closed_jaxpr.jaxpr)
    return out


def _subjaxprs(val: Any) -> Iterator[Any]:
    if hasattr(val, "eqns"):            # open Jaxpr
        yield val
    elif hasattr(val, "jaxpr"):         # ClosedJaxpr
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _subjaxprs(v)


def _require(problems: List[str], sigs: List[tuple], want: tuple,
             count: int, program: str, seam: str) -> None:
    have = sigs.count(want)
    if have < count:
        problems.append(
            f"{program}: expected >= {count} optimization_barrier seam(s) "
            f"{seam} with outputs {list(want)}, found {have} — the "
            f"bit-exactness pin was removed or reshaped")


def check_dp_seams() -> List[str]:
    """The PR 4 barrier seams are present in BOTH the single-device
    unsupervised step and its shard_map data-parallel equivalent."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from ..core.network import (
        init_network, make_network_spec, unsupervised_layer_step,
    )
    from ..distributed.data_parallel import (
        make_data_parallel_unsupervised_step,
    )

    b, n_shards = 8, 2
    spec = make_network_spec((4, 3), [(4, 5)], 3, backend="jnp")
    ni, nj = spec.input_geom.N, spec.projs[0].post.N          # 12, 20
    state = init_network(spec, jax.random.PRNGKey(0))
    x = jnp.zeros((b, ni), jnp.float32)

    problems: List[str] = []
    single = jax.make_jaxpr(
        lambda st, xx: unsupervised_layer_step(st, spec, xx, 0))(state, x)
    sigs_1 = _barrier_signatures(single)

    def shape(*dims: int) -> str:
        return f"float32[{','.join(str(d) for d in dims)}]"

    noise = (shape(b, nj),)
    learn_xy = tuple(sorted((shape(b, ni), shape(b, nj))))
    stats = tuple(sorted((shape(ni), shape(nj), shape(ni, nj))))
    _require(problems, sigs_1, noise, 2, "single-device step",
             "(noise draw + scaled-noise pins, core/network._noisy_rates)")
    _require(problems, sigs_1, learn_xy, 1, "single-device step",
             "(learn-input pin, core/traces.update_traces)")
    _require(problems, sigs_1, stats, 1, "single-device step",
             "(batch-stats pin, core/traces.update_traces_from_stats)")

    if len(jax.devices()) < n_shards:
        problems.append(
            f"dp step: needs >= {n_shards} devices, found "
            f"{len(jax.devices())} — run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} (the CLI "
            f"sets this automatically when jax is not yet imported)")
        return problems

    mesh = Mesh(jax.devices()[:n_shards], ("data",))
    dp_step = make_data_parallel_unsupervised_step(spec, mesh)
    sigs_n = _barrier_signatures(jax.make_jaxpr(dp_step)(state, x))
    nj_l = nj // n_shards
    _require(problems, sigs_n, noise, 1, "data-parallel step",
             "(full-batch noise pin mirroring _noisy_rates)")
    _require(problems, sigs_n, (shape(b, nj_l),), 1, "data-parallel step",
             "(column-sliced scaled-noise pin)")
    _require(problems, sigs_n, learn_xy, 1, "data-parallel step",
             "(learn-input pin, distributed._learn_sharded)")
    _require(problems, sigs_n,
             tuple(sorted((shape(b, ni), shape(b, nj_l)))), 1,
             "data-parallel step",
             "(trace all-reduce pin, distributed._co_allreduce_dense)")
    _require(problems, sigs_n, stats, 1, "data-parallel step",
             "(batch-stats pin — the all-reduced stats fold)")
    return problems


def check_masked_seams() -> List[str]:
    """The masked tail-learning barrier seams (PR 10) are present in both
    the single-device masked step and the shard_map masked epoch."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from ..core.network import (
        init_network, make_network_spec, train_projection_step,
    )
    from ..distributed.data_parallel import (
        make_data_parallel_projection_epoch,
    )

    b, n_shards, nb = 8, 2, 2
    spec = make_network_spec((4, 3), [(4, 5)], 3, backend="jnp")
    ni, nj = spec.input_geom.N, spec.projs[0].post.N          # 12, 20
    state = init_network(spec, jax.random.PRNGKey(0))
    x = jnp.zeros((b, ni), jnp.float32)
    v = jnp.zeros((b,), jnp.float32)

    def shape(*dims: int) -> str:
        return f"float32[{','.join(str(d) for d in dims)}]"

    masked_in = tuple(sorted((shape(b, ni), shape(b, nj), shape(b))))
    problems: List[str] = []
    single = jax.make_jaxpr(
        lambda st, xx, vv: train_projection_step(st, spec, xx, 0, valid=vv)
    )(state, x, v)
    sigs_1 = _barrier_signatures(single)
    _require(problems, sigs_1, masked_in, 1, "single-device masked step",
             "(masked-input pin, core/bcpnn_layer.masked_inputs)")
    _require(problems, sigs_1,
             tuple(sorted((shape(b, ni), shape(b, nj)))), 1,
             "single-device masked step",
             "(masked-product pin, core/bcpnn_layer.learn_masked)")

    if len(jax.devices()) < n_shards:
        problems.append(
            f"masked dp epoch: needs >= {n_shards} devices, found "
            f"{len(jax.devices())} — run with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
        return problems

    mesh = Mesh(jax.devices()[:n_shards], ("data",))
    dp_epoch = make_data_parallel_projection_epoch(spec, mesh, masked=True)
    hs = jnp.zeros((nb, b, ni), jnp.float32)
    valid = jnp.zeros((nb, b), jnp.float32)
    sigs_n = _barrier_signatures(jax.make_jaxpr(dp_epoch)(state, hs, valid))
    nj_l = nj // n_shards
    _require(problems, sigs_n, masked_in, 1, "data-parallel masked epoch",
             "(masked-input pin mirroring core/bcpnn_layer.masked_inputs)")
    _require(problems, sigs_n,
             tuple(sorted((shape(b, ni), shape(b, nj), shape(b, nj_l)))), 1,
             "data-parallel masked epoch",
             "(masked sharded-product pin, distributed._learn_sharded)")
    return problems


# ---------------------------------------------------- donation guard ----

def check_donation_guard() -> List[str]:
    """The PR 6 regression, as a live check: consume a memoized index
    table's buffer the way a ``donate_argnums`` jit does and assert
    ``cached_table`` rebuilds instead of returning the dead array."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..core.compact import build_table, cached_table

    nact = 2
    mask = jnp.asarray(np.array([[1, 0], [1, 1], [0, 1], [0, 0]],
                                np.float32))  # (Hi=4, Hj=2), exactly-nact
    expected = np.asarray(build_table(mask, nact))

    problems: List[str] = []
    t1 = cached_table(mask, nact)
    # what a donating jit (Trainer's train steps donate the state, and
    # the compact state carries its table as a leaf) does to the buffer:
    consume = jax.jit(lambda t: t + 1, donate_argnums=0)
    consume(t1)
    # repro: suppress[donated-reuse] — deliberate use-after-donate probe
    if not t1.is_deleted():
        problems.append("donation probe failed to consume the table "
                        "buffer — the check cannot exercise the guard")
        return problems
    t2 = cached_table(mask, nact)
    if t2.is_deleted():
        problems.append(
            "cached_table returned a DELETED buffer after its memoized "
            "table was consumed by a donating jit — the core/compact "
            "_deleted() guard is broken (PR 6 bug class)")
        return problems
    if not np.array_equal(np.asarray(t2), expected):
        problems.append("cached_table rebuilt a WRONG table after "
                        "donation — guard rebuilt from stale content")
    # content-level memo must also refuse the dead buffer: a different
    # mask object with identical content hits the content cache.
    mask_copy = jnp.asarray(np.asarray(mask))
    t3 = cached_table(mask_copy, nact)
    if t3.is_deleted() or not np.array_equal(np.asarray(t3), expected):
        problems.append("content-level cached_table memo served a dead or "
                        "wrong table after donation")
    return problems


# --------------------------------------------- quarantine rollback ----

def check_quarantine_rollback() -> List[str]:
    """Live check of the serving quarantine contract (DESIGN.md §10): a
    fold whose output fails the non-finite sentinel must (a) leave the
    slot's state BIT-IDENTICAL to the last-good pre-fold state, (b) flip
    the slot to inference-only (``Quarantined`` on feedback, surfaced in
    ``snapshot()``), and (c) re-arm through ``revalidate()``."""
    import time
    import jax
    import numpy as np
    from ..core.network import init_network, make_network_spec
    from ..serve.engine import BCPNNService
    from ..serve.errors import Quarantined
    from ..serve.faultinject import FaultInjector

    spec = make_network_spec((2, 2), [(1, 4)], 2, backend="jnp")
    state = init_network(spec, jax.random.PRNGKey(0))
    # fold invocation 0 stays clean (establishes a non-trivial last-good
    # snapshot), invocation 1 is corrupted; feedback_eager=False makes
    # the invocation -> batch-composition mapping deterministic (folds
    # fire only on FULL feedback batches, never on idle polls)
    inj = FaultInjector(seed=0, schedule={"nan-state": {1}})
    svc = BCPNNService(state, spec, buckets=(1, 2), max_wait_ms=0.5,
                       online_learning=True, feedback_batch=2,
                       feedback_eager=False, fault_injector=inj)
    problems: List[str] = []
    svc.start(warmup=True)
    try:
        rng = np.random.default_rng(0)
        ni = spec.input_geom.N
        deadline = time.perf_counter() + 30.0
        for i in range(2):
            svc.feedback(rng.random(ni).astype(np.float32), i % 2)
        while svc.snapshot()["learn_steps"] < 1:
            if time.perf_counter() > deadline:
                problems.append("clean fold never landed")
                return problems
            time.sleep(0.002)
        good = jax.tree_util.tree_map(np.asarray, svc._slot(None).state)
        # the corrupted fold: must quarantine, not commit
        for i in range(2):
            svc.feedback(rng.random(ni).astype(np.float32), i % 2)
        while not svc._slot(None).quarantined:
            if time.perf_counter() > deadline:
                problems.append("nan-injected fold never quarantined")
                return problems
            time.sleep(0.002)
        after = jax.tree_util.tree_map(np.asarray, svc._slot(None).state)
        flat_g = jax.tree_util.tree_leaves(good)
        flat_a = jax.tree_util.tree_leaves(after)
        for g, a in zip(flat_g, flat_a):
            if g.dtype != a.dtype or not np.array_equal(g, a):
                problems.append(
                    "quarantine rollback is not bit-identical to the "
                    "last-good state — a corrupted fold leaked into the "
                    "served state")
                break
        if svc.snapshot().get("quarantined") != 1.0:
            problems.append("quarantine not surfaced in snapshot()")
        try:
            svc.feedback(rng.random(ni).astype(np.float32), 0)
            problems.append("quarantined slot accepted feedback "
                            "(expected Quarantined)")
        except Quarantined:
            pass
        svc.revalidate()
        if svc._slot(None).quarantined:
            problems.append("revalidate() failed to re-arm a finite "
                            "rolled-back slot")
    finally:
        svc.stop()
    return problems


# ------------------------------------------- router exactly-once ----

def check_router_exactly_once() -> List[str]:
    """Live check of the router failure ladder (DESIGN.md §11): with an
    engine killed under load, every router-issued id resolves EXACTLY
    once — a result or one typed error, never a hang, never a second
    resolution — router accounting closes, and a submit against a tier
    with no healthy replica rejects within the reroute budget."""
    import jax
    import numpy as np
    from ..core.network import init_network, make_network_spec
    from ..serve import BCPNNRouter, NoHealthyReplica, ServeError

    spec = make_network_spec((2, 2), [(1, 4)], 2, backend="jnp")
    state = init_network(spec, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    ni = spec.input_geom.N
    problems: List[str] = []

    r = BCPNNRouter.local(2, max_batch=4, max_queue=256)
    r.add_model("m", state, spec, replicas=2)
    r.start()
    try:
        ids = [r.submit(rng.random(ni).astype(np.float32), model="m")
               for _ in range(16)]
        victim = r.placement("m")["replicas"][0]
        r._engines[victim].kill("contract-probe")
        resolved = 0
        for rid in ids:
            try:
                r.result(rid, timeout=30.0)
                resolved += 1
            except ServeError:
                resolved += 1  # typed failure IS a resolution
            except TimeoutError:
                problems.append(f"router id {rid} hung past its engine's "
                                f"death — an in-flight future was lost")
        if resolved != len(ids) and not problems:
            problems.append(f"{len(ids) - resolved} of {len(ids)} router "
                            f"ids vanished without a typed resolution")
        try:
            r.result(ids[0], timeout=1.0)
            problems.append("an already-resolved router id resolved a "
                            "SECOND time — exactly-once is broken")
        except KeyError:
            pass
        snap = r.metrics.snapshot()
        if snap["submitted"] != snap["completed"] + snap["failed"]:
            problems.append(
                f"router accounting does not close: submitted="
                f"{snap['submitted']} != completed={snap['completed']} "
                f"+ failed={snap['failed']}")
    finally:
        r.stop()

    # reroute budget: a tier with no healthy replica rejects typed,
    # within 1 + max_reroutes admission attempts
    r2 = BCPNNRouter.local(1, max_reroutes=2)
    r2.add_model("m", state, spec)
    r2.start()
    try:
        r2._engines["engine0"].kill("contract-probe")
        import time as _time
        deadline = _time.perf_counter() + 30.0
        while r2._engines["engine0"].alive():
            if _time.perf_counter() > deadline:
                problems.append("killed engine never died")
                return problems
            _time.sleep(0.002)
        try:
            r2.submit(rng.random(ni).astype(np.float32), model="m")
            problems.append("submit admitted a request on a tier with no "
                            "healthy replica")
        except NoHealthyReplica as e:
            if e.attempts > 1 + r2.max_reroutes:
                problems.append(f"reroute budget exceeded: {e.attempts} "
                                f"attempts > 1 + {r2.max_reroutes}")
        if r2.metrics.snapshot()["rejected"] != 1.0:
            problems.append("NoHealthyReplica rejection not counted")
    finally:
        r2.stop()
    return problems


# ------------------------------------------------- replica merge ----

def check_replica_merge() -> List[str]:
    """The reconciliation merge's bitwise contract on a REAL folded
    model state: merging K agreeing replicas is bit-identical to each
    replica (the disjoint-support reassembly is lossless for every leaf
    shape/dtype in the state tree), and a diverged replica set cannot
    merge clean."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..core.network import (
        init_network, make_network_spec, supervised_readout_step,
    )
    from ..serve.reconcile import (
        merge_replica_states, state_divergence, states_bitwise_equal,
    )

    spec = make_network_spec((2, 2), [(1, 4)], 2, backend="jnp")
    state0 = init_network(spec, jax.random.PRNGKey(1))
    fold = jax.jit(lambda st, x, y: supervised_readout_step(st, spec, x, y))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((4, spec.input_geom.N)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 2, size=4).astype(np.int32))
    folded = fold(state0, x, y)

    problems: List[str] = []
    for k in (1, 2, 3):
        merged = merge_replica_states([folded] * k)
        if not states_bitwise_equal(merged, folded):
            div = "; ".join(state_divergence(merged, folded)[:3])
            problems.append(f"merge of {k} agreeing replicas is not "
                            f"bit-identical: {div}")
    mixed = merge_replica_states([folded, state0])
    if states_bitwise_equal(mixed, folded) and \
            states_bitwise_equal(mixed, state0):
        problems.append("merge failed to expose a diverged replica set — "
                        "reconcile() could report drifted replicas as "
                        "consistent")
    return problems


# -------------------------------------------------------------- driver ----

CONTRACTS: Dict[str, Callable[[], List[str]]] = {
    "donation-guard": check_donation_guard,
    "recompile-sentinel": check_recompile_sentinel,
    "dp-seams": check_dp_seams,
    "masked-seams": check_masked_seams,
    "pallas-plans": check_pallas_plans,
    "quarantine-rollback": check_quarantine_rollback,
    "router-exactly-once": check_router_exactly_once,
    "replica-merge": check_replica_merge,
}


def run_contracts(names: Optional[Sequence[str]] = None
                  ) -> Dict[str, List[str]]:
    """Run the named contract checks (all by default) -> {name: problems}."""
    picked = list(names) if names else sorted(CONTRACTS)
    unknown = [n for n in picked if n not in CONTRACTS]
    if unknown:
        raise ValueError(f"unknown contract checks {unknown}; known: "
                         f"{sorted(CONTRACTS)}")
    return {name: CONTRACTS[name]() for name in picked}
