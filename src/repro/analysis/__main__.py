"""``python -m repro.analysis`` — run the invariant linter and the
jaxpr/contract sanitizers over the repo.

Exit codes: 0 = clean (modulo suppressions + baseline), 1 = findings or
contract failures, 2 = usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

# Scanned by default: everything that is source, nothing that is corpus.
DEFAULT_ROOTS = ("src", "scripts", "benchmarks", "examples", "tests")
BASELINE_NAME = ".analysis-baseline.json"


def repo_root() -> Path:
    """The repo root: nearest ancestor of this file holding src/repro."""
    here = Path(__file__).resolve()
    for cand in here.parents:
        if (cand / "src" / "repro").is_dir():
            return cand
    return Path.cwd()


def main(argv: List[str] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant linter + contract checker")
    parser.add_argument("paths", nargs="*",
                        help=f"files/dirs to lint (default: "
                             f"{', '.join(DEFAULT_ROOTS)} under the repo "
                             f"root)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 on any unsuppressed, unbaselined "
                             "finding (and on contract failures)")
    parser.add_argument("--baseline", default=None,
                        help=f"baseline file (default: {BASELINE_NAME} at "
                             f"the repo root)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="accept all current findings into the baseline")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline (report everything)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule ids to run (default all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--contracts", nargs="?", const="all", default=None,
                        metavar="NAMES",
                        help="additionally run the jaxpr/contract checks "
                             "(all, or a comma-separated subset: "
                             "donation-guard, recompile-sentinel, dp-seams, "
                             "masked-seams, pallas-plans, "
                             "quarantine-rollback)")
    args = parser.parse_args(argv)

    root = repo_root()
    from .lint import all_rules, lint_paths
    from .findings import load_baseline, save_baseline, split_baselined

    if args.list_rules:
        for rid, cls in sorted(all_rules().items()):
            print(f"{rid:22s} {cls.contract}")
        return 0

    rule_ids = ([r.strip() for r in args.rules.split(",") if r.strip()]
                if args.rules else None)
    paths = ([Path(p) for p in args.paths] if args.paths
             else [root / r for r in DEFAULT_ROOTS if (root / r).exists()])
    try:
        findings = lint_paths(paths, root, rule_ids=rule_ids)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    baseline_path = (Path(args.baseline) if args.baseline
                     else root / BASELINE_NAME)
    if args.write_baseline:
        save_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0
    baseline = [] if args.no_baseline else load_baseline(baseline_path)
    new, baselined = split_baselined(findings, baseline)

    for f in new:
        print(f.format())
    if baselined:
        print(f"({len(baselined)} baselined finding(s) suppressed; "
              f"--no-baseline to show)")

    failed = bool(new)
    if args.contracts is not None:
        names = (None if args.contracts == "all"
                 else [n.strip() for n in args.contracts.split(",")
                       if n.strip()])
        from .contracts import ensure_host_devices, run_contracts
        ensure_host_devices(2)
        try:
            results = run_contracts(names)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        for name, problems in results.items():
            status = "FAIL" if problems else "ok"
            print(f"contract {name}: {status}")
            for p in problems:
                print(f"  - {p}")
            failed = failed or bool(problems)

    if not failed:
        print("analysis clean" + ("" if args.contracts is None
                                  else " (lint + contracts)"))
        return 0
    # informational mode still reports, but only --strict gates
    return 1 if args.strict else 0


if __name__ == "__main__":
    sys.exit(main())
