"""Pallas plan checker: pad plans, logical output shapes, accumulators.

Three layers of the kernel contract (DESIGN.md §7), checked without
executing a single kernel:

1. **Pad-plan invariants** — for a hostile sweep of real geometries
   (primes, 1568 = 28·28·2, tiny readouts), every ``pad_spec`` /
   ``pad_hc_spec`` plan must produce an aligned block that divides the
   padded dim exactly (BlockSpec shapes divide the padded extents — the
   Mosaic precondition), pad minimally, and keep hypercolumns whole per
   block (per-HC softmax stays block-local).
2. **Logical output shapes** — ``jax.eval_shape`` over every registered
   public kernel wrapper on a deliberately misaligned geometry: the
   wrapper must slice its padded outputs back to the logical shapes, so
   padding can never leak into a caller.
3. **Accumulator dtypes** — an AST scan of ``kernels/*.py`` asserting
   every ``pltpu.VMEM`` scratch buffer carries its kernel's declared
   accumulator dtype (f32 everywhere; i32 for the exact int8 kernels)
   and every kernel matmul pins ``preferred_element_type`` to f32.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Any, Callable, Dict, List, Tuple

# Declared accumulator contract per kernel module: the dtypes VMEM
# scratch buffers are allowed to carry.  quant.py accumulates exact int8
# products in i32 (DESIGN.md §8); everything else accumulates f32.
KERNEL_ACCUMULATOR_DTYPES: Dict[str, set] = {
    "bcpnn_fwd.py": {"jnp.float32"},
    "bcpnn_update.py": {"jnp.float32"},
    "hc_softmax.py": {"jnp.float32"},
    "patchy.py": {"jnp.float32"},
    "quant.py": {"jnp.int32"},
}

# Geometry sweep for the pad-plan invariants: the repo's real shapes
# (Model 1's 1568-unit pre side, 10/2-class readouts) plus primes and
# degenerate sizes that historically exposed fit-down-to-divisor bugs.
_DIMS = (1, 2, 3, 5, 7, 8, 10, 13, 16, 21, 100, 127, 128, 129, 130, 200,
         1009, 1568)
_BLOCKS = (8, 16, 128, 512)
_HC_GEOMS = ((1, 2), (1, 10), (3, 10), (7, 3), (28, 2), (32, 128),
             (13, 5), (784, 2))


def check_pad_plans() -> List[str]:
    """Layer 1: pad_spec/pad_hc_spec invariants over the hostile sweep."""
    from ..kernels.tiling import (
        LANE, SUBLANE, lane_multiple, pad_hc_spec, pad_spec, round_up,
    )
    import math

    problems: List[str] = []
    for dim in _DIMS:
        for block in _BLOCKS:
            for multiple in (SUBLANE, lane_multiple(dim)):
                ps = pad_spec(dim, block, multiple)
                where = (f"pad_spec(dim={dim}, block={block}, "
                         f"multiple={multiple})")
                if ps.block % multiple != 0:
                    problems.append(f"{where}: block {ps.block} is not "
                                    f"aligned to {multiple}")
                if ps.padded % ps.block != 0:
                    problems.append(f"{where}: block {ps.block} does not "
                                    f"divide padded {ps.padded}")
                if ps.padded < dim:
                    problems.append(f"{where}: padded {ps.padded} < dim")
                if ps.padded > round_up(dim, multiple):
                    problems.append(
                        f"{where}: padded {ps.padded} over-pads (a "
                        f"{multiple}-aligned block reaches "
                        f"{round_up(dim, multiple)})")
    for n_hc, n_mc in _HC_GEOMS:
        for block_units in (128, 512, 2048):
            hs = pad_hc_spec(n_hc, n_mc, block_units)
            where = f"pad_hc_spec({n_hc}, {n_mc}, {block_units})"
            if hs.mc_padded < n_mc:
                problems.append(f"{where}: mc_padded {hs.mc_padded} < n_mc")
            if hs.hc.padded % hs.hc.block != 0:
                problems.append(f"{where}: HC block {hs.hc.block} does not "
                                f"divide padded HC count {hs.hc.padded}")
            # whole 128-lane tiles per block: the HC-count block must be a
            # multiple of LANE/gcd(mc_padded, LANE)
            hq = LANE // math.gcd(hs.mc_padded, LANE)
            if hs.hc.block % hq != 0:
                problems.append(
                    f"{where}: HC block {hs.hc.block} breaks whole-lane "
                    f"tiling (needs a multiple of {hq})")
    return problems


def _hostile_shapes() -> Tuple[Dict[str, int], Any, Any, Any, Any]:
    """One deliberately misaligned geometry shared by every wrapper
    check: B=5, pre 7×3 (Ni=21), post 3×10 (Nj=30), nact=2 (K=6) —
    nothing divides 8 or 128."""
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as S

    f32, i8, i32 = jnp.float32, jnp.int8, jnp.int32
    d = dict(b=5, hi=7, mi=3, hj=3, mj=10, nact=2)
    d["ni"] = d["hi"] * d["mi"]
    d["nj"] = d["hj"] * d["mj"]
    d["k"] = d["nact"] * d["mi"]
    return d, S, f32, i8, i32


def check_output_shapes() -> List[str]:
    """Layer 2: every registered kernel wrapper returns LOGICAL shapes."""
    import jax
    import jax.numpy as jnp
    from ..kernels.bcpnn_fwd import bcpnn_fwd_pallas
    from ..kernels.bcpnn_update import bcpnn_update_pallas
    from ..kernels.hc_softmax import hc_softmax_pallas
    from ..kernels.patchy import (
        compact_forward, compact_update, patchy_forward, patchy_update,
    )
    from ..kernels.quant import (
        quant_compact_forward, quant_fwd_pallas, quant_patchy_forward,
    )

    d, S, f32, i8, i32 = _hostile_shapes()
    b, ni, nj, hi, mi, hj, mj, k = (d["b"], d["ni"], d["nj"], d["hi"],
                                    d["mi"], d["hj"], d["mj"], d["k"])
    x = S((b, ni), f32)
    w = S((ni, nj), f32)
    bias = S((nj,), f32)
    table = S((hj, d["nact"]), i32)
    scale = S((hj,), f32)
    alpha = S((), f32)

    # name -> (thunk, expected output shapes)
    cases: Dict[str, Tuple[Callable, Tuple[Tuple[int, ...], ...]]] = {
        "hc_softmax": (lambda: jax.eval_shape(
            lambda s: hc_softmax_pallas(s, hj, mj), S((b, nj), f32)),
            ((b, nj),)),
        "bcpnn_fwd": (lambda: jax.eval_shape(
            lambda *a: bcpnn_fwd_pallas(*a, n_hc=hj, n_mc=mj), x, w, bias),
            ((b, nj),)),
        "bcpnn_update": (lambda: jax.eval_shape(
            lambda pij, lpi, lpj, xx, yy, mask, al: bcpnn_update_pallas(
                pij, lpi, lpj, xx, yy, mask, al),
            S((ni, nj), f32), S((ni,), f32), S((nj,), f32), x,
            S((b, nj), f32), S((ni, nj), f32), alpha),
            ((ni, nj), (ni, nj))),
        "patchy_forward": (lambda: jax.eval_shape(
            lambda xx, ww, bb, tt: patchy_forward(xx, ww, bb, tt, mi, hj, mj),
            x, w, bias, table), ((b, nj),)),
        "patchy_update": (lambda: jax.eval_shape(
            lambda pij, lpi, lpj, xx, yy, tt, al: patchy_update(
                pij, lpi, lpj, xx, yy, tt, al, mi, hj, mj),
            S((ni, nj), f32), S((ni,), f32), S((nj,), f32), x,
            S((b, nj), f32), table, alpha), ((ni, nj), (ni, nj))),
        "compact_forward": (lambda: jax.eval_shape(
            lambda xx, wc, bb, tt: compact_forward(xx, wc, bb, tt, mi),
            x, S((hj, k, mj), f32), bias, table), ((b, nj),)),
        "compact_update": (lambda: jax.eval_shape(
            lambda pc, lpi, lpj, xx, yy, tt, al: compact_update(
                pc, lpi, lpj, xx, yy, tt, al, mi),
            S((hj, k, mj), f32), S((ni,), f32), S((nj,), f32), x,
            S((b, nj), f32), table, alpha),
            ((hj, k, mj), (hj, k, mj))),
        "quant_fwd": (lambda: jax.eval_shape(
            lambda xx, wq, bb, ss: quant_fwd_pallas(xx, wq, bb, ss, hj, mj),
            x, S((ni, nj), i8), bias, scale), ((b, nj),)),
        "quant_patchy_forward": (lambda: jax.eval_shape(
            lambda xx, wq, bb, ss, tt: quant_patchy_forward(
                xx, wq, bb, ss, tt, mi, hj, mj),
            x, S((ni, nj), i8), bias, scale, table), ((b, nj),)),
        "quant_compact_forward": (lambda: jax.eval_shape(
            lambda xx, wq, bb, ss, tt: quant_compact_forward(
                xx, wq, bb, ss, tt, mi),
            x, S((hj, k, mj), i8), bias, scale, table), ((b, nj),)),
    }

    from ..kernels.ops import _KERNEL_BLOCKS
    problems: List[str] = []
    missing = set(_KERNEL_BLOCKS) - set(cases)
    if missing:
        problems.append(f"kernels registered in ops._KERNEL_BLOCKS but not "
                        f"shape-checked here: {sorted(missing)} — add cases")
    for name, (thunk, expected) in cases.items():
        try:
            out = thunk()
        except Exception as e:  # noqa: BLE001 — any trace failure is the finding
            problems.append(f"{name}: abstract eval failed on the hostile "
                            f"geometry: {type(e).__name__}: {e}")
            continue
        shapes = tuple(o.shape for o in jax.tree_util.tree_leaves(out))
        if shapes != expected:
            problems.append(f"{name}: output shapes {shapes} != logical "
                            f"{expected} — padded extents leaked past the "
                            f"wrapper's unpad slice")
    return problems


def check_accumulators(kernels_dir: Path = None) -> List[str]:
    """Layer 3: VMEM scratch dtypes + preferred_element_type, by AST."""
    if kernels_dir is None:
        kernels_dir = Path(__file__).resolve().parent.parent / "kernels"
    problems: List[str] = []
    for fname, allowed in sorted(KERNEL_ACCUMULATOR_DTYPES.items()):
        fpath = kernels_dir / fname
        if not fpath.exists():
            problems.append(f"{fname}: declared in "
                            f"KERNEL_ACCUMULATOR_DTYPES but missing on disk")
            continue
        tree = ast.parse(fpath.read_text(encoding="utf-8"))
        n_vmem = n_dot = 0
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = _dotted(node.func)
            if name.endswith("VMEM") and len(node.args) >= 2:
                n_vmem += 1
                dt = _dotted(node.args[1])
                if dt.split(".")[-1] not in {a.split(".")[-1]
                                             for a in allowed}:
                    problems.append(
                        f"{fname}:{node.lineno}: VMEM scratch dtype {dt!r} "
                        f"violates the declared accumulator contract "
                        f"{sorted(allowed)}")
            if name.endswith("dot") or name.endswith("dot_general"):
                n_dot += 1
                pet = next((kw.value for kw in node.keywords
                            if kw.arg == "preferred_element_type"), None)
                if pet is None or _dotted(pet).split(".")[-1] != "float32":
                    problems.append(
                        f"{fname}:{node.lineno}: kernel matmul without "
                        f"preferred_element_type=jnp.float32 — accumulation "
                        f"precision is part of the kernel contract")
        if fname != "hc_softmax.py" and n_dot == 0:
            problems.append(f"{fname}: expected at least one kernel matmul "
                            f"to audit, found none (scan out of date?)")
    return problems


def _dotted(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def check_pallas_plans() -> List[str]:
    """All three layers; empty list = the kernel plan contract holds."""
    return (check_pad_plans() + check_output_shapes()
            + check_accumulators())
