"""The repo's invariant catalog as machine-checked AST rules.

Each rule encodes one contract that previously lived only in DESIGN.md
prose (§§6–8) and in tests that catch violations late; DESIGN.md §9
carries the human-readable catalog (contract, rationale, and which
historical bug each rule would have caught at review time).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .findings import Finding
from .lint import Module, Rule, dotted_name, register

# --------------------------------------------------------------- helpers --


def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """donate_argnums of a ``jax.jit(...)``-like call, or None."""
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = []
                for e in v.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, int):
                        out.append(e.value)
                return tuple(out)
            return ()  # dynamic value: positions unknown
    return None


def _is_jit_call(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    if name.endswith("jax.jit") or name == "jit" or name == "jax.jit":
        return True
    # functools.partial(jax.jit, ...)
    if name.endswith("partial") and call.args:
        return dotted_name(call.args[0]).endswith("jit")
    return False


def _function_scopes(tree: ast.Module) -> Iterator[ast.AST]:
    """Yield every function/lambda scope plus the module itself."""
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            yield node


# ============================================================ donated-jit --


@register
class DonatedReuseRule(Rule):
    """No read (or cache) of a buffer passed through ``donate_argnums``
    after the donating call — the callee owns it and XLA may have
    already reused its memory (the PR 6 ``cached_table`` dead-buffer
    class: ``Array has been deleted`` at best, silent garbage at worst).
    """

    id = "donated-reuse"
    contract = ("a variable passed at a donate_argnums position must not "
                "be read after the donating call unless rebound first")

    def check(self, module: Module) -> List[Finding]:
        # Pass 1: names bound to donating jits, with donated positions.
        # Covers ``f = jax.jit(g, donate_argnums=...)`` at any scope and
        # ``@partial(jax.jit, donate_argnums=...)`` decorators.
        donors: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                pos = (_donate_positions(node.value)
                       if _is_jit_call(node.value) else None)
                if pos:
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            donors[t.id] = pos
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) and _is_jit_call(dec):
                        pos = _donate_positions(dec)
                        if pos:
                            donors[node.name] = pos
        if not donors:
            return []

        out: List[Finding] = []
        for scope in _function_scopes(module.tree):
            body = getattr(scope, "body", None)
            if not isinstance(body, list):
                continue
            out.extend(self._check_scope(module, body, donors))
        return out

    def _check_scope(self, module: Module, body: List[ast.stmt],
                     donors: Dict[str, Tuple[int, ...]]) -> List[Finding]:
        # Linear scan of the statement list: a call to a donor taints the
        # Name args at donated positions; a later load of a tainted name
        # is a finding; a store (rebind) clears the taint.  Nested
        # function bodies are separate scopes (handled by the caller), so
        # prune them here.
        tainted: Dict[str, int] = {}  # name -> donating call line
        out: List[Finding] = []

        def visit(node: ast.AST) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                return
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                # evaluation order: RHS first (a donating call taints its
                # args), THEN the targets (a rebind clears the taint) —
                # this is what makes `state = step(state)` clean
                if node.value is not None:
                    visit(node.value)
                for t in (node.targets if isinstance(node, ast.Assign)
                          else [node.target]):
                    visit(t)
                return
            if isinstance(node, ast.Call):
                fname = dotted_name(node.func)
                base = fname.split(".")[-1] if fname else ""
                pos = donors.get(fname) or donors.get(base)
                if pos:
                    # visit args first: using a tainted name AS an arg of
                    # a second donating call is itself a use-after-donate
                    for child in ast.iter_child_nodes(node):
                        visit(child)
                    for p in pos:
                        if p < len(node.args) and isinstance(node.args[p],
                                                             ast.Name):
                            tainted[node.args[p].id] = node.lineno
                    return
            if isinstance(node, ast.Name):
                if isinstance(node.ctx, ast.Store):
                    tainted.pop(node.id, None)
                elif isinstance(node.ctx, ast.Load) and node.id in tainted:
                    out.append(module.finding(
                        self.id, node,
                        f"'{node.id}' was donated to a jit "
                        f"(donate_argnums) at line {tainted[node.id]} and "
                        f"read afterwards; its buffer may be deleted or "
                        f"reused — recompute it from the call's result or "
                        f"drop the donation"))
                    del tainted[node.id]  # one finding per donation
                return
            for child in ast.iter_child_nodes(node):
                visit(child)

        for stmt in body:
            visit(stmt)
        return out


# ======================================================== pad-fill hygiene --


_INF_NAMES = {"jnp.inf", "np.inf", "numpy.inf", "math.inf", "jax.numpy.inf"}


def _is_inf(node: ast.AST) -> bool:
    """Positive infinity in any spelling (the USub parent makes it a fill)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float) and node.value == float("inf")
    if isinstance(node, ast.Attribute):
        return dotted_name(node) in _INF_NAMES
    if isinstance(node, ast.Call) and dotted_name(node.func) == "float":
        return bool(node.args) and isinstance(node.args[0], ast.Constant) \
            and str(node.args[0].value).strip() == "inf"
    return False


def _inf_repr(node: ast.AST) -> str:
    return dotted_name(node) or "float('inf')"


@register
class PadFillLiteralRule(Rule):
    """Softmax-lane pad fills come from ``kernels.tiling.NEG`` /
    ``kernels.padding.clamp_fill`` — never hand-rolled ``-1e30`` / -inf
    literals, which overflow to -inf on a bf16/f16 cast and turn all-pad
    hypercolumns into ``-inf - (-inf) = NaN`` inside the softmax."""

    id = "pad-fill-literal"
    contract = ("no hand-rolled -1e30 / -inf fill values; use "
                "kernels.tiling.NEG or kernels.padding.clamp_fill")

    # repro: suppress[pad-fill-literal] — the rule's own magnitude threshold
    _FILL_MAG = 1e30

    def check(self, module: Module) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            bad: Optional[str] = None
            if isinstance(node, ast.Constant) and isinstance(
                    node.value, float) and node.value == node.value and \
                    abs(node.value) >= self._FILL_MAG and \
                    abs(node.value) != float("inf"):
                # huge finite magnitudes are fills whatever their sign
                # (the source text `-1e30` parses as USub over this node)
                bad = repr(node.value)
            elif isinstance(node, ast.UnaryOp) and \
                    isinstance(node.op, ast.USub) and _is_inf(node.operand):
                bad = f"-{_inf_repr(node.operand)}"
            elif isinstance(node, ast.Call) and \
                    dotted_name(node.func) == "float" and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    str(node.args[0].value).strip() == "-inf":
                bad = "float('-inf')"
            elif isinstance(node, ast.Attribute) and \
                    dotted_name(node).endswith(".NINF"):
                bad = dotted_name(node)
            if bad is not None:
                out.append(module.finding(
                    self.id, node,
                    f"hand-rolled fill literal {bad}: take softmax-lane "
                    f"fills from kernels.tiling.NEG (clamped per-dtype by "
                    f"kernels.padding.clamp_fill) so narrow-float casts "
                    f"stay NaN-free"))
        return out


# ===================================================== serve-lock discipline --


_MUTATORS = {"append", "appendleft", "extend", "extendleft", "insert",
             "pop", "popleft", "popitem", "remove", "update", "setdefault",
             "add", "discard"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _lock_name(item: ast.withitem) -> Optional[str]:
    attr = _self_attr(item.context_expr)
    if attr is not None and "lock" in attr.lower():
        return attr
    return None


class _Mutation:
    __slots__ = ("attr", "node", "kind")

    def __init__(self, attr: str, node: ast.AST, kind: str) -> None:
        self.attr, self.node, self.kind = attr, node, kind


def _mutations(node: ast.AST) -> List[_Mutation]:
    """self-attribute mutations in a statement subtree: assignments,
    augmented assignments, subscript stores, and container-mutator calls.
    """
    out: List[_Mutation] = []
    for n in ast.walk(node):
        targets: Sequence[ast.AST] = ()
        if isinstance(n, ast.Assign):
            targets = n.targets
        elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
            targets = (n.target,)
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                out.append(_Mutation(attr, t, "assignment"))
            if isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)
                if attr is not None:
                    out.append(_Mutation(attr, t, "item assignment"))
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr in _MUTATORS:
            attr = _self_attr(n.func.value)
            if attr is not None:
                out.append(_Mutation(attr, n, f".{n.func.attr}() call"))
    return out


@register
class ServeLockRule(Rule):
    """Any ``self`` attribute a class ever mutates under a
    ``with self.<...lock...>:`` block is lock-guarded state: every other
    mutation of it (outside ``__init__``) must also hold a lock,
    otherwise the serving engine's telemetry/registry invariants race."""

    id = "serve-lock"
    contract = ("an attribute mutated under `with self._lock` is never "
                "written without a lock outside __init__")

    def check(self, module: Module) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                out.extend(self._check_class(module, node))
        return out

    def _check_class(self, module: Module,
                     cls: ast.ClassDef) -> List[Finding]:
        guarded: Dict[str, str] = {}      # attr -> lock attr
        inside_lock: Set[int] = set()     # ids of nodes under any lock
        for n in ast.walk(cls):
            if isinstance(n, ast.With):
                locks = [ln for item in n.items
                         for ln in (_lock_name(item),) if ln]
                if not locks:
                    continue
                for stmt in n.body:
                    for sub in ast.walk(stmt):
                        inside_lock.add(id(sub))
                    for m in _mutations_of_body(n.body):
                        guarded.setdefault(m.attr, locks[0])
        if not guarded:
            return []
        out: List[Finding] = []
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name == "__init__":
                continue  # construction precedes sharing
            for m in _mutations(fn):
                if m.attr in guarded and id(m.node) not in inside_lock:
                    out.append(module.finding(
                        self.id, m.node,
                        f"'self.{m.attr}' is mutated under "
                        f"'self.{guarded[m.attr]}' elsewhere in "
                        f"{cls.name}, but this {m.kind} holds no lock — "
                        f"take the lock or document the threading story "
                        f"with a suppression"))
        return out


def _mutations_of_body(body: List[ast.stmt]) -> List[_Mutation]:
    out: List[_Mutation] = []
    for stmt in body:
        out.extend(_mutations(stmt))
    return out


# ====================================================== serve-except sinks --


_EXC_SINKS = {
    # supervision sinks: counting or completing is NOT swallowing
    "record_crash", "_note_crash", "_die",
    "_fail_request", "_fail_requests", "_finish_exceptionally",
}


def _catches_broadly(handler: ast.ExceptHandler) -> bool:
    """True for ``except:``, ``except Exception`` and
    ``except BaseException`` (any dotted spelling, incl. tuples)."""
    t = handler.type
    if t is None:
        return True
    parts = t.elts if isinstance(t, ast.Tuple) else [t]
    for p in parts:
        name = dotted_name(p).split(".")[-1]
        if name in ("Exception", "BaseException"):
            return True
    return False


def _handler_discharges(handler: ast.ExceptHandler) -> bool:
    """True if the handler re-raises, completes a request future
    (``.error`` assignment / ``done.set()``), or calls a supervision
    sink that does."""
    for n in ast.walk(handler):
        if isinstance(n, ast.Raise):
            return True
        if isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Attribute) and t.attr == "error":
                    return True
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            if n.func.attr in _EXC_SINKS:
                return True
            if n.func.attr == "set" and \
                    isinstance(n.func.value, ast.Attribute) and \
                    n.func.value.attr == "done":
                return True
    return False


@register
class ServeExceptRule(Rule):
    """The serving worker survives exceptions BY DESIGN — but a broad
    handler that neither re-raises, completes the affected futures, nor
    routes through a supervision sink turns a crash into a silent hang:
    the caller blocks in ``result()`` forever on a request nobody will
    ever finish (the exact hazard the PR 8 supervision rework removes).
    """

    id = "serve-except"
    contract = ("an `except Exception`/bare handler under serve/ must "
                "re-raise, complete futures (.error / done.set()), or "
                "call a supervision sink (record_crash/_note_crash/"
                "_fail_*/_die)")

    def check(self, module: Module) -> List[Finding]:
        if "serve/" not in module.path.replace("\\", "/"):
            return []
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if not _catches_broadly(node):
                continue
            if _handler_discharges(node):
                continue
            out.append(module.finding(
                self.id, node,
                "broad exception handler swallows the error without "
                "re-raising, completing request futures, or recording "
                "the crash — a supervised serving path must discharge "
                "every exception (DESIGN.md §10)"))
        return out


# ============================================================= jit-purity --


_IMPURE_CALLS = {
    "jax.default_backend": "backend introspection re-initializes the "
                           "platform and is not a traced value",
    "jax.devices": "device topology is host state",
    "jax.device_count": "device topology is host state",
    "jax.local_device_count": "device topology is host state",
    "time.time": "wall-clock reads burn in trace-time values",
    "time.perf_counter": "wall-clock reads burn in trace-time values",
    "time.monotonic": "wall-clock reads burn in trace-time values",
    "time.process_time": "wall-clock reads burn in trace-time values",
    "time.sleep": "blocking the trace thread",
    "datetime.now": "wall-clock reads burn in trace-time values",
    "os.getenv": "environment reads burn in trace-time values",
    "input": "host I/O",
    "open": "host I/O",
    "print": "host I/O (use jax.debug.print / pl.debug_print)",
}
_IMPURE_PREFIXES = {
    "np.random": "host RNG is invisible to jit caching — use jax.random",
    "numpy.random": "host RNG is invisible to jit caching — use jax.random",
    "random": "host RNG is invisible to jit caching — use jax.random",
    "os.environ": "environment reads burn in trace-time values",
}


def _jitted_scopes(tree: ast.Module) -> Dict[str, str]:
    """Names of functions that run under jit or as Pallas kernel bodies.

    Detected: ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorators,
    ``name`` (or any name nested in the argument expression, e.g.
    ``jax.jit(shard_map(step, ...))``) passed to ``jax.jit(...)``, and
    the first argument of ``pl.pallas_call`` (directly or through
    ``functools.partial``).
    """
    jitted: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if (isinstance(dec, ast.Call) and _is_jit_call(dec)) or \
                        dotted_name(dec).endswith("jit"):
                    jitted[node.name] = "jitted function"
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func)
        if fname.endswith("jit") and node.args:
            for sub in ast.walk(node.args[0]):
                if isinstance(sub, ast.Name):
                    jitted.setdefault(sub.id, "jitted function")
        if fname.endswith("pallas_call") and node.args:
            target = node.args[0]
            if isinstance(target, ast.Call) and \
                    dotted_name(target.func).endswith("partial") and \
                    target.args:
                target = target.args[0]
            if isinstance(target, ast.Name):
                jitted[target.id] = "Pallas kernel body"
    return jitted


@register
class JitPurityRule(Rule):
    """Jitted functions and Pallas kernel bodies trace once and replay:
    host state read at trace time (backend queries, wall clock, host RNG,
    environment) silently freezes into the compiled program — and churns
    the jit cache when it changes."""

    id = "jit-purity"
    contract = ("no jax.default_backend/devices, wall-clock, host RNG, "
                "os.environ, or host I/O inside jitted/kernel bodies")

    def check(self, module: Module) -> List[Finding]:
        jitted = _jitted_scopes(module.tree)
        if not jitted:
            return []
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in jitted:
                out.extend(self._check_body(module, node, jitted[node.name]))
        return out

    def _check_body(self, module: Module, fn: ast.AST,
                    why: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func)
            if not fname:
                continue
            reason = _IMPURE_CALLS.get(fname)
            if reason is None:
                for prefix, r in _IMPURE_PREFIXES.items():
                    if fname == prefix or fname.startswith(prefix + "."):
                        reason = r
                        break
            if reason is None and fname.endswith(".default_backend"):
                reason = _IMPURE_CALLS["jax.default_backend"]
            if reason is not None:
                out.append(module.finding(
                    self.id, node,
                    f"impure call '{fname}' inside a {why}: {reason}"))
        return out


# ========================================================= dtype contracts --


_LOW_PRECISION = {"bfloat16", "float16", "int8"}
# The packing boundary (DESIGN.md §8): the only core functions that may
# name a low-precision dtype — they derive SERVING views, never state.
_PACK_FUNCS = {"pack_projection", "packed_support", "packed_forward",
               "pack_state", "infer_packed"}


@register
class LearningDtypeRule(Rule):
    """Learning state is fp32, full stop (DESIGN.md §8: trace increments
    ``alpha*x`` underflow in bf16).  Inside ``src/repro/core/`` only the
    pack/packed serving boundary may mention a low-precision dtype."""

    id = "learning-dtype"
    contract = ("no low-precision dtype (bf16/f16/int8) in core/ outside "
                "the pack_*/packed_* serving boundary")

    def check(self, module: Module) -> List[Finding]:
        if "core/" not in module.path.replace("\\", "/"):
            return []
        allowed_spans: List[Tuple[int, int]] = []
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in _PACK_FUNCS:
                allowed_spans.append(
                    (node.lineno, node.end_lineno or node.lineno))
        out: List[Finding] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Attribute):
                continue
            if node.attr not in _LOW_PRECISION:
                continue
            line = node.lineno
            if any(a <= line <= b for a, b in allowed_spans):
                continue
            out.append(module.finding(
                self.id, node,
                f"low-precision dtype '{dotted_name(node)}' in a core "
                f"learning-state module outside the pack_*/packed_* "
                f"serving boundary — learning state leaves are fp32 "
                f"(DESIGN.md §8)"))
        return out


@register
class InferPackMutationRule(Rule):
    """``InferPack`` is a derived, immutable view: it is constructed by
    ``pack_projection`` at fold boundaries and only ever *replaced*,
    never edited in place — a field write would desynchronize served
    weights from the fp32 state (stale int8 scales, dead tables)."""

    id = "infer-pack-mutation"
    contract = ("InferPack is constructed only in pack_projection and "
                "its fields are never assignment targets")

    _FIELDS = {"w", "b", "scale", "table"}

    def check(self, module: Module) -> List[Finding]:
        out: List[Finding] = []
        # (a) constructor calls outside pack_projection
        pack_spans: List[Tuple[int, int]] = []
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == "pack_projection":
                pack_spans.append((node.lineno, node.end_lineno or node.lineno))
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) and \
                    dotted_name(node.func).split(".")[-1] == "InferPack":
                if not any(a <= node.lineno <= b for a, b in pack_spans):
                    out.append(module.finding(
                        self.id, node,
                        "InferPack constructed outside pack_projection — "
                        "serving views are derived at fold boundaries "
                        "only (DESIGN.md §8)"))
        # (b) field stores on known packs: names assigned from
        # pack_projection/pack_state, or any `<x>.pack.<field>` chain.
        pack_vars: Set[str] = set()
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                callee = dotted_name(node.value.func).split(".")[-1]
                if callee in ("pack_projection", "pack_state"):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            pack_vars.add(t.id)
        for node in ast.walk(module.tree):
            targets: Sequence[ast.AST] = ()
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = (node.target,)
            for t in targets:
                if not isinstance(t, ast.Attribute) or \
                        t.attr not in self._FIELDS:
                    continue
                base = t.value
                is_pack = (isinstance(base, ast.Name) and
                           base.id in pack_vars) or \
                          (isinstance(base, ast.Attribute) and
                           base.attr == "pack")
                if is_pack:
                    out.append(module.finding(
                        self.id, t,
                        f"assignment to InferPack field '.{t.attr}' — "
                        f"packs are immutable derived views; re-derive "
                        f"with pack_projection/pack_state at a fold "
                        f"boundary instead"))
        return out
