"""BCPNNHead — the paper's technique as a first-class framework feature.

Attaches a BCPNN classifier to ANY architecture in the zoo: pooled hidden
states from a (frozen or training) LM trunk are rate-encoded into input
hypercolumns and fed to a full BCPNN network for online unsupervised /
semi-supervised readout.  This is the integration point that makes BCPNN
applicable across all 10 assigned architectures (DESIGN.md §4) — the trunk
trains with gradients; the head learns with the local Hebbian-Bayesian
rule, online, with no backprop through it.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .hypercolumns import encode_scalar_hcs
from .network import BCPNNConfig, BCPNNState, infer, init_network, supervised_step, unsupervised_step


@dataclasses.dataclass(frozen=True)
class BCPNNHeadConfig:
    feature_dim: int          # trunk hidden size (pooled)
    hidden_hc: int = 16
    hidden_mc: int = 64
    n_classes: int = 10
    nact_hi: int = 0          # 0 = dense connectivity
    alpha: float = 1e-2
    struct_every: int = 0
    support_noise: float = 3.0
    noise_steps: int = 50     # anneal fast: heads see few online batches
    encode_gain: float = 4.0  # rate-encoding sharpness (sigmoid temp)

    def network_config(self) -> BCPNNConfig:
        return BCPNNConfig(
            input_hc=self.feature_dim,
            input_mc=2,
            hidden_hc=self.hidden_hc,
            hidden_mc=self.hidden_mc,
            n_classes=self.n_classes,
            nact_hi=self.nact_hi if self.nact_hi > 0 else self.feature_dim,
            alpha=self.alpha,
            struct_every=self.struct_every,
            support_noise=self.support_noise,
            noise_steps=self.noise_steps,
        )


def init_head(cfg: BCPNNHeadConfig, key: jax.Array) -> BCPNNState:
    return init_network(cfg.network_config(), key)


def encode_features(feats: jax.Array, gain: float = 4.0) -> jax.Array:
    """(B, F) trunk features -> (B, 2F) rate-coded input hypercolumns.

    Features are squashed to [0,1] with a sharpened logistic before
    complement-pair encoding.  The gain matters: near-0.5 rates make
    p_ij ~ p_i p_j (no extractable information); gain ~4 pushes encodings
    toward confident (0/1) rates, which is what the Bayesian rule needs.
    """
    return encode_scalar_hcs(jax.nn.sigmoid(gain * feats))


def head_unsupervised(state: BCPNNState, cfg: BCPNNHeadConfig, feats: jax.Array) -> BCPNNState:
    return unsupervised_step(state, cfg.network_config(),
                             encode_features(feats, cfg.encode_gain))


def head_supervised(state: BCPNNState, cfg: BCPNNHeadConfig, feats: jax.Array,
                    labels: jax.Array) -> BCPNNState:
    return supervised_step(state, cfg.network_config(),
                           encode_features(feats, cfg.encode_gain), labels)


def head_predict(state: BCPNNState, cfg: BCPNNHeadConfig, feats: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return infer(state, cfg.network_config(),
                 encode_features(feats, cfg.encode_gain))
