"""BCPNN projection: a plastic, patchily-connected weight matrix between
two hypercolumnar populations, plus its probability traces.

This is the unit of work the paper's accelerator streams: activation
(support matmul + HC softmax) and plasticity (trace EMA + log-weight
recompute).  Each projection carries a ``backend`` tag in its spec:

  * ``"jnp"``    — the pure-jnp reference path implemented in this module
                   (XLA fuses it within one jit; the "sequential" baseline
                   of the paper's §4.1 comparison);
  * ``"pallas"`` — the fused stream-dataflow kernels in ``kernels/``
                   (Mosaic on TPU, interpret mode elsewhere), the
                   production hot path.

``forward`` / ``support`` / ``learn`` below are the single dispatch
point: every caller (the deep network engine, the trainer, benchmarks)
routes through them, so flipping ``ProjSpec.backend`` swaps the whole
execution stack per projection.  See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .hypercolumns import LayerGeom, hc_softmax
from .traces import Traces, init_traces, mutual_information, weights_from_traces

BACKENDS = ("jnp", "pallas")

# Serving dtypes of the dtype-polymorphic inference path (DESIGN.md §8).
# Learning state is fp32 regardless; ``infer_dtype`` governs only the
# derived inference weights a fold produces (``pack_projection``).
INFER_DTYPES = ("fp32", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class ProjSpec:
    """Static configuration of a projection.

    The trailing fields are per-projection training knobs used by the
    deep engine (core/network.py): exploration noise on the post support
    during unsupervised learning (annealed over ``noise_steps`` trace
    updates) and the structural-plasticity rewire period.
    """

    pre: LayerGeom
    post: LayerGeom
    alpha: float = 1e-3        # trace smoothing = dt / tau_p
    eps: float = 1e-4          # probability floor
    gain: float = 1.0          # softmax gain on support
    nact: Optional[int] = None  # active pre-HCs per post-HC (None = dense)
    backend: str = "jnp"       # "jnp" reference | "pallas" fused kernels
    support_noise: float = 0.0  # exploration noise amplitude (unsup. only)
    noise_steps: int = 0       # anneal horizon in trace updates
    struct_every: int = 0      # rewire period in trace updates (0 = off)
    patchy_traces: bool = False  # patchy plasticity: silent synapses carry
    #                              no dense joint trace (DESIGN.md §7)
    compact: bool = False      # compact-RESIDENT state: pij/w stored as
    #                            (Hj, K, Mj) + index-table leaf; the learn
    #                            path never materializes (Ni, Nj)
    infer_dtype: str = "fp32"  # serving dtype of the derived inference
    #                            weights: fp32 | bf16 (cast-on-fold) |
    #                            int8 (per-HC quantized); DESIGN.md §8

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        if self.infer_dtype not in INFER_DTYPES:
            raise ValueError(f"unknown infer_dtype {self.infer_dtype!r}; "
                             f"expected one of {INFER_DTYPES}")
        if self.compact and not (self.patchy_traces and is_patchy(self)):
            raise ValueError(
                "ProjSpec.compact requires patchy_traces=True and a binding "
                f"nact budget (got nact={self.nact}, pre.H={self.pre.H}, "
                f"patchy_traces={self.patchy_traces}); only nact-budgeted "
                "patchy-trace projections have a compact (Hj, K, Mj) form")

    def with_backend(self, backend: str) -> "ProjSpec":
        return dataclasses.replace(self, backend=backend)

    def with_infer_dtype(self, infer_dtype: str) -> "ProjSpec":
        return dataclasses.replace(self, infer_dtype=infer_dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Projection:
    """Learnable state of a projection (a pytree).

    Two layouts share this container (DESIGN.md §7): the dense layout
    (``w``/``traces.pij`` are (Ni, Nj), ``table`` is None) and the
    compact-resident layout of ``ProjSpec.compact`` projections
    (``w``/``traces.pij`` are (Hj, K, Mj) with K = nact·Mi, and ``table``
    holds the (Hj, nact) active-pre-HC indices — persistent state, rebuilt
    only by ``rewire``).
    """

    traces: Traces
    w: jax.Array     # (Ni, Nj) masked | (Hj, K, Mj) compact log-odds weights
    b: jax.Array     # (Nj,)    log-prior biases
    mask: jax.Array  # (Hi, Hj) float {0,1} structural connectivity
    table: Optional[jax.Array] = None  # (Hj, nact) int32, compact only


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class InferPack:
    """Derived, forward-only view of one projection in its serving dtype
    (DESIGN.md §8) — what a serve model slot actually reads per request.

    Built by ``pack_projection`` from the fp32 state at fold boundaries
    (after feedback folds and ``struct_every`` rewires, never
    per-request): ``w`` is the inference weight matrix cast (bf16) or
    per-post-HC quantized (int8, with ``scale``), in the dense (Ni, Nj)
    or compact (Hj, K, Mj) layout of its projection; ``table`` carries
    the patchy index table as *data*, so the jitted serving forward never
    re-derives it from the mask.  fp32 packs alias the projection's own
    arrays — packing is free when nothing is quantized.
    """

    w: jax.Array                       # weights in the serving dtype
    b: jax.Array                       # (Nj,) log-prior bias
    scale: Optional[jax.Array] = None  # (Hj,) per-post-HC scales, int8 only
    table: Optional[jax.Array] = None  # (Hj, nact), patchy only


def is_patchy(spec: ProjSpec) -> bool:
    """True when the projection has a binding connectivity budget."""
    return spec.nact is not None and spec.nact < spec.pre.H


def is_compact(spec: ProjSpec) -> bool:
    """True when the projection keeps its state compact-resident."""
    return spec.compact


def _compact_ops():
    # Lazy for the same reason as _pallas_ops: core.compact imports this
    # module for the Projection pytree type.
    from . import compact
    return compact


def validate_patchy_mask(mask, spec: ProjSpec, where: str = "projection") -> None:
    """Host-side guard (concrete arrays only — do NOT call under jit):
    the compact patchy kernels assume the exactly-nact mask invariant
    (``topk_mask``); a column with MORE live pre-HCs would be silently
    truncated by the index table.  Masks written by this codebase always
    satisfy it, but checkpoints predating the exactly-nact fix (or
    hand-built states) may not — fail loudly at the deployment boundary
    instead of serving wrong probabilities."""
    if not is_patchy(spec):
        return
    import numpy as np
    per_col = np.asarray(jax.device_get(mask)).sum(axis=0)
    if (per_col > spec.nact).any():
        bad = int(per_col.max())
        raise ValueError(
            f"{where}: patchy mask has a column with {bad} active pre-HCs, "
            f"exceeding nact={spec.nact}; the compact kernels would drop "
            f"connections. Rebuild the mask with topk_mask (e.g. rewire) "
            f"before serving.")


def validate_patchy_state(proj: Projection, spec: ProjSpec,
                          where: str = "projection") -> None:
    """Host-side deployment guard over the whole projection state
    (concrete arrays only — do NOT call under jit): the mask invariant of
    ``validate_patchy_mask`` plus, for compact-resident projections, that
    the persistent index table exists, has the compact shapes, and agrees
    with the mask — a table that drifted from its mask (hand-edited state,
    a bad migration) would serve through the WRONG synapses silently."""
    validate_patchy_mask(proj.mask, spec, where=where)
    if not is_compact(spec):
        return
    import numpy as np
    hj, mj = spec.post.H, spec.post.M
    k = spec.nact * spec.pre.M
    if proj.table is None:
        raise ValueError(
            f"{where}: compact-resident projection has no index table "
            f"leaf; was this state built dense? Migrate it with "
            f"scripts/migrate_ckpt.py.")
    for name, leaf, want in (("pij", proj.traces.pij, (hj, k, mj)),
                             ("w", proj.w, (hj, k, mj)),
                             ("table", proj.table, (hj, spec.nact))):
        if tuple(leaf.shape) != want:
            raise ValueError(
                f"{where}: compact leaf {name} has shape "
                f"{tuple(leaf.shape)}, expected {want}")
    if not _compact_ops().table_matches_mask(proj.mask, proj.table,
                                             spec.nact):
        mask = np.asarray(jax.device_get(proj.mask))
        table = np.asarray(jax.device_get(proj.table))
        for j in range(hj):
            live = np.flatnonzero(mask[:, j])
            if not np.array_equal(np.sort(table[j]), live):
                raise ValueError(
                    f"{where}: compact index table disagrees with the mask "
                    f"at post-HC {j} (table {np.sort(table[j]).tolist()} vs "
                    f"mask {live.tolist()}); rebuild the table from the "
                    f"mask (core.compact.build_table) before serving.")
        raise ValueError(
            f"{where}: compact index table disagrees with the mask; "
            f"rebuild it from the mask (core.compact.build_table) before "
            f"serving.")


def apply_hc_mask(w: jax.Array, mask: jax.Array, spec: ProjSpec) -> jax.Array:
    """Mask a (Ni, Nj) unit matrix with the (Hi, Hj) HC-level mask.

    Broadcast through the (Hi, Mi, Hj, Mj) view instead of materializing a
    repeated (Ni, Nj) unit mask: XLA fuses the broadcast into the multiply,
    so no O(Ni·Nj) mask array ever exists — the old ``jnp.repeat`` chain
    rebuilt one on every learn call.
    """
    hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
    w4 = w.reshape(hi, mi, hj, mj) * mask[:, None, :, None]
    return w4.reshape(spec.pre.N, spec.post.N)


def expand_hc_mask(mask: jax.Array, spec: ProjSpec) -> jax.Array:
    """(Hi, Hj) HC-level mask -> materialized (Ni, Nj) unit-level mask.

    Only for consumers that need the mask as a standalone operand (the
    dense update kernel streams it per tile); a single fused broadcast,
    not the repeat chain.  Everything else should use ``apply_hc_mask``.
    """
    hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
    m4 = jnp.broadcast_to(mask[:, None, :, None], (hi, mi, hj, mj))
    return m4.reshape(spec.pre.N, spec.post.N)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Exactly-k column mask: scores (Hi, Hj) -> float {0,1} mask with
    exactly ``k`` ones per post-HC column.

    A threshold test (``scores >= kth_value``) admits *every* pre-HC tied
    at the cutoff, silently exceeding the ``nact`` connectivity budget —
    common early in training, when many HC pairs share identical ~0 MI.
    ``jax.lax.top_k`` returns k distinct indices (ties broken by index
    order), so the scattered one-hots sum to exactly k per column.
    """
    _, idx = jax.lax.top_k(scores.T, k)  # (Hj, k) distinct row indices
    hot = jax.nn.one_hot(idx, scores.shape[0], dtype=jnp.float32)
    return jnp.sum(hot, axis=1).T  # (Hi, Hj)


def init_projection(spec: ProjSpec, key: jax.Array) -> Projection:
    """Uniform-prior traces + random initial receptive fields.

    With nact set, each post-HC starts with a random subset of nact pre-HCs
    active (the paper's "sparse, patchy connectivity"); structural
    plasticity later rewires this mask toward high-MI inputs (Fig. 5).
    """
    k_tr, key = jax.random.split(key)
    tr = init_traces(spec.pre.N, spec.post.N, spec.pre.M, spec.post.M, key=k_tr)
    if spec.nact is None or spec.nact >= spec.pre.H:
        mask = jnp.ones((spec.pre.H, spec.post.H), jnp.float32)
    else:
        scores = jax.random.uniform(key, (spec.pre.H, spec.post.H))
        mask = topk_mask(scores, spec.nact)
    w, b = weights_from_traces(tr, spec.eps)
    w = apply_hc_mask(w, mask, spec)
    proj = Projection(traces=tr, w=w, b=b, mask=mask)
    if is_compact(spec):
        # Same dense init (same key -> same active values), then gathered:
        # compact and dense references start in lockstep on active entries.
        proj = _compact_ops().compactify_projection(proj, spec)
    return proj


# ------------------------------------------------------------- dispatch --

def _pallas_ops():
    # Imported lazily: kernels.ops imports this module for the pytree
    # types, so the dependency must point one way at import time.
    from ..kernels import ops
    return ops


def _quant_ops():
    # Lazy like _pallas_ops: kernels.quant imports core.compact.
    from ..kernels import quant
    return quant


def forward(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Activation stage: rates -> post-synaptic rates.   x: (B, Ni)."""
    if spec.backend == "pallas":
        return _pallas_ops().fused_forward(proj, spec, x)
    return _forward_jnp(proj, spec, x)


def support(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Log-domain support only (used by readout/inference and the noisy
    unsupervised path).  A bare matmul has no fusion epilogue to win, so
    both backends share the jnp implementation; it is kept behind the
    dispatch point so a future support-only kernel slots in here.
    Compact-resident projections contract against the resident (Hj, K,
    Mj) weights instead of a dense matmul."""
    # Accept low-precision weight operands (the bf16 cast-on-fold tier
    # feeds this reference too): contract and accumulate in fp32.
    w = proj.w if proj.w.dtype == jnp.float32 else proj.w.astype(jnp.float32)
    b = proj.b if proj.b.dtype == jnp.float32 else proj.b.astype(jnp.float32)
    if is_compact(spec) and proj.table is not None:
        return _compact_ops().compact_support(x, w, b, proj.table,
                                              spec.pre.M)
    return b[None, :] + x @ w


def normalize(support_vals: jax.Array, spec: ProjSpec) -> jax.Array:
    """Divisive normalization of a post-population support matrix."""
    if spec.backend == "pallas":
        return _pallas_ops().hc_softmax(
            support_vals, spec.post.H, spec.post.M, spec.gain)
    return hc_softmax(support_vals, spec.post, spec.gain)


def learn(proj: Projection, spec: ProjSpec, x: jax.Array, y: jax.Array) -> Projection:
    """Plasticity stage: one streaming batch update of traces + weights."""
    if spec.backend == "pallas":
        return _pallas_ops().fused_learn(proj, spec, x, y)
    if is_compact(spec) and proj.table is not None:
        return _compact_ops().learn_compact_jnp(proj, spec, x, y)
    return _learn_jnp(proj, spec, x, y)


# ------------------------------------------- packed (serving) dispatch ----

def pack_projection(proj: Projection, spec: ProjSpec) -> InferPack:
    """Derive the forward-only ``InferPack`` of one projection from its
    fp32 state, in ``spec.infer_dtype`` — the fold-boundary half of the
    precision contract (DESIGN.md §8).  Callers decide the cadence: the
    serving engine packs after every feedback fold / rewire; ``infer``
    packs inline (per jit trace) for honest low-precision evaluation.

    Patchy projections get their index table attached here: from the
    persistent leaf (compact-resident) or via the mask-identity memo
    (``cached_table`` — dense-resident states pack on concrete arrays at
    fold boundaries, so the table is rebuilt only when the mask actually
    changed, i.e. on rewire)."""
    table = proj.table
    if table is None and is_patchy(spec):
        table = _compact_ops().cached_table(proj.mask, spec.nact)
    if spec.infer_dtype == "bf16":
        return InferPack(w=proj.w.astype(jnp.bfloat16),
                         b=proj.b.astype(jnp.bfloat16), table=table)
    if spec.infer_dtype == "int8":
        q = _quant_ops()
        if proj.w.ndim == 3:
            w_q, scale = q.quantize_compact(proj.w)
        else:
            w_q, scale = q.quantize_dense(proj.w, spec.post.H, spec.post.M)
        return InferPack(w=w_q, b=proj.b, scale=scale, table=table)
    return InferPack(w=proj.w, b=proj.b, table=table)


def packed_forward(pack: InferPack, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Activation stage from an ``InferPack`` — same dispatch contract as
    ``forward`` but over the serving-dtype weights."""
    if spec.backend == "pallas":
        return _pallas_ops().fused_packed_forward(pack, spec, x)
    return hc_softmax(packed_support(pack, spec, x), spec.post, spec.gain)


def packed_support(pack: InferPack, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Log-domain support from an ``InferPack``: fp32/bf16 contract in
    fp32; int8 runs the fixed-point reference arithmetic (quantized
    activations, scale-folded dequant).  Always returns fp32."""
    if pack.w.dtype == jnp.int8:
        q = _quant_ops()
        if pack.w.ndim == 3:
            return q.quant_support_compact_jnp(x, pack.w, pack.scale, pack.b,
                                               pack.table, spec.pre.M)
        return q.quant_support_dense_jnp(x, pack.w, pack.scale, pack.b,
                                         spec.post.H, spec.post.M)
    w = pack.w if pack.w.dtype == jnp.float32 else pack.w.astype(jnp.float32)
    b = pack.b if pack.b.dtype == jnp.float32 else pack.b.astype(jnp.float32)
    if pack.w.ndim == 3:
        return _compact_ops().compact_support(x, w, b, pack.table, spec.pre.M)
    return b[None, :] + x @ w


# ------------------------------------------------------ jnp reference ----

def _forward_jnp(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    s = support(proj, spec, x)
    return hc_softmax(s, spec.post, spec.gain)


def apply_dense_stats(proj: Projection, spec: ProjSpec, xm: jax.Array,
                      ym: jax.Array, co: jax.Array) -> Projection:
    """EMA + plasticity semantics + weight fold on dense-layout state from
    precomputed batch statistics — the single implementation behind
    ``_learn_jnp`` and the data-parallel step (which all-reduces the
    stats first, distributed/data_parallel.py), mirroring
    ``core.compact.apply_compact_stats`` for the compact layout.  Keeping
    one copy makes the single-device/DP shared-arithmetic guarantee
    structural."""
    from .traces import update_traces_from_stats

    tr = update_traces_from_stats(proj.traces, xm, ym, co, spec.alpha)
    if is_patchy(spec) and spec.patchy_traces:
        hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
        keep = proj.mask[:, None, :, None] > 0
        if is_compact(spec):
            # Compact semantics (DESIGN.md §7): a silent synapse carries no
            # evidence — its joint probability IS the independence product
            # p_i·p_j (weight 0), recomputed from the current marginals, so
            # the dense state is a pure function of what the compact layout
            # stores (and rewire ranks silent HC pairs at exactly 0 MI).
            off = jnp.outer(tr.pi, tr.pj).reshape(hi, mi, hj, mj)
        else:
            # Patchy-held semantics: silent synapses HOLD their last
            # joint-trace value (the memory-capped hardware model of the
            # dense-resident patchy path).
            off = proj.traces.pij.reshape(hi, mi, hj, mj)
        pij = jnp.where(keep, tr.pij.reshape(hi, mi, hj, mj), off)
        tr = Traces(pi=tr.pi, pj=tr.pj,
                    pij=pij.reshape(spec.pre.N, spec.post.N), t=tr.t)
    w, b = weights_from_traces(tr, spec.eps)
    w = apply_hc_mask(w, proj.mask, spec)
    return Projection(traces=tr, w=w, b=b, mask=proj.mask, table=proj.table)


def _learn_jnp(proj: Projection, spec: ProjSpec, x: jax.Array, y: jax.Array) -> Projection:
    """Dense-layout reference of all three plasticity semantics: dense
    traces, patchy-held traces, and (for a ``compact`` spec on a
    dense-layout state) the compact semantics computed densely — the
    oracle the scatter-free compact paths are tested against."""
    x, y = jax.lax.optimization_barrier((x, y))  # see update_traces
    b = x.shape[0]
    return apply_dense_stats(proj, spec, jnp.mean(x, axis=0),
                             jnp.mean(y, axis=0), (x.T @ y) / b)


def masked_inputs(x: jax.Array, y: jax.Array, valid: jax.Array):
    """Pin and pad-zero one masked stat seam: returns ``(xv, yv, n)``
    where rows with ``valid == 0`` are zeroed and ``n`` is the REAL row
    count (clamped to 1 so an all-pad batch stays finite).

    The inert-pad contract mirrors ``kernels/padding.py``: a pad row
    contributes exact zeros to every sum, so dividing the sums by ``n``
    IS the mean over genuine rows.  The barrier pins the mask products so
    the single-device and data-parallel masked programs multiply the same
    materialized buffers (distributed/data_parallel.py mirrors this seam)."""
    x, y, valid = jax.lax.optimization_barrier((x, y, valid))
    v = valid.astype(x.dtype)
    n = jnp.maximum(jnp.sum(v), 1.0)
    xv = x * v[:, None]
    yv = y * v[:, None]
    return xv, yv, n


def learn_masked(proj: Projection, spec: ProjSpec, x: jax.Array,
                 y: jax.Array, valid: jax.Array) -> Projection:
    """Plasticity step over a zero-padded tail batch: batch stats divide
    by the number of GENUINE rows (``valid`` 0/1 per row), so pad slots
    are inert rather than diluting the traces.

    Scope: like the data-parallel steps, this always computes stats on
    the jnp path even for ``backend="pallas"`` specs — the fused kernels
    bake the batch size into their grid as a static divisor, so a traced
    valid count cannot flow through them.  Only the tail batch of a fit
    takes this path (whole batches keep the backend dispatch of
    ``learn`` bit-for-bit)."""
    xv, yv, n = masked_inputs(x, y, valid)
    xv, yv = jax.lax.optimization_barrier((xv, yv))
    xm = jnp.sum(xv, axis=0) / n
    ym = jnp.sum(yv, axis=0) / n
    if is_compact(spec) and proj.table is not None:
        co_c = _compact_ops().compact_co_stats(
            xv, yv, proj.table, spec.pre.M, spec.post.M, n_valid=n)
        return _compact_ops().apply_compact_stats(proj, spec, xm, ym, co_c)
    co = (xv.T @ yv) / n
    return apply_dense_stats(proj, spec, xm, ym, co)


def maybe_rewire(proj: Projection, spec: ProjSpec) -> Projection:
    """Trace-counter-keyed structural plasticity: rewire when the
    projection's own trace clock hits a ``struct_every`` multiple, else
    pass through.  jit-safe (``lax.cond``), and the one rewire entry both
    the trainer's unsupervised step and the serving engine's
    online-learning fold go through — ``rewire`` rebuilds the mask AND
    (for compact-resident projections) the index-table leaf together, so
    a state that passed ``validate_patchy_state`` at deployment keeps its
    invariants across any number of in-deployment rewires."""
    if spec.struct_every <= 0:
        return proj
    return jax.lax.cond(
        proj.traces.t % spec.struct_every == 0,
        lambda p: rewire(p, spec),
        lambda p: p,
        proj,
    )


def rewire(proj: Projection, spec: ProjSpec) -> Projection:
    """Structural plasticity: keep the top-nact highest-MI pre-HCs per
    post-HC.  Fully on-device (beyond-paper: the paper did this on the host
    and paid a measured total-time penalty on small datasets).  Cold path:
    runs every ``struct_every`` steps, so it stays pure jnp on both
    backends.  Rewire is also where the patchy index tables turn over:
    this produces a NEW mask array, which invalidates the identity-keyed
    table memo of dense-resident projections (core.compact.cached_table),
    and ``rewire_compact`` rebuilds the persistent table leaf of
    compact-resident ones — nothing else may rebuild or mutate them.
    Compact-resident projections densify their joint trace here (the one
    O(Ni·Nj) touch of the compact layout, on the cold path only) so
    rewiring ranks over the same MI scores as the dense reference."""
    if spec.nact is None or spec.nact >= spec.pre.H:
        return proj
    if is_compact(spec) and proj.table is not None:
        return _compact_ops().rewire_compact(proj, spec)
    mi = mutual_information(
        proj.traces, spec.pre.H, spec.pre.M, spec.post.H, spec.post.M, spec.eps
    )  # (Hi, Hj)
    mask = topk_mask(mi, spec.nact)
    w, b = weights_from_traces(proj.traces, spec.eps)
    w = apply_hc_mask(w, mask, spec)
    return Projection(traces=proj.traces, w=w, b=b, mask=mask)
