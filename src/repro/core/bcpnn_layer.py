"""BCPNN projection: a plastic, patchily-connected weight matrix between
two hypercolumnar populations, plus its probability traces.

This is the unit of work the paper's accelerator streams: activation
(support matmul + HC softmax) and plasticity (trace EMA + log-weight
recompute).  Each projection carries a ``backend`` tag in its spec:

  * ``"jnp"``    — the pure-jnp reference path implemented in this module
                   (XLA fuses it within one jit; the "sequential" baseline
                   of the paper's §4.1 comparison);
  * ``"pallas"`` — the fused stream-dataflow kernels in ``kernels/``
                   (Mosaic on TPU, interpret mode elsewhere), the
                   production hot path.

``forward`` / ``support`` / ``learn`` below are the single dispatch
point: every caller (the deep network engine, the trainer, benchmarks)
routes through them, so flipping ``ProjSpec.backend`` swaps the whole
execution stack per projection.  See DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .hypercolumns import LayerGeom, hc_softmax
from .traces import Traces, init_traces, mutual_information, update_traces, weights_from_traces

BACKENDS = ("jnp", "pallas")


@dataclasses.dataclass(frozen=True)
class ProjSpec:
    """Static configuration of a projection.

    The trailing fields are per-projection training knobs used by the
    deep engine (core/network.py): exploration noise on the post support
    during unsupervised learning (annealed over ``noise_steps`` trace
    updates) and the structural-plasticity rewire period.
    """

    pre: LayerGeom
    post: LayerGeom
    alpha: float = 1e-3        # trace smoothing = dt / tau_p
    eps: float = 1e-4          # probability floor
    gain: float = 1.0          # softmax gain on support
    nact: Optional[int] = None  # active pre-HCs per post-HC (None = dense)
    backend: str = "jnp"       # "jnp" reference | "pallas" fused kernels
    support_noise: float = 0.0  # exploration noise amplitude (unsup. only)
    noise_steps: int = 0       # anneal horizon in trace updates
    struct_every: int = 0      # rewire period in trace updates (0 = off)
    patchy_traces: bool = False  # patchy plasticity: silent synapses hold
    #                              their joint trace instead of tracking the
    #                              full dense co-activation (DESIGN.md §7)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")

    def with_backend(self, backend: str) -> "ProjSpec":
        return dataclasses.replace(self, backend=backend)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Projection:
    """Learnable state of a projection (a pytree)."""

    traces: Traces
    w: jax.Array     # (Ni, Nj) masked log-odds weights
    b: jax.Array     # (Nj,)    log-prior biases
    mask: jax.Array  # (Hi, Hj) float {0,1} structural connectivity


def is_patchy(spec: ProjSpec) -> bool:
    """True when the projection has a binding connectivity budget."""
    return spec.nact is not None and spec.nact < spec.pre.H


def validate_patchy_mask(mask, spec: ProjSpec, where: str = "projection") -> None:
    """Host-side guard (concrete arrays only — do NOT call under jit):
    the compact patchy kernels assume the exactly-nact mask invariant
    (``topk_mask``); a column with MORE live pre-HCs would be silently
    truncated by the index table.  Masks written by this codebase always
    satisfy it, but checkpoints predating the exactly-nact fix (or
    hand-built states) may not — fail loudly at the deployment boundary
    instead of serving wrong probabilities."""
    if not is_patchy(spec):
        return
    import numpy as np
    per_col = np.asarray(jax.device_get(mask)).sum(axis=0)
    if (per_col > spec.nact).any():
        bad = int(per_col.max())
        raise ValueError(
            f"{where}: patchy mask has a column with {bad} active pre-HCs, "
            f"exceeding nact={spec.nact}; the compact kernels would drop "
            f"connections. Rebuild the mask with topk_mask (e.g. rewire) "
            f"before serving.")


def apply_hc_mask(w: jax.Array, mask: jax.Array, spec: ProjSpec) -> jax.Array:
    """Mask a (Ni, Nj) unit matrix with the (Hi, Hj) HC-level mask.

    Broadcast through the (Hi, Mi, Hj, Mj) view instead of materializing a
    repeated (Ni, Nj) unit mask: XLA fuses the broadcast into the multiply,
    so no O(Ni·Nj) mask array ever exists — the old ``jnp.repeat`` chain
    rebuilt one on every learn call.
    """
    hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
    w4 = w.reshape(hi, mi, hj, mj) * mask[:, None, :, None]
    return w4.reshape(spec.pre.N, spec.post.N)


def expand_hc_mask(mask: jax.Array, spec: ProjSpec) -> jax.Array:
    """(Hi, Hj) HC-level mask -> materialized (Ni, Nj) unit-level mask.

    Only for consumers that need the mask as a standalone operand (the
    dense update kernel streams it per tile); a single fused broadcast,
    not the repeat chain.  Everything else should use ``apply_hc_mask``.
    """
    hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
    m4 = jnp.broadcast_to(mask[:, None, :, None], (hi, mi, hj, mj))
    return m4.reshape(spec.pre.N, spec.post.N)


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Exactly-k column mask: scores (Hi, Hj) -> float {0,1} mask with
    exactly ``k`` ones per post-HC column.

    A threshold test (``scores >= kth_value``) admits *every* pre-HC tied
    at the cutoff, silently exceeding the ``nact`` connectivity budget —
    common early in training, when many HC pairs share identical ~0 MI.
    ``jax.lax.top_k`` returns k distinct indices (ties broken by index
    order), so the scattered one-hots sum to exactly k per column.
    """
    _, idx = jax.lax.top_k(scores.T, k)  # (Hj, k) distinct row indices
    hot = jax.nn.one_hot(idx, scores.shape[0], dtype=jnp.float32)
    return jnp.sum(hot, axis=1).T  # (Hi, Hj)


def init_projection(spec: ProjSpec, key: jax.Array) -> Projection:
    """Uniform-prior traces + random initial receptive fields.

    With nact set, each post-HC starts with a random subset of nact pre-HCs
    active (the paper's "sparse, patchy connectivity"); structural
    plasticity later rewires this mask toward high-MI inputs (Fig. 5).
    """
    k_tr, key = jax.random.split(key)
    tr = init_traces(spec.pre.N, spec.post.N, spec.pre.M, spec.post.M, key=k_tr)
    if spec.nact is None or spec.nact >= spec.pre.H:
        mask = jnp.ones((spec.pre.H, spec.post.H), jnp.float32)
    else:
        scores = jax.random.uniform(key, (spec.pre.H, spec.post.H))
        mask = topk_mask(scores, spec.nact)
    w, b = weights_from_traces(tr, spec.eps)
    w = apply_hc_mask(w, mask, spec)
    return Projection(traces=tr, w=w, b=b, mask=mask)


# ------------------------------------------------------------- dispatch --

def _pallas_ops():
    # Imported lazily: kernels.ops imports this module for the pytree
    # types, so the dependency must point one way at import time.
    from ..kernels import ops
    return ops


def forward(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Activation stage: rates -> post-synaptic rates.   x: (B, Ni)."""
    if spec.backend == "pallas":
        return _pallas_ops().fused_forward(proj, spec, x)
    return _forward_jnp(proj, spec, x)


def support(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Log-domain support only (used by readout/inference and the noisy
    unsupervised path).  A bare matmul has no fusion epilogue to win, so
    both backends share the jnp implementation; it is kept behind the
    dispatch point so a future support-only kernel slots in here."""
    return proj.b[None, :] + x @ proj.w


def normalize(support_vals: jax.Array, spec: ProjSpec) -> jax.Array:
    """Divisive normalization of a post-population support matrix."""
    if spec.backend == "pallas":
        return _pallas_ops().hc_softmax(
            support_vals, spec.post.H, spec.post.M, spec.gain)
    return hc_softmax(support_vals, spec.post, spec.gain)


def learn(proj: Projection, spec: ProjSpec, x: jax.Array, y: jax.Array) -> Projection:
    """Plasticity stage: one streaming batch update of traces + weights."""
    if spec.backend == "pallas":
        return _pallas_ops().fused_learn(proj, spec, x, y)
    return _learn_jnp(proj, spec, x, y)


# ------------------------------------------------------ jnp reference ----

def _forward_jnp(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    s = proj.b[None, :] + x @ proj.w
    return hc_softmax(s, spec.post, spec.gain)


def _learn_jnp(proj: Projection, spec: ProjSpec, x: jax.Array, y: jax.Array) -> Projection:
    tr = update_traces(proj.traces, x, y, spec.alpha)
    if is_patchy(spec) and spec.patchy_traces:
        # Patchy-trace semantics (DESIGN.md §7): silent synapses HOLD their
        # last joint-trace value rather than tracking the dense
        # co-activation — the reference for the compact patchy kernel,
        # which never computes the masked-out pairs at all.
        hi, mi, hj, mj = spec.pre.H, spec.pre.M, spec.post.H, spec.post.M
        keep = proj.mask[:, None, :, None] > 0
        pij = jnp.where(keep, tr.pij.reshape(hi, mi, hj, mj),
                        proj.traces.pij.reshape(hi, mi, hj, mj))
        tr = Traces(pi=tr.pi, pj=tr.pj,
                    pij=pij.reshape(spec.pre.N, spec.post.N), t=tr.t)
    w, b = weights_from_traces(tr, spec.eps)
    w = apply_hc_mask(w, proj.mask, spec)
    return Projection(traces=tr, w=w, b=b, mask=proj.mask)


def rewire(proj: Projection, spec: ProjSpec) -> Projection:
    """Structural plasticity: keep the top-nact highest-MI pre-HCs per
    post-HC.  Fully on-device (beyond-paper: the paper did this on the host
    and paid a measured total-time penalty on small datasets).  Cold path:
    runs every ``struct_every`` steps, so it stays pure jnp on both
    backends.  The patchy kernels' active-pre-HC index table is derived
    from ``mask`` on every call (kernels/patchy.py::active_pre_hcs), so the
    compact layout follows the rewired mask automatically."""
    if spec.nact is None or spec.nact >= spec.pre.H:
        return proj
    mi = mutual_information(
        proj.traces, spec.pre.H, spec.pre.M, spec.post.H, spec.post.M, spec.eps
    )  # (Hi, Hj)
    mask = topk_mask(mi, spec.nact)
    w, b = weights_from_traces(proj.traces, spec.eps)
    w = apply_hc_mask(w, mask, spec)
    return Projection(traces=proj.traces, w=w, b=b, mask=mask)
