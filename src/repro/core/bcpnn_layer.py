"""BCPNN projection: a plastic, patchily-connected weight matrix between
two hypercolumnar populations, plus its probability traces.

This is the unit of work the paper's accelerator streams: activation
(support matmul + HC softmax) and plasticity (trace EMA + log-weight
recompute).  Both stages have fused Pallas kernels in kernels/; the
methods here are the pure-jnp reference path, selected by ``use_pallas``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .hypercolumns import LayerGeom, hc_softmax
from .traces import Traces, init_traces, mutual_information, update_traces, weights_from_traces


@dataclasses.dataclass(frozen=True)
class ProjSpec:
    """Static configuration of a projection."""

    pre: LayerGeom
    post: LayerGeom
    alpha: float = 1e-3        # trace smoothing = dt / tau_p
    eps: float = 1e-4          # probability floor
    gain: float = 1.0          # softmax gain on support
    nact: Optional[int] = None  # active pre-HCs per post-HC (None = dense)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Projection:
    """Learnable state of a projection (a pytree)."""

    traces: Traces
    w: jax.Array     # (Ni, Nj) masked log-odds weights
    b: jax.Array     # (Nj,)    log-prior biases
    mask: jax.Array  # (Hi, Hj) float {0,1} structural connectivity


def _expand_mask(mask: jax.Array, spec: ProjSpec) -> jax.Array:
    """(Hi, Hj) HC-level mask -> (Ni, Nj) unit-level mask."""
    m = jnp.repeat(mask, spec.pre.M, axis=0)
    return jnp.repeat(m, spec.post.M, axis=1)


def init_projection(spec: ProjSpec, key: jax.Array) -> Projection:
    """Uniform-prior traces + random initial receptive fields.

    With nact set, each post-HC starts with a random subset of nact pre-HCs
    active (the paper's "sparse, patchy connectivity"); structural
    plasticity later rewires this mask toward high-MI inputs (Fig. 5).
    """
    k_tr, key = jax.random.split(key)
    tr = init_traces(spec.pre.N, spec.post.N, spec.pre.M, spec.post.M, key=k_tr)
    if spec.nact is None or spec.nact >= spec.pre.H:
        mask = jnp.ones((spec.pre.H, spec.post.H), jnp.float32)
    else:
        scores = jax.random.uniform(key, (spec.pre.H, spec.post.H))
        thresh = -jnp.sort(-scores, axis=0)[spec.nact - 1]  # per-post cutoff
        mask = (scores >= thresh).astype(jnp.float32)
    w, b = weights_from_traces(tr, spec.eps)
    w = w * _expand_mask(mask, spec)
    return Projection(traces=tr, w=w, b=b, mask=mask)


def forward(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Activation stage: rates -> post-synaptic rates.   x: (B, Ni)."""
    support = proj.b[None, :] + x @ proj.w
    return hc_softmax(support, spec.post, spec.gain)


def support(proj: Projection, spec: ProjSpec, x: jax.Array) -> jax.Array:
    """Log-domain support only (used by readout/inference paths)."""
    return proj.b[None, :] + x @ proj.w


def learn(proj: Projection, spec: ProjSpec, x: jax.Array, y: jax.Array) -> Projection:
    """Plasticity stage: one streaming batch update of traces + weights."""
    tr = update_traces(proj.traces, x, y, spec.alpha)
    w, b = weights_from_traces(tr, spec.eps)
    w = w * _expand_mask(proj.mask, spec)
    return Projection(traces=tr, w=w, b=b, mask=proj.mask)


def rewire(proj: Projection, spec: ProjSpec) -> Projection:
    """Structural plasticity: keep the top-nact highest-MI pre-HCs per
    post-HC.  Fully on-device (beyond-paper: the paper did this on the host
    and paid a measured total-time penalty on small datasets)."""
    if spec.nact is None or spec.nact >= spec.pre.H:
        return proj
    mi = mutual_information(
        proj.traces, spec.pre.H, spec.pre.M, spec.post.H, spec.post.M, spec.eps
    )  # (Hi, Hj)
    thresh = -jnp.sort(-mi, axis=0)[spec.nact - 1]
    mask = (mi >= thresh).astype(jnp.float32)
    w, b = weights_from_traces(proj.traces, spec.eps)
    w = w * _expand_mask(mask, spec)
    return Projection(traces=proj.traces, w=w, b=b, mask=mask)
