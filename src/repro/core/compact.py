"""Compact-resident patchy state: the (Hj, K, Mj) layout and its jnp path.

A patchy projection with an ``nact`` connectivity budget has only
``K = nact * Mi`` live pre-synaptic units per post-HC.  The paper's
accelerator keeps exactly that compact window resident on chip; the dense
emulation (PR 3's ``patchy_traces`` path) kept the joint trace in the
shared (Ni, Nj) layout and paid an O(Ni·Nj) gather + scatter around every
compact kernel call.  This module makes the compact layout the *resident*
state format (``ProjSpec.compact``): ``pij`` and ``w`` are stored as
``(Hj, K, Mj)``, the ``(Hj, nact)`` active-pre-HC index table is a leaf of
the projection state (rebuilt only by ``rewire``), and the hot learn path
never touches an (Ni, Nj) array.

Semantics of the compact state (DESIGN.md §7): a silent synapse carries no
evidence — its joint probability is *defined* as the independence product
``p_i · p_j`` (weight exactly 0) rather than a held stale value.  That
definition is what makes the layout lossless: the dense equivalent of a
compact state is a pure function of the stored leaves
(``densify_pij``), so the ``struct_every`` cold path can materialize the
dense trace, rank HC pairs by mutual information (silent pairs contribute
exactly 0) and re-gather under the new mask — and a dense-compute jnp
reference of the same semantics exists for parity tests
(``core.bcpnn_layer._learn_jnp`` on a dense-layout state with a compact
spec).  Newly-activated pairs start at independence in both.

Layout conventions shared with ``kernels/patchy.py``:

    table : (Hj, nact) int32, ascending pre-HC indices per post-HC
    x_g   : (Hj, B, K)   gathered pre-rates (x duplicated per post-HC)
    pij/w : (Hj, K, Mj)  resident compact matrices
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Tuple

import jax
import jax.numpy as jnp

from .traces import Traces, mutual_information


# ------------------------------------------------------- index tables ----

def build_table(mask: jax.Array, nact: int) -> jax.Array:
    """(Hi, Hj) exactly-nact HC mask -> (Hj, nact) int32 table of active
    pre-HC indices per post-HC, ascending (the compact stream order)."""
    _, idx = jax.lax.top_k(mask.T, nact)  # (Hj, nact) distinct rows
    return jnp.sort(idx, axis=1).astype(jnp.int32)


# Host-side memo: mask identity -> table, with a content-level fallback.
# The compact-resident state carries its table as a leaf (zero rebuilds
# on the hot path); this cache covers the remaining eager call sites that
# derive a table from a concrete mask (the dense-resident patchy forward,
# ``pack_projection`` at serving fold boundaries, state conversion,
# serving validation).  Identity keys hold the mask only weakly — a
# dropped state cannot be pinned by the cache.
_TABLE_CACHE: dict = {}
_TABLE_CONTENT_CACHE: dict = {}
_TABLE_CACHE_MAX = 64


def _deleted(arr) -> bool:
    """True if a device array's buffer no longer exists (e.g. it was an
    argument to a ``donate_argnums`` jit and got consumed)."""
    is_deleted = getattr(arr, "is_deleted", None)
    return bool(is_deleted()) if callable(is_deleted) else False


def _evict(cache: dict, dead=lambda entry: False) -> None:
    if len(cache) < _TABLE_CACHE_MAX:
        return
    for k in [k for k, v in cache.items() if dead(v)]:
        del cache[k]
    while len(cache) >= _TABLE_CACHE_MAX:
        del cache[next(iter(cache))]


def cached_table(mask: jax.Array, nact: int) -> jax.Array:
    """``build_table`` memoized on a concrete ``mask`` — by identity
    first, then by content.

    Tracers (calls under jit, where the result is part of the traced
    graph anyway) bypass the cache.  The two levels serve different
    churn: rewire produces a mask with NEW values (both levels miss —
    the one legitimate rebuild), while an online-learning fold returns a
    NEW buffer with UNCHANGED values every step (identity misses, the
    content digest hits), so across a served learning stream the table
    is rebuilt only on rewire.  The content check is one host digest of
    the (Hi, Hj) HC-level mask — bytes, not an O(Ni·Nj) array — at fold
    cadence, against a device top_k + sort saved per rebuild.
    """
    if isinstance(mask, jax.core.Tracer):
        return build_table(mask, nact)
    key = (id(mask), nact)
    hit = _TABLE_CACHE.get(key)
    if hit is not None:
        ref, table = hit
        # A cached table's buffer can be DELETED after the array was
        # handed to a donating jit (Trainer's train steps donate the
        # state, and the compact state carries the table as a leaf) —
        # a dead hit must rebuild, never be returned.
        if ref() is mask and not _deleted(table):
            return table
        del _TABLE_CACHE[key]
    import numpy as np
    host = np.asarray(jax.device_get(mask))
    ckey = (host.tobytes(), host.shape, str(host.dtype), nact)
    table = _TABLE_CONTENT_CACHE.get(ckey)
    if table is None or _deleted(table):
        table = build_table(mask, nact)
        _evict(_TABLE_CONTENT_CACHE)
        _TABLE_CONTENT_CACHE[ckey] = table
    try:
        ref = weakref.ref(mask)
    except TypeError:
        return table
    _evict(_TABLE_CACHE, dead=lambda entry: entry[0]() is None)
    _TABLE_CACHE[key] = (ref, table)
    return table


def table_matches_mask(mask, table, nact: int) -> bool:
    """Host-side vectorized check that a (Hj, nact) index table routes
    exactly the live pre-HCs of an exactly-nact (Hi, Hj) mask — the
    deployment-boundary invariant ``validate_patchy_state`` enforces at
    service construction and ``BCPNNService.revalidate`` re-checks after
    in-deployment rewires.  Scatters the table back into a mask and
    compares whole arrays (no per-column python loop): duplicate or
    out-of-range table entries produce a column with fewer than nact
    ones, which an exactly-nact mask can never match."""
    import numpy as np
    m = np.asarray(jax.device_get(mask))
    t = np.asarray(jax.device_get(table))
    hi, hj = m.shape
    if t.shape != (hj, nact) or (t < 0).any() or (t >= hi).any():
        return False
    ts = np.sort(t, axis=1)
    if nact > 1 and (np.diff(ts, axis=1) <= 0).any():
        # duplicate entries would scatter onto the same mask cell and
        # could spuriously match an under-full column — a valid table has
        # nact DISTINCT pre-HCs per row
        return False
    want = np.zeros((hi, hj), m.dtype)
    want[t, np.arange(hj)[:, None]] = 1
    return bool(np.array_equal(want, m))


def unit_indices(table: jax.Array, mi: int, k_pad: int = 0,
                 sentinel: int = -1) -> jax.Array:
    """Expand the HC table to unit-level gather indices (Hj, nact*Mi+k_pad).
    Pad slots carry ``sentinel`` (out of range): gathers fill zeros there
    and scatters drop them."""
    hj, nact = table.shape
    ui = (table[:, :, None] * mi
          + jnp.arange(mi, dtype=jnp.int32)[None, None, :]).reshape(hj, nact * mi)
    if k_pad:
        ui = jnp.concatenate(
            [ui, jnp.full((hj, k_pad), sentinel, jnp.int32)], axis=1)
    return ui


# --------------------------------------------------- gather / scatter ----

def gather_pre(x: jax.Array, ui: jax.Array) -> jax.Array:
    """x (B, Ni) -> compact (Hj, B, K): per-post-HC gather of live rates."""
    xg = jnp.take(x, ui, axis=1, mode="fill", fill_value=0.0)  # (B, Hj, K)
    return xg.transpose(1, 0, 2)


def gather_dense(dense: jax.Array, ui: jax.Array, hj: int, mj: int) -> jax.Array:
    """dense (Ni, Hj*Mj) -> compact (Hj, K, Mj): each post-HC's column
    block restricted to its live pre-unit rows (zero fill for pad rows)."""
    d3 = dense.reshape(dense.shape[0], hj, mj)
    take = lambda idx, col: jnp.take(col, idx, axis=0, mode="fill",
                                     fill_value=0.0)
    return jax.vmap(take, in_axes=(0, 1))(ui, d3)


def scatter_dense(base3: jax.Array, ui: jax.Array, vals: jax.Array) -> jax.Array:
    """Scatter compact (Hj, K, Mj) values into a (Ni, Hj, Mj) base;
    sentinel rows drop.  Cold-path only (densify / migration)."""
    put = lambda col, idx, v: col.at[idx].set(v, mode="drop")
    return jax.vmap(put, in_axes=(1, 0, 0), out_axes=1)(base3, ui, vals)


def densify_pij(pij_c: jax.Array, pi: jax.Array, pj: jax.Array,
                table: jax.Array, mi: int) -> jax.Array:
    """Dense (Ni, Nj) view of a compact joint trace: active entries from
    storage, silent entries at the independence product p_i·p_j (their
    defining value — weight 0, MI contribution exactly 0).  O(Ni·Nj):
    ``struct_every`` cold path and inspection only."""
    hj, k, mj = pij_c.shape
    ni = pi.shape[0]
    ui = unit_indices(table, mi, sentinel=ni)
    base = jnp.outer(pi, pj).reshape(ni, hj, mj)
    return scatter_dense(base, ui, pij_c).reshape(ni, hj * mj)


# ----------------------------------------------------- compact compute ----

def compact_support(x: jax.Array, w_c: jax.Array, b: jax.Array,
                    table: jax.Array, mi: int) -> jax.Array:
    """Log-domain support from compact weights: gather live pre-rates per
    post-HC and contract against the resident (Hj, K, Mj) weights."""
    hj, k, mj = w_c.shape
    ui = unit_indices(table, mi, sentinel=x.shape[1])
    xg = gather_pre(x, ui)                              # (Hj, B, K)
    s3 = jnp.einsum("jbk,jkm->bjm", xg, w_c)
    return s3.reshape(x.shape[0], hj * mj) + b[None, :]


def compact_co_stats(x: jax.Array, y: jax.Array, table: jax.Array,
                     mi: int, mj: int, n_valid=None) -> jax.Array:
    """Batch-mean compact co-activation ⟨x⊗y⟩ restricted to live pairs:
    (Hj, K, Mj).  The canonical stat contraction — the data-parallel step
    computes the same einsum on post-HC shards and all-reduces the
    disjoint partials (distributed/data_parallel.py).

    ``n_valid`` (optional traced scalar) overrides the static batch-size
    divisor: the masked tail-batch path passes pad-zeroed ``x``/``y``
    plus the REAL row count so the mean divides by genuine samples only
    (DESIGN.md §12).  ``None`` keeps the static ``/ b`` bit-for-bit."""
    x, y = jax.lax.optimization_barrier((x, y))  # one buffer per stat seam
    b = x.shape[0]
    hj = table.shape[0]
    ui = unit_indices(table, mi, sentinel=x.shape[1])
    xg = gather_pre(x, ui)                              # (Hj, B, K)
    y3 = y.reshape(b, hj, mj).transpose(1, 0, 2)        # (Hj, B, Mj)
    return jnp.einsum("jbk,jbm->jkm", xg, y3) / (b if n_valid is None
                                                 else n_valid)


def fold_weights_compact(pij_c: jax.Array, log_pi: jax.Array,
                         log_pj: jax.Array, table: jax.Array, mi: int,
                         eps: float) -> jax.Array:
    """Bayesian log-odds fold on the compact layout:
    w = log p_ij − (log p_i + log p_j), all compact-sized."""
    hj, k, mj = pij_c.shape
    ui = unit_indices(table, mi, sentinel=log_pi.shape[0])
    lpi_g = jnp.take(log_pi, ui, axis=0, mode="fill", fill_value=0.0)
    logp = jnp.log(jnp.clip(pij_c, eps * eps, 1.0))
    return logp - (lpi_g[:, :, None] + log_pj.reshape(hj, 1, mj))


# ------------------------------------------------------- compact learn ----

def apply_compact_stats(proj, spec, xm: jax.Array, ym: jax.Array,
                        co_c: jax.Array):
    """EMA + weight fold on compact state from precomputed batch stats.

    Shared by the single-device jnp learn (stats from
    ``compact_co_stats``) and the data-parallel step (stats from the
    disjoint-support all-reduce) so both run the identical fold ops.
    """
    from .bcpnn_layer import Projection
    from .traces import update_traces_from_stats

    tr = update_traces_from_stats(proj.traces, xm, ym, co_c, spec.alpha)
    log_pi = jnp.log(jnp.clip(tr.pi, spec.eps, 1.0))
    log_pj = jnp.log(jnp.clip(tr.pj, spec.eps, 1.0))
    w_c = fold_weights_compact(tr.pij, log_pi, log_pj, proj.table,
                               spec.pre.M, spec.eps)
    return Projection(traces=tr, w=w_c, b=log_pj, mask=proj.mask,
                      table=proj.table)


def learn_compact_jnp(proj, spec, x: jax.Array, y: jax.Array):
    """One streaming plasticity step on compact-resident state, pure jnp.

    The jnp-backend production path for ``ProjSpec.compact`` projections
    (and the shape-reference for the fused kernel): no (Ni, Nj) array is
    ever materialized — the co-activation, EMA and fold are all
    (Hj, K, Mj)-sized.
    """
    x, y = jax.lax.optimization_barrier((x, y))
    co_c = compact_co_stats(x, y, proj.table, spec.pre.M, spec.post.M)
    return apply_compact_stats(proj, spec, jnp.mean(x, axis=0),
                               jnp.mean(y, axis=0), co_c)


# ------------------------------------------------- layout conversions ----

def compactify_projection(proj, spec):
    """Dense-layout projection -> compact-resident (cold path).

    Active entries of pij/w are gathered; silent pij values are DROPPED —
    under the compact semantics they are defined as the independence
    product, so a dense-held state loses its stale silent values here (the
    held-trace and compact semantics agree on everything the forward pass
    and the active-entry recursion can observe).
    """
    from .bcpnn_layer import Projection
    hi, mi = spec.pre.H, spec.pre.M
    hj, mj = spec.post.H, spec.post.M
    table = cached_table(proj.mask, spec.nact)
    ui = unit_indices(table, mi, sentinel=spec.pre.N)
    tr = proj.traces
    pij_c = gather_dense(tr.pij, ui, hj, mj)
    w_c = gather_dense(proj.w, ui, hj, mj)
    return Projection(traces=Traces(pi=tr.pi, pj=tr.pj, pij=pij_c, t=tr.t),
                      w=w_c, b=proj.b, mask=proj.mask, table=table)


def densify_projection(proj, spec):
    """Compact-resident projection -> dense layout (cold path): pij silent
    entries at independence, w silent entries at 0 (their exact values
    under the compact semantics)."""
    from .bcpnn_layer import Projection
    mi = spec.pre.M
    hj, mj = spec.post.H, spec.post.M
    ni = spec.pre.N
    tr = proj.traces
    ui = unit_indices(proj.table, mi, sentinel=ni)
    pij = densify_pij(tr.pij, tr.pi, tr.pj, proj.table, mi)
    w = scatter_dense(jnp.zeros((ni, hj, mj), proj.w.dtype), ui,
                      proj.w).reshape(ni, hj * mj)
    return Projection(traces=Traces(pi=tr.pi, pj=tr.pj, pij=pij, t=tr.t),
                      w=w, b=proj.b, mask=proj.mask, table=None)


def rewire_compact(proj, spec):
    """Structural plasticity on compact state — the ``struct_every`` cold
    path, and the only place the compact layout touches O(Ni·Nj): densify
    the joint trace (silent pairs at independence -> exactly 0 MI), rank
    pre-HCs by mutual information, rebuild the mask/table, and re-gather.
    Newly-activated pairs start at the independence product (weight 0)."""
    from .bcpnn_layer import Projection, topk_mask
    hi, mi = spec.pre.H, spec.pre.M
    hj, mj = spec.post.H, spec.post.M
    tr = proj.traces
    pij_dense = densify_pij(tr.pij, tr.pi, tr.pj, proj.table, mi)
    dense_tr = Traces(pi=tr.pi, pj=tr.pj, pij=pij_dense, t=tr.t)
    scores = mutual_information(dense_tr, hi, mi, hj, mj, spec.eps)
    mask = topk_mask(scores, spec.nact)
    table = build_table(mask, spec.nact)
    ui = unit_indices(table, mi, sentinel=spec.pre.N)
    pij_c = gather_dense(pij_dense, ui, hj, mj)
    log_pi = jnp.log(jnp.clip(tr.pi, spec.eps, 1.0))
    log_pj = jnp.log(jnp.clip(tr.pj, spec.eps, 1.0))
    w_c = fold_weights_compact(pij_c, log_pi, log_pj, table, mi, spec.eps)
    return Projection(traces=Traces(pi=tr.pi, pj=tr.pj, pij=pij_c, t=tr.t),
                      w=w_c, b=log_pj, mask=mask, table=table)


# --------------------------------------------------- state conversions ----

def compact_network_spec(spec):
    """NetworkSpec with ``compact=True`` on every projection eligible for
    the compact-resident layout (patchy_traces + a binding nact budget)."""
    from .bcpnn_layer import is_patchy
    from .network import NetworkSpec

    def flip(p):
        if p.patchy_traces and is_patchy(p) and not p.compact:
            return dataclasses.replace(p, compact=True)
        return p

    return NetworkSpec(projs=tuple(flip(p) for p in spec.projs),
                       readout=flip(spec.readout))


def compactify_state(state, spec) -> Tuple[object, object]:
    """(DeepState, NetworkSpec) with every eligible projection converted
    to the compact-resident layout.  Used by ``scripts/migrate_ckpt.py``
    and tests; inference over the converted state is bit-identical (the
    forward kernels see the same gathered operands either way)."""
    from .bcpnn_layer import is_compact
    from .network import DeepState, as_spec

    spec = as_spec(spec)
    new_spec = compact_network_spec(spec)
    projs = tuple(
        compactify_projection(p, ps) if is_compact(ps) else p
        for p, ps in zip(state.projs, new_spec.projs))
    readout = (compactify_projection(state.readout, new_spec.readout)
               if is_compact(new_spec.readout) else state.readout)
    return DeepState(projs=projs, readout=readout, step=state.step,
                     key=state.key), new_spec
