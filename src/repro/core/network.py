"""Deep BCPNN: arbitrary-depth projection stacks + the execution engine.

A network is a chain of hypercolumnar populations

    input -> hidden_1 -> ... -> hidden_L -> output

with one plastic ``Projection`` per adjacent population pair plus the
supervised readout head (last hidden -> output).  ``NetworkSpec`` is the
static description (hashable — it is a jit static argument), ``DeepState``
the learnable pytree.  The engine implements the paper's three execution
modes over any depth (DESIGN.md §1):

  * unsupervised  — layerwise greedy: forward through frozen lower
                    projections, noisy forward + plasticity on the layer
                    being trained (StreamBrain-style stacking);
  * supervised    — forward through the whole frozen stack, update only
                    the readout with label one-hots as target activity;
  * inference     — forward only, no state writes (the paper's smaller /
                    faster inference-only bitstream; here a separate jit
                    path with no trace outputs).

Every projection dispatches through core.bcpnn_layer (DESIGN.md §3), so a
stack may mix ``backend="jnp"`` and ``backend="pallas"`` per projection.
The paper's three-population network is the depth-1 special case, kept as
the thin ``BCPNNConfig`` preset below.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from .bcpnn_layer import (
    InferPack,
    Projection,
    ProjSpec,
    forward,
    init_projection,
    learn,
    learn_masked,
    maybe_rewire,
    normalize,
    pack_projection,
    packed_forward,
    packed_support,
    support,
)
from .hypercolumns import LayerGeom

GeomLike = Union[LayerGeom, Tuple[int, int]]


# ---------------------------------------------------------------- spec --

@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Static description of a deep BCPNN (hashable; jit-static).

    ``projs[l]`` connects population l to population l+1 (projs[0].pre is
    the input population); ``readout`` connects the last hidden population
    to the output population (one WTA hypercolumn over the classes for
    classification).
    """

    projs: Tuple[ProjSpec, ...]
    readout: ProjSpec

    def __post_init__(self):
        if not self.projs:
            raise ValueError("NetworkSpec needs at least one stack projection")
        for a, b in zip(self.projs, self.projs[1:]):
            if a.post != b.pre:
                raise ValueError(f"population mismatch in stack: {a.post} "
                                 f"feeds {b.pre}")
        if self.projs[-1].post != self.readout.pre:
            raise ValueError("readout.pre must equal the last hidden geometry")

    @property
    def depth(self) -> int:
        """Number of plastic stack projections (= number of hidden layers)."""
        return len(self.projs)

    @property
    def input_geom(self) -> LayerGeom:
        return self.projs[0].pre

    @property
    def output_geom(self) -> LayerGeom:
        return self.readout.post

    @property
    def n_classes(self) -> int:
        return self.output_geom.N

    def with_backend(self, backend: str) -> "NetworkSpec":
        """Same network, every projection on ``backend``."""
        return NetworkSpec(
            projs=tuple(p.with_backend(backend) for p in self.projs),
            readout=self.readout.with_backend(backend),
        )

    def with_infer_dtype(self, infer_dtype: str) -> "NetworkSpec":
        """Same network, every projection serving in ``infer_dtype``."""
        return NetworkSpec(
            projs=tuple(p.with_infer_dtype(infer_dtype) for p in self.projs),
            readout=self.readout.with_infer_dtype(infer_dtype),
        )

    @property
    def uses_low_precision(self) -> bool:
        return any(p.infer_dtype != "fp32"
                   for p in self.projs + (self.readout,))


def _as_geom(g: GeomLike) -> LayerGeom:
    return g if isinstance(g, LayerGeom) else LayerGeom(*g)


def make_network_spec(
    input_geom: GeomLike,
    hidden: Sequence[GeomLike],
    n_classes: int,
    alpha: float = 1e-3,
    eps: float = 1e-4,
    gain: float = 1.0,
    nact: Optional[Sequence[Optional[int]]] = None,
    backend: str = "jnp",
    support_noise: float = 3.0,
    noise_steps: int = 500,
    struct_every: int = 0,
    patchy_traces: bool = False,
    compact: bool = False,
    infer_dtype: str = "fp32",
) -> NetworkSpec:
    """Build a NetworkSpec for a stack of ``len(hidden)`` hidden layers.

    ``nact`` (optional) gives the patchy-connectivity budget per stack
    projection (None entries = dense); ``patchy_traces`` opts those
    projections into patchy plasticity and ``compact`` additionally into
    the compact-resident (Hj, K, Mj) state layout (DESIGN.md §7).  The
    training knobs apply to every stack projection; per-projection
    overrides go through ``dataclasses.replace`` on the result.
    """
    geoms = [_as_geom(input_geom)] + [_as_geom(h) for h in hidden]
    nacts = list(nact) if nact is not None else [None] * (len(geoms) - 1)
    if len(nacts) != len(geoms) - 1:
        raise ValueError(f"nact has {len(nacts)} entries for "
                         f"{len(geoms) - 1} projections")
    # compact applies per projection (dense entries of a mixed-nact stack
    # stay dense), but a request that can apply NOWHERE is a misconfig —
    # fail like a direct ProjSpec(compact=True) would, don't silently
    # build an all-dense network.
    eligible = [na is not None and na < pre.H
                for pre, na in zip(geoms[:-1], nacts)]
    if compact and not (patchy_traces and any(eligible)):
        raise ValueError(
            "compact=True requires patchy_traces=True and at least one "
            f"projection with a binding nact budget (nact={nacts})")
    projs = tuple(
        ProjSpec(pre, post, alpha=alpha, eps=eps, gain=gain, nact=na,
                 backend=backend, support_noise=support_noise,
                 noise_steps=noise_steps, struct_every=struct_every,
                 patchy_traces=patchy_traces,
                 compact=compact and patchy_traces and ok,
                 infer_dtype=infer_dtype)
        for (pre, post, na), ok in zip(
            zip(geoms[:-1], geoms[1:], nacts), eligible)
    )
    readout = ProjSpec(geoms[-1], LayerGeom(1, n_classes), alpha=alpha,
                       eps=eps, gain=gain, nact=None, backend=backend,
                       infer_dtype=infer_dtype)
    return NetworkSpec(projs=projs, readout=readout)


# --------------------------------------------------------------- state --

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeepState:
    """All learnable state (a pytree — checkpointable, shardable)."""

    projs: Tuple[Projection, ...]
    readout: Projection
    step: jax.Array  # scalar int32 streaming-step counter
    key: jax.Array   # PRNG key for exploration noise

    # Legacy aliases for the paper's depth-1 network.
    @property
    def ih(self) -> Projection:
        return self.projs[0]

    @property
    def ho(self) -> Projection:
        return self.readout


# Back-compat name: the depth-1 state of the original three-population
# network is just a DeepState with one stack projection.
BCPNNState = DeepState


def init_deep(spec: NetworkSpec, key: jax.Array) -> DeepState:
    keys = jax.random.split(key, spec.depth + 2)
    return DeepState(
        projs=tuple(init_projection(p, k) for p, k in zip(spec.projs, keys)),
        readout=init_projection(spec.readout, keys[spec.depth]),
        step=jnp.zeros((), jnp.int32),
        key=keys[spec.depth + 1],
    )


# ---------------------------------------------------------------- modes --

def stack_rates(state: DeepState, spec: NetworkSpec, x: jax.Array,
                depth: Optional[int] = None) -> jax.Array:
    """Deterministic forward through the first ``depth`` stack projections
    (all of them by default).  x: (B, N_input)."""
    n = spec.depth if depth is None else depth
    h = x
    for l in range(n):
        h = forward(state.projs[l], spec.projs[l], h)
    return h


def _noisy_rates(proj: Projection, pspec: ProjSpec, h: jax.Array,
                 key: jax.Array) -> jax.Array:
    """Post rates with annealed exploration noise on the support.

    This is the symmetry-breaking "neuronal noise" that prevents
    minicolumn collapse and drives the soft-WTA clustering to use all
    minicolumns.  The anneal clock is the projection's own trace counter,
    so each layer of a greedy stack starts its schedule fresh.
    """
    s = support(proj, pspec, h)
    t = proj.traces.t.astype(jnp.float32)
    amp = pspec.support_noise * jnp.maximum(
        0.0, 1.0 - t / max(1, pspec.noise_steps))
    # Pin the noise draw and the scaled product: the erfinv chain and the
    # mul are otherwise duplicated/FMA-contracted per consumer fusion,
    # which breaks bit-reproducibility against the data-parallel step's
    # column-sliced noise (distributed/data_parallel.py mirrors this).
    noise = jax.lax.optimization_barrier(
        jax.random.normal(key, s.shape, s.dtype))
    s = s + jax.lax.optimization_barrier(amp * noise)
    return normalize(s, pspec)


def train_projection_step(state: DeepState, spec: NetworkSpec, h: jax.Array,
                          layer: int,
                          valid: Optional[jax.Array] = None) -> DeepState:
    """Plasticity on stack projection ``layer`` given its DIRECT input
    rates ``h`` (i.e. the frozen lower layers already applied).  The
    trainer uses this to hoist the frozen forward out of the epoch loop:
    during layer ``l``'s greedy phase the representation below it is
    deterministic, so it is computed once per phase, not once per step.

    ``valid`` (optional, (B,) 0/1) marks genuine rows of a zero-padded
    tail batch: the noisy forward still runs on every row (pad rows cost
    flops, nothing else), but the plasticity stats divide by the REAL row
    count (``learn_masked``) so pad slots are inert.  ``None`` keeps the
    whole-batch ``learn`` dispatch bit-for-bit."""
    pspec = spec.projs[layer]
    key, sub = jax.random.split(state.key)
    y = _noisy_rates(state.projs[layer], pspec, h, sub)
    if valid is None:
        proj = learn(state.projs[layer], pspec, h, y)
    else:
        proj = learn_masked(state.projs[layer], pspec, h, y, valid)
    proj = maybe_rewire(proj, pspec)
    projs = state.projs[:layer] + (proj,) + state.projs[layer + 1:]
    return DeepState(projs=projs, readout=state.readout,
                     step=state.step + 1, key=key)


def unsupervised_layer_step(state: DeepState, spec: NetworkSpec, x: jax.Array,
                            layer: int) -> DeepState:
    """One streaming batch of unsupervised learning on stack projection
    ``layer`` (projections below it are frozen feature extractors)."""
    h = stack_rates(state, spec, x, depth=layer)
    return train_projection_step(state, spec, h, layer)


def supervised_readout_step(state: DeepState, spec: NetworkSpec, x: jax.Array,
                            labels: jax.Array,
                            valid: Optional[jax.Array] = None) -> DeepState:
    """One streaming batch of the supervised readout (labels: (B,) int).
    The stack is frozen; only the readout projection learns.  ``valid``
    masks pad rows out of the readout stats (pad labels one-hot to class
    0, but their rows are zeroed before any stat sees them)."""
    h = stack_rates(state, spec, x)
    y = jax.nn.one_hot(labels, spec.n_classes, dtype=h.dtype)
    if valid is None:
        ro = learn(state.readout, spec.readout, h, y)
    else:
        ro = learn_masked(state.readout, spec.readout, h, y, valid)
    return DeepState(projs=state.projs, readout=ro,
                     step=state.step + 1, key=state.key)


def online_learn_step(state: DeepState, spec: NetworkSpec, x: jax.Array,
                      labels: jax.Array,
                      learn_stack: bool = True) -> DeepState:
    """One serving-mode learning step on a labeled batch.

    With ``learn_stack=True`` every stack projection learns from its own
    deterministic activations — post rates from the current weights, no
    exploration noise (deployment refines an already-annealed
    representation, and determinism is what makes a served learning
    stream bit-reproducible against an offline replay of the same
    batches) — with the ``struct_every`` structural-plasticity cold path
    riding along (``maybe_rewire``, keyed on each projection's own trace
    clock, so receptive fields keep refining in deployment).  The
    readout then takes the standard supervised update with label
    one-hots as target activity.

    With ``learn_stack=False`` the stack is frozen and this computes
    exactly ``supervised_readout_step`` (the readout-only online mode).

    Streaming order matches training: each layer's activations come from
    the PRE-update weights (activation stage, then plasticity stage),
    and upper layers see the frozen-lower-layer rates of this batch.
    """
    h = x
    projs = []
    for proj, pspec in zip(state.projs, spec.projs):
        y = forward(proj, pspec, h)
        if learn_stack:
            projs.append(maybe_rewire(learn(proj, pspec, h, y), pspec))
        else:
            projs.append(proj)
        h = y
    y1h = jax.nn.one_hot(labels, spec.n_classes, dtype=h.dtype)
    ro = learn(state.readout, spec.readout, h, y1h)
    return DeepState(projs=tuple(projs), readout=ro,
                     step=state.step + 1, key=state.key)


# ------------------------------------------------- packed inference ----

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class InferParams:
    """Forward-only network view in the serving dtypes (a pytree): one
    ``InferPack`` per stack projection + the readout.  Derived from the
    fp32 ``DeepState`` by ``pack_state`` at fold boundaries; this is what
    a serve model slot holds and what the jitted serving forward reads
    (DESIGN.md §8)."""

    projs: Tuple[InferPack, ...]
    readout: InferPack


def pack_state(state: DeepState, spec_or_cfg) -> InferParams:
    """Derive every projection's inference weights from the fp32 state
    in its spec'd ``infer_dtype``.  fp32 packs alias the state's arrays
    (free); bf16 casts; int8 quantizes with per-post-HC scales."""
    spec = as_spec(spec_or_cfg)
    return InferParams(
        projs=tuple(pack_projection(p, ps)
                    for p, ps in zip(state.projs, spec.projs)),
        readout=pack_projection(state.readout, spec.readout),
    )


def infer_packed(params: InferParams, spec_or_cfg, x: jax.Array,
                 valid: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """``infer`` over pre-derived ``InferParams``: the serving hot path.
    Identical to ``infer`` for all-fp32 specs (packs alias the state);
    low-precision specs serve through the cast/quantized weights packed
    at the last fold boundary — never requantized per request."""
    spec = as_spec(spec_or_cfg)
    h = x
    for pack, pspec in zip(params.projs, spec.projs):
        h = packed_forward(pack, pspec, h)
    s = packed_support(params.readout, spec.readout, h)
    probs = normalize(s, spec.readout)
    pred = jnp.argmax(probs, axis=-1)
    if valid is not None:
        keep = valid.astype(bool)
        probs = probs * keep[:, None].astype(probs.dtype)
        pred = jnp.where(keep, pred, -1)
    return probs, pred


def infer(state: DeepState, spec_or_cfg, x: jax.Array,
          valid: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Inference-only path: class probabilities + argmax predictions.

    No trace reads beyond the folded weights and no state writes — the
    analogue of the paper's resource-light inference-only configuration.
    Specs with a low-precision ``infer_dtype`` evaluate through the same
    pack + packed-forward path the serving engine uses (the packing cost
    folds into the jit trace), so offline accuracy numbers are honest
    about the serving dtype; all-fp32 specs keep the direct state reads.

    ``valid`` (optional, (B,) bool/0-1) marks genuine rows of a padded
    batch: the forward pass is row-independent, so padding rows cannot
    corrupt real ones, but their outputs are made inert (probs zeroed,
    pred = -1) so a consumer — the serving engine's shape buckets, the
    trainer's padded eval — can never mistake a pad slot for a result.
    """
    spec = as_spec(spec_or_cfg)
    if spec.uses_low_precision:
        return infer_packed(pack_state(state, spec), spec, x, valid)
    h = stack_rates(state, spec, x)
    s = support(state.readout, spec.readout, h)
    probs = normalize(s, spec.readout)
    pred = jnp.argmax(probs, axis=-1)
    if valid is not None:
        keep = valid.astype(bool)
        probs = probs * keep[:, None].astype(probs.dtype)
        pred = jnp.where(keep, pred, -1)
    return probs, pred


# ------------------------------------------------- legacy depth-1 API ----

@dataclasses.dataclass(frozen=True)
class BCPNNConfig:
    """The paper's three-population network (Table 1 schema) — a thin
    preset over NetworkSpec with exactly one hidden layer."""

    input_hc: int          # input hypercolumns (e.g. 28*28 pixels)
    input_mc: int = 2      # minicolumns per input HC (complement pairs)
    hidden_hc: int = 32
    hidden_mc: int = 128
    n_classes: int = 10
    nact_hi: int = 128     # active input HCs per hidden HC
    alpha: float = 1e-3
    eps: float = 1e-4
    gain: float = 1.0
    struct_every: int = 0  # steps between rewires; 0 = no structural plasticity
    support_noise: float = 3.0
    noise_steps: int = 500
    backend: str = "jnp"   # backend for both projections
    patchy_traces: bool = False  # patchy plasticity on the ih projection
    compact: bool = False  # compact-resident ih state (requires patchy_traces)
    infer_dtype: str = "fp32"  # serving dtype for both projections (§8)

    @property
    def input_geom(self) -> LayerGeom:
        return LayerGeom(self.input_hc, self.input_mc)

    @property
    def hidden_geom(self) -> LayerGeom:
        return LayerGeom(self.hidden_hc, self.hidden_mc)

    @property
    def output_geom(self) -> LayerGeom:
        # classification output = one WTA hypercolumn over the classes
        return LayerGeom(1, self.n_classes)

    def ih_spec(self) -> ProjSpec:
        if self.compact and not (self.patchy_traces
                                 and self.nact_hi < self.input_hc):
            raise ValueError(
                "BCPNNConfig.compact requires patchy_traces=True and "
                f"nact_hi < input_hc (got patchy_traces="
                f"{self.patchy_traces}, nact_hi={self.nact_hi}, "
                f"input_hc={self.input_hc})")
        return ProjSpec(self.input_geom, self.hidden_geom, alpha=self.alpha,
                        eps=self.eps, gain=self.gain, nact=self.nact_hi,
                        backend=self.backend,
                        support_noise=self.support_noise,
                        noise_steps=self.noise_steps,
                        struct_every=self.struct_every,
                        patchy_traces=self.patchy_traces,
                        compact=self.compact,
                        infer_dtype=self.infer_dtype)

    def ho_spec(self) -> ProjSpec:
        return ProjSpec(self.hidden_geom, self.output_geom, alpha=self.alpha,
                        eps=self.eps, gain=self.gain, nact=None,
                        backend=self.backend, infer_dtype=self.infer_dtype)

    def network_spec(self) -> NetworkSpec:
        return NetworkSpec(projs=(self.ih_spec(),), readout=self.ho_spec())


def as_spec(spec_or_cfg) -> NetworkSpec:
    """Normalize a BCPNNConfig (legacy) or NetworkSpec to a NetworkSpec."""
    if isinstance(spec_or_cfg, NetworkSpec):
        return spec_or_cfg
    return spec_or_cfg.network_spec()


# ------------------------------------------------- spec (de)serialization --

def _projspec_to_dict(p: ProjSpec) -> dict:
    d = dataclasses.asdict(p)
    d["pre"] = [p.pre.H, p.pre.M]
    d["post"] = [p.post.H, p.post.M]
    return d


def _projspec_from_dict(d: dict) -> ProjSpec:
    d = dict(d)
    d["pre"] = LayerGeom(*d["pre"])
    d["post"] = LayerGeom(*d["post"])
    return ProjSpec(**d)


def spec_to_dict(spec_or_cfg) -> dict:
    """JSON-serializable description of a NetworkSpec (checkpoint manifests,
    serving configs).  Round-trips through ``spec_from_dict``."""
    spec = as_spec(spec_or_cfg)
    return {
        "projs": [_projspec_to_dict(p) for p in spec.projs],
        "readout": _projspec_to_dict(spec.readout),
    }


def spec_from_dict(d: dict) -> NetworkSpec:
    return NetworkSpec(
        projs=tuple(_projspec_from_dict(p) for p in d["projs"]),
        readout=_projspec_from_dict(d["readout"]),
    )


def init_network(spec_or_cfg, key: jax.Array) -> DeepState:
    return init_deep(as_spec(spec_or_cfg), key)


def hidden_rates(state: DeepState, spec_or_cfg, x: jax.Array) -> jax.Array:
    return stack_rates(state, as_spec(spec_or_cfg), x)


def unsupervised_step(state: DeepState, spec_or_cfg, x: jax.Array,
                      layer: int = 0) -> DeepState:
    """One streaming batch of unsupervised representation learning."""
    return unsupervised_layer_step(state, as_spec(spec_or_cfg), x, layer)


def supervised_step(state: DeepState, spec_or_cfg, x: jax.Array,
                    labels: jax.Array) -> DeepState:
    """One streaming batch of the supervised readout (labels: (B,) int)."""
    return supervised_readout_step(state, as_spec(spec_or_cfg), x, labels)
