"""The paper's three-population network: input -> hidden -> output.

Two projections connect the populations (input-hidden and hidden-output).
The kernel supports the paper's three execution modes sharing one
pipeline:

  * unsupervised  — forward to hidden, update input-hidden plasticity
  * supervised    — forward to hidden (frozen), update hidden-output
                    plasticity with label one-hots as target activity
  * inference     — forward only, no state writes (the paper's smaller /
                    faster inference-only bitstream; here a separate jit
                    path with no trace outputs)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .bcpnn_layer import Projection, ProjSpec, forward, init_projection, learn, rewire, support
from .hypercolumns import LayerGeom, hc_softmax


@dataclasses.dataclass(frozen=True)
class BCPNNConfig:
    """Static network configuration (paper Table 1 schema)."""

    input_hc: int          # input hypercolumns (e.g. 28*28 pixels)
    input_mc: int = 2      # minicolumns per input HC (complement pairs)
    hidden_hc: int = 32
    hidden_mc: int = 128
    n_classes: int = 10
    nact_hi: int = 128     # active input HCs per hidden HC
    alpha: float = 1e-3
    eps: float = 1e-4
    gain: float = 1.0
    struct_every: int = 0  # steps between rewires; 0 = no structural plasticity
    # Exploration noise on the hidden support during unsupervised learning
    # (linearly annealed to zero over noise_steps).  This is the symmetry-
    # breaking "neuronal noise" that prevents minicolumn collapse and drives
    # the soft-WTA clustering to use all minicolumns.
    support_noise: float = 3.0
    noise_steps: int = 500

    @property
    def input_geom(self) -> LayerGeom:
        return LayerGeom(self.input_hc, self.input_mc)

    @property
    def hidden_geom(self) -> LayerGeom:
        return LayerGeom(self.hidden_hc, self.hidden_mc)

    @property
    def output_geom(self) -> LayerGeom:
        # classification output = one WTA hypercolumn over the classes
        return LayerGeom(1, self.n_classes)

    def ih_spec(self) -> ProjSpec:
        return ProjSpec(self.input_geom, self.hidden_geom, self.alpha,
                        self.eps, self.gain, self.nact_hi)

    def ho_spec(self) -> ProjSpec:
        return ProjSpec(self.hidden_geom, self.output_geom, self.alpha,
                        self.eps, self.gain, None)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BCPNNState:
    """All learnable state (a pytree — checkpointable, shardable)."""

    ih: Projection
    ho: Projection
    step: jax.Array  # scalar int32 streaming-step counter
    key: jax.Array   # PRNG key for exploration noise


def init_network(cfg: BCPNNConfig, key: jax.Array) -> BCPNNState:
    k1, k2, k3 = jax.random.split(key, 3)
    return BCPNNState(
        ih=init_projection(cfg.ih_spec(), k1),
        ho=init_projection(cfg.ho_spec(), k2),
        step=jnp.zeros((), jnp.int32),
        key=k3,
    )


# ---------------------------------------------------------------- modes --

def hidden_rates(state: BCPNNState, cfg: BCPNNConfig, x: jax.Array) -> jax.Array:
    return forward(state.ih, cfg.ih_spec(), x)


def _noisy_hidden(state: BCPNNState, cfg: BCPNNConfig, x: jax.Array,
                  key: jax.Array) -> jax.Array:
    """Hidden rates with annealed exploration noise on the support."""
    spec = cfg.ih_spec()
    s = support(state.ih, spec, x)
    amp = cfg.support_noise * jnp.maximum(
        0.0, 1.0 - state.step.astype(jnp.float32) / max(1, cfg.noise_steps))
    s = s + amp * jax.random.normal(key, s.shape, s.dtype)
    return hc_softmax(s, cfg.hidden_geom, cfg.gain)


def unsupervised_step(state: BCPNNState, cfg: BCPNNConfig, x: jax.Array) -> BCPNNState:
    """One streaming batch of unsupervised representation learning."""
    spec = cfg.ih_spec()
    key, sub = jax.random.split(state.key)
    h = _noisy_hidden(state, cfg, x, sub)
    ih = learn(state.ih, spec, x, h)
    if cfg.struct_every > 0:
        ih = jax.lax.cond(
            (state.step + 1) % cfg.struct_every == 0,
            lambda p: rewire(p, spec),
            lambda p: p,
            ih,
        )
    return BCPNNState(ih=ih, ho=state.ho, step=state.step + 1, key=key)


def supervised_step(state: BCPNNState, cfg: BCPNNConfig, x: jax.Array,
                    labels: jax.Array) -> BCPNNState:
    """One streaming batch of the supervised readout (labels: (B,) int)."""
    h = forward(state.ih, cfg.ih_spec(), x)
    y = jax.nn.one_hot(labels, cfg.n_classes, dtype=h.dtype)
    ho = learn(state.ho, cfg.ho_spec(), h, y)
    return BCPNNState(ih=state.ih, ho=ho, step=state.step + 1, key=state.key)


def infer(state: BCPNNState, cfg: BCPNNConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Inference-only path: class probabilities + argmax predictions.

    No trace reads beyond the folded weights and no state writes — the
    analogue of the paper's resource-light inference-only configuration.
    """
    h = forward(state.ih, cfg.ih_spec(), x)
    s = support(state.ho, cfg.ho_spec(), h)
    probs = hc_softmax(s, cfg.output_geom, cfg.gain)
    return probs, jnp.argmax(probs, axis=-1)
