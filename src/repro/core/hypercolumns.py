"""Hypercolumn/minicolumn geometry and divisive normalization.

A BCPNN layer is a population of H hypercolumns (HCs), each containing M
minicolumns (MCs).  Unit activity lives in a flat vector of N = H*M rates;
divisive normalization is a softmax *within* each hypercolumn, so the M
minicolumns of one HC always form a probability distribution (the paper's
"discrete probability estimate" per input attribute).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerGeom:
    """Geometry of one BCPNN population layer."""

    H: int  # hypercolumns
    M: int  # minicolumns per hypercolumn

    @property
    def N(self) -> int:
        return self.H * self.M

    def blocked(self, x: jax.Array) -> jax.Array:
        """(..., N) -> (..., H, M)."""
        return x.reshape(*x.shape[:-1], self.H, self.M)

    def flat(self, x: jax.Array) -> jax.Array:
        """(..., H, M) -> (..., N)."""
        return x.reshape(*x.shape[:-2], self.H * self.M)


def hc_softmax(support: jax.Array, geom: LayerGeom, gain: float = 1.0) -> jax.Array:
    """Softmax within each hypercolumn (divisive normalization / soft-WTA).

    support: (..., N) log-domain support values.
    Returns rates in [0, 1] summing to 1 within each HC.
    """
    s = geom.blocked(support) * gain
    s = s - jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    e = jnp.exp(s)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    return geom.flat(out)


def hc_hardmax(support: jax.Array, geom: LayerGeom) -> jax.Array:
    """One-hot winner per hypercolumn (hard-WTA), used at inference."""
    s = geom.blocked(support)
    idx = jnp.argmax(s, axis=-1)
    out = jax.nn.one_hot(idx, geom.M, dtype=support.dtype)
    return geom.flat(out)


def encode_scalar_hcs(x: jax.Array) -> jax.Array:
    """Encode scalar features in [0,1] as complementary-pair hypercolumns.

    x: (..., F) in [0, 1]  ->  (..., 2F) with each feature f becoming an HC
    of two minicolumns (x_f, 1 - x_f).  This is the standard rate encoding
    used for grayscale pixels in the BCPNN literature (each pixel = one
    input attribute; its two MCs are mutually exclusive value estimates).
    """
    x = jnp.clip(x, 0.0, 1.0)
    pair = jnp.stack([x, 1.0 - x], axis=-1)  # (..., F, 2)
    return pair.reshape(*x.shape[:-1], x.shape[-1] * 2)
