"""Exponential probability traces — the memory of a BCPNN projection.

Three traces are kept per projection (paper §3): the marginal activation
probabilities of the pre-synaptic units (p_i), of the post-synaptic units
(p_j), and their joint probability (p_ij).  All are exponential moving
averages of (batch-mean) rates with a shared smoothing factor
``alpha = dt / tau_p``.

On the FPGA these are the eight "local synaptic state variables" streamed
through FIFO stages; here they are a pytree updated by one fused kernel
(see kernels/bcpnn_update.py) or the pure-jnp path below.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Traces:
    """Probability traces of one projection (pre: Ni units, post: Nj units)."""

    pi: jax.Array   # (Ni,)  pre-synaptic marginal
    pj: jax.Array   # (Nj,)  post-synaptic marginal
    pij: jax.Array  # (Ni, Nj) joint
    t: jax.Array    # scalar int32 update counter (for bias correction)


def init_traces(ni: int, nj: int, mi: int, mj: int, dtype=jnp.float32,
                key: jax.Array | None = None, init_noise: float = 0.1) -> Traces:
    """Uniform-prior initialization: every MC equally likely within its HC.

    The joint trace gets a small multiplicative log-normal perturbation:
    without it the network is perfectly symmetric (uniform support ->
    uniform hidden activity -> p_ij == p_i p_j forever) and unsupervised
    learning can never differentiate the minicolumns.
    """
    pi0 = 1.0 / mi
    pj0 = 1.0 / mj
    pij = jnp.full((ni, nj), pi0 * pj0, dtype=dtype)
    if key is not None and init_noise > 0:
        pij = pij * jnp.exp(init_noise * jax.random.normal(key, (ni, nj), dtype))
    return Traces(
        pi=jnp.full((ni,), pi0, dtype=dtype),
        pj=jnp.full((nj,), pj0, dtype=dtype),
        pij=pij,
        t=jnp.zeros((), jnp.int32),
    )


def update_traces_from_stats(tr: Traces, xm: jax.Array, ym: jax.Array,
                             co: jax.Array, alpha: float) -> Traces:
    """EMA step from precomputed batch statistics (means + batch-mean
    co-activation).  ``co`` may be the dense (Ni, Nj) matrix or the
    compact (Hj, K, Mj) layout — the EMA is shape-agnostic as long as it
    matches ``tr.pij``.  Split out of ``update_traces`` so the
    data-parallel step (which all-reduces the stats across devices,
    distributed/data_parallel.py) applies the bit-identical fold.

    The effective smoothing is ``max(1/(t+1), alpha)``: a true running mean
    while young (bias correction away from the uniform prior — crucial for
    the single supervised pass of the paper's protocol), annealing into the
    fixed-time-constant EMA of the streaming regime.

    The stats are pinned behind an ``optimization_barrier``: XLA freely
    duplicates cheap elementwise producers into consumer fusions and
    contracts mul+add chains to FMA per fusion kernel, so without a pin
    the "same" statistic can round differently in two different programs.
    Pinning the seam makes the EMA arithmetic bit-identical between the
    single-device step and the data-parallel decomposition
    (distributed/data_parallel.py), at the cost of materializing three
    buffers that the co-activation matmul materializes anyway.
    """
    xm, ym, co = jax.lax.optimization_barrier((xm, ym, co))
    a = jnp.maximum(1.0 / (tr.t.astype(tr.pij.dtype) + 1.0),
                    jnp.asarray(alpha, tr.pij.dtype))
    one = 1.0 - a
    return Traces(
        pi=one * tr.pi + a * xm,
        pj=one * tr.pj + a * ym,
        pij=one * tr.pij + a * co,
        t=tr.t + 1,
    )


def update_traces(tr: Traces, x: jax.Array, y: jax.Array, alpha: float) -> Traces:
    """One streaming step of the Hebbian-Bayesian trace update.

    x: (B, Ni) pre-synaptic rates; y: (B, Nj) post-synaptic rates.
    The batch-mean co-activation ⟨x⊗y⟩ = XᵀY / B is an MXU matmul — the TPU
    analogue of the FPGA's joint-probability accumulation stream.

    x and y are pinned first so every statistic reads the one materialized
    buffer (XLA would otherwise duplicate a cheap producer — e.g. a
    softmax chain — into the mean's fusion with its own rounding; see
    ``update_traces_from_stats``).
    """
    x, y = jax.lax.optimization_barrier((x, y))
    b = x.shape[0]
    return update_traces_from_stats(
        tr, jnp.mean(x, axis=0), jnp.mean(y, axis=0), (x.T @ y) / b, alpha)


def weights_from_traces(
    tr: Traces, eps: float = 1e-4
) -> Tuple[jax.Array, jax.Array]:
    """Bayesian weight/bias readout:  b_j = log p_j,  w_ij = log p_ij/(p_i p_j).

    eps floors keep the logs finite for never-active units (paper keeps
    fp32; so do we — the increments alpha*x are too small for bf16).
    """
    pi = jnp.clip(tr.pi, eps, 1.0)
    pj = jnp.clip(tr.pj, eps, 1.0)
    pij = jnp.clip(tr.pij, eps * eps, 1.0)
    w = jnp.log(pij) - (jnp.log(pi)[:, None] + jnp.log(pj)[None, :])
    b = jnp.log(pj)
    return w, b


def mutual_information(tr: Traces, hi: int, mi: int, hj: int, mj: int,
                       eps: float = 1e-4) -> jax.Array:
    """Mutual information between input HC i and output HC j, (Hi, Hj).

    MI_ij = Σ_{m∈i, n∈j} p_mn log( p_mn / (p_m p_n) ) — the score that
    drives structural plasticity (which input attributes carry information
    about which hidden code).  Computed fully on device (the paper ran this
    on the host; see DESIGN.md §2).
    """
    w, _ = weights_from_traces(tr, eps)
    pij = jnp.clip(tr.pij, eps * eps, 1.0)
    contrib = pij * w  # (Ni, Nj)
    blocked = contrib.reshape(hi, mi, hj, mj)
    return jnp.sum(blocked, axis=(1, 3))  # (Hi, Hj)
