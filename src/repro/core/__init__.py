"""BCPNN core — the paper's contribution as composable JAX modules."""
from .hypercolumns import LayerGeom, encode_scalar_hcs, hc_hardmax, hc_softmax
from .traces import Traces, init_traces, mutual_information, update_traces, weights_from_traces
from .bcpnn_layer import Projection, ProjSpec, forward, init_projection, learn, rewire, support
from .network import (
    BCPNNConfig,
    BCPNNState,
    hidden_rates,
    infer,
    init_network,
    supervised_step,
    unsupervised_step,
)
from .trainer import Trainer, eval_batches, supervised_epoch, unsupervised_epoch
from .head import (
    BCPNNHeadConfig,
    encode_features,
    head_predict,
    head_supervised,
    head_unsupervised,
    init_head,
)

__all__ = [
    "LayerGeom", "encode_scalar_hcs", "hc_hardmax", "hc_softmax",
    "Traces", "init_traces", "mutual_information", "update_traces", "weights_from_traces",
    "Projection", "ProjSpec", "forward", "init_projection", "learn", "rewire", "support",
    "BCPNNConfig", "BCPNNState", "hidden_rates", "infer", "init_network",
    "supervised_step", "unsupervised_step",
    "Trainer", "eval_batches", "supervised_epoch", "unsupervised_epoch",
    "BCPNNHeadConfig", "encode_features", "head_predict", "head_supervised",
    "head_unsupervised", "init_head",
]
