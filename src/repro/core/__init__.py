"""BCPNN core — the paper's contribution as composable JAX modules."""
from .hypercolumns import LayerGeom, encode_scalar_hcs, hc_hardmax, hc_softmax
from .traces import Traces, init_traces, mutual_information, update_traces, weights_from_traces
from .bcpnn_layer import (
    BACKENDS, Projection, ProjSpec, forward, init_projection, learn,
    maybe_rewire, normalize, rewire, support, topk_mask,
)
from .network import (
    BCPNNConfig,
    BCPNNState,
    DeepState,
    NetworkSpec,
    as_spec,
    hidden_rates,
    infer,
    init_deep,
    init_network,
    make_network_spec,
    online_learn_step,
    spec_from_dict,
    spec_to_dict,
    stack_rates,
    supervised_readout_step,
    supervised_step,
    train_projection_step,
    unsupervised_layer_step,
    unsupervised_step,
)
from .trainer import (
    FitCursor, Trainer, eval_batches, evaluate_padded, supervised_epoch,
    unsupervised_epoch, unsupervised_layer_epoch,
)
from .head import (
    BCPNNHeadConfig,
    encode_features,
    head_predict,
    head_supervised,
    head_unsupervised,
    init_head,
)

__all__ = [
    "LayerGeom", "encode_scalar_hcs", "hc_hardmax", "hc_softmax",
    "Traces", "init_traces", "mutual_information", "update_traces", "weights_from_traces",
    "BACKENDS", "Projection", "ProjSpec", "forward", "init_projection",
    "learn", "maybe_rewire", "normalize", "rewire", "support", "topk_mask",
    "BCPNNConfig", "BCPNNState", "DeepState", "NetworkSpec", "as_spec",
    "hidden_rates", "infer", "init_deep", "init_network", "make_network_spec",
    "online_learn_step", "spec_from_dict", "spec_to_dict",
    "stack_rates", "supervised_readout_step", "supervised_step",
    "train_projection_step", "unsupervised_layer_step", "unsupervised_step",
    "FitCursor", "Trainer", "eval_batches", "evaluate_padded", "supervised_epoch",
    "unsupervised_epoch", "unsupervised_layer_epoch",
    "BCPNNHeadConfig", "encode_features", "head_predict", "head_supervised",
    "head_unsupervised", "init_head",
]
