"""Streaming trainer for deep BCPNN — the host-side driver of the
accelerator.

The paper's semi-unsupervised protocol (§5), generalized to any depth
(DESIGN.md §1): for each stack projection in turn, N epochs of
unsupervised representation learning (layerwise greedy — lower layers are
frozen feature extractors while a layer trains), then ONE supervised pass
on the readout projection, then inference.  Epochs run as a single jit'd
``lax.scan`` over batch-major data, so a whole epoch is one device
program — the TPU analogue of keeping the FPGA pipeline hot.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from .bcpnn_layer import forward
from .network import (
    DeepState,
    NetworkSpec,
    as_spec,
    infer,
    init_deep,
    spec_to_dict,
    supervised_readout_step,
    train_projection_step,
    unsupervised_layer_step,
)


def _batchify(x: np.ndarray, batch: int) -> np.ndarray:
    """Trim to a whole number of batches and reshape batch-major."""
    nb = x.shape[0] // batch
    return x[: nb * batch].reshape(nb, batch, *x.shape[1:])


def _batchify_padded(x: np.ndarray, batch: int):
    """Zero-pad to a whole number of batches; also return the (nb, B)
    validity mask marking genuine rows.  Unlike ``_batchify`` this loses
    no tail samples — evaluation masks the pad slots out of the mean."""
    n = x.shape[0]
    nb = max(1, -(-n // batch))
    pad = nb * batch - n
    if pad:
        x = np.concatenate(
            [x, np.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    valid = (np.arange(nb * batch) < n).astype(np.float32)
    return (x.reshape(nb, batch, *x.shape[1:]),
            valid.reshape(nb, batch))


@functools.partial(jax.jit, static_argnames=("spec", "layer"),
                   donate_argnums=(0,))
def unsupervised_layer_epoch(state: DeepState, spec: NetworkSpec,
                             xs: jax.Array, layer: int) -> DeepState:
    """xs: (nbatch, B, Ni) — one unsupervised epoch on stack projection
    ``layer``, fully on device."""
    def body(st, x):
        return unsupervised_layer_step(st, spec, x, layer), None
    state, _ = jax.lax.scan(body, state, xs)
    return state


def unsupervised_epoch(state: DeepState, spec_or_cfg, xs: jax.Array,
                       layer: int = 0) -> DeepState:
    """Legacy entry point (depth-1 networks train their only projection)."""
    return unsupervised_layer_epoch(state, as_spec(spec_or_cfg), xs, layer)


@functools.partial(jax.jit, static_argnames=("spec", "layer"),
                   donate_argnums=(0,))
def _train_projection_epoch(state: DeepState, spec: NetworkSpec,
                            hs: jax.Array, layer: int) -> DeepState:
    """One epoch over PRECOMPUTED layer-input rates hs: (nbatch, B, N_l)."""
    def body(st, h):
        return train_projection_step(st, spec, h, layer), None
    state, _ = jax.lax.scan(body, state, hs)
    return state


@functools.partial(jax.jit, static_argnames=("spec", "layer"))
def _propagate_batches(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                       layer: int) -> jax.Array:
    """Push batched rates through the (now frozen) projection ``layer``."""
    return jax.lax.map(
        lambda xb: forward(state.projs[layer], spec.projs[layer], xb), xs)


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(0,))
def _supervised_epoch(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                      ys: jax.Array) -> DeepState:
    def body(st, xy):
        x, y = xy
        return supervised_readout_step(st, spec, x, y), None
    state, _ = jax.lax.scan(body, state, (xs, ys))
    return state


def supervised_epoch(state: DeepState, spec_or_cfg, xs: jax.Array,
                     ys: jax.Array) -> DeepState:
    return _supervised_epoch(state, as_spec(spec_or_cfg), xs, ys)


@functools.partial(jax.jit, static_argnames=("spec",))
def _eval_batches(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                  ys: jax.Array, valid: jax.Array) -> jax.Array:
    """Accuracy over genuine samples only: correct/total are accumulated
    under the validity mask, so a zero-padded tail batch neither skews the
    mean (the old per-batch average weighted short batches equally) nor
    contributes phantom predictions."""
    def body(carry, xyv):
        x, y, v = xyv
        _, pred = infer(state, spec, x, valid=v)
        correct, total = carry
        correct = correct + jnp.sum((pred == y).astype(jnp.float32) * v)
        return (correct, total + jnp.sum(v)), None
    (correct, total), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (xs, ys, valid))
    return correct / jnp.maximum(total, 1.0)


def eval_batches(state: DeepState, spec_or_cfg, xs: jax.Array,
                 ys: jax.Array, valid: Optional[jax.Array] = None) -> jax.Array:
    """Mean accuracy over (nbatch, B, ...) eval data; ``valid`` (optional,
    (nbatch, B) 0/1) masks padded rows out of the mean."""
    if valid is None:
        valid = jnp.ones(ys.shape[:2], jnp.float32)
    return _eval_batches(state, as_spec(spec_or_cfg), xs, ys, valid)


def evaluate_padded(state: DeepState, spec_or_cfg, x: np.ndarray,
                    y: np.ndarray, batch: int = 128) -> float:
    """Accuracy of ``state`` over the FULL unbatched eval set: the tail is
    zero-padded to a whole batch and masked out of the mean, not dropped.
    Shared by ``Trainer.evaluate`` and the serving drivers."""
    if len(x) != len(y):
        raise ValueError(f"x has {len(x)} samples but y has {len(y)} labels")
    xs, valid = _batchify_padded(np.asarray(x), batch)
    ys, _ = _batchify_padded(np.asarray(y, np.int32), batch)
    return float(eval_batches(state, spec_or_cfg, jnp.asarray(xs),
                              jnp.asarray(ys), jnp.asarray(valid)))


class Trainer:
    """End-to-end driver mirroring the paper's experimental protocol.

    Accepts either a legacy ``BCPNNConfig`` (the paper's depth-1 network)
    or a ``NetworkSpec`` of any depth; ``epochs`` in ``fit`` applies per
    stack projection (layerwise greedy schedule).
    """

    def __init__(self, cfg, seed: int = 0):
        self.cfg = cfg
        self.spec = as_spec(cfg)
        self.state = init_deep(self.spec, jax.random.PRNGKey(seed))

    def fit(
        self,
        x_train: np.ndarray,
        y_train: np.ndarray,
        epochs: int,
        batch: int = 128,
        log: bool = False,
    ) -> Dict[str, float]:
        """Layerwise unsupervised epochs + one supervised pass.

        Returns timings (per-image latency covers the whole unsupervised
        phase, i.e. depth * epochs passes over the data).
        """
        xs = jnp.asarray(_batchify(x_train, batch))
        ys = jnp.asarray(_batchify(y_train, batch))
        t0 = time.perf_counter()
        # Greedy phases reuse the frozen representation: ``cur`` holds the
        # dataset's rates at the current layer's input, computed once per
        # phase instead of once per step inside every epoch.
        cur = xs
        for layer in range(self.spec.depth):
            for e in range(epochs):
                self.state = _train_projection_epoch(
                    self.state, self.spec, cur, layer)
                if log:
                    jax.block_until_ready(self.state.projs[layer].w)
                    print(f"  layer {layer + 1}/{self.spec.depth} "
                          f"unsupervised epoch {e + 1}/{epochs} done")
            if layer + 1 < self.spec.depth:
                cur = _propagate_batches(self.state, self.spec, cur, layer)
        jax.block_until_ready(self.state.projs[-1].w)
        t1 = time.perf_counter()
        self.state = supervised_epoch(self.state, self.spec, xs, ys)
        jax.block_until_ready(self.state.readout.w)
        t2 = time.perf_counter()
        n_img = xs.shape[0] * xs.shape[1]
        return {
            "unsup_s": t1 - t0,
            "sup_s": t2 - t1,
            "train_ms_per_img": 1e3 * (t1 - t0)
            / max(1, n_img * epochs * self.spec.depth),
        }

    def evaluate(self, x: np.ndarray, y: np.ndarray, batch: int = 128) -> float:
        """Accuracy over the FULL eval set: the last partial batch is
        zero-padded and masked out of the mean rather than dropped."""
        return evaluate_padded(self.state, self.spec, x, y, batch)

    def predict(self, x: np.ndarray) -> np.ndarray:
        _, pred = infer(self.state, self.spec, jnp.asarray(x))
        return np.asarray(pred)

    # ------------------------------------------------------ checkpoints --
    def save(self, directory: str, step: Optional[int] = None) -> None:
        """Blocking checkpoint of the full DeepState pytree.  The spec is
        stored alongside (manifest ``extra``), so serving can rebuild the
        network from the checkpoint directory alone."""
        mgr = CheckpointManager(directory)
        mgr.save(step if step is not None else int(self.state.step),
                 self.state, blocking=True,
                 extra={"spec": spec_to_dict(self.spec)})

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        """Restore the latest (or a specific) checkpoint into this trainer.
        The target structure comes from the current spec, so depth or
        geometry mismatches fail with a clear error."""
        mgr = CheckpointManager(directory)
        step = step if step is not None else mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        self.state = mgr.restore(step, self.state)
        return step
