"""Streaming trainer for deep BCPNN — the host-side driver of the
accelerator.

The paper's semi-unsupervised protocol (§5), generalized to any depth
(DESIGN.md §1): for each stack projection in turn, N epochs of
unsupervised representation learning (layerwise greedy — lower layers are
frozen feature extractors while a layer trains), then ONE supervised pass
on the readout projection, then inference.  Epochs run as a single jit'd
``lax.scan`` over batch-major data, so a whole epoch is one device
program — the TPU analogue of keeping the FPGA pipeline hot.

Fault-tolerant data-parallel fit (DESIGN.md §12): ``Trainer(cfg,
mesh=...)`` runs each epoch as the shard_map scan-over-batches program
(``distributed.data_parallel``) — bit-for-bit equal to the single-device
epoch — and ``fit(ckpt_dir=..., ckpt_every_batches=k)`` checkpoints
mid-fit with a schedule cursor in the manifest, so a fit interrupted by
worker loss resumes exactly where it stopped on whatever mesh
``elastic_mesh`` can still build.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from .bcpnn_layer import forward
from .network import (
    DeepState,
    NetworkSpec,
    as_spec,
    infer,
    init_deep,
    spec_to_dict,
    supervised_readout_step,
    train_projection_step,
    unsupervised_layer_step,
)


def _batchify_padded(x: np.ndarray, batch: int):
    """Zero-pad to a whole number of batches; also return the (nb, B)
    validity mask marking genuine rows.  Unlike ``_batchify`` this loses
    no tail samples — evaluation masks the pad slots out of the mean."""
    n = x.shape[0]
    nb = max(1, -(-n // batch))
    pad = nb * batch - n
    if pad:
        x = np.concatenate(
            [x, np.zeros((pad, *x.shape[1:]), x.dtype)], axis=0)
    valid = (np.arange(nb * batch) < n).astype(np.float32)
    return (x.reshape(nb, batch, *x.shape[1:]),
            valid.reshape(nb, batch))


@dataclasses.dataclass(frozen=True)
class FitCursor:
    """Where a fit stopped in the layerwise-greedy schedule — stored in
    the checkpoint manifest ``extra`` next to the spec, so a resumed fit
    (possibly on a rebuilt mesh) continues EXACTLY where the interrupted
    one left off.  ``batch`` counts batches of the current epoch already
    consumed; the cursor always names the NEXT work item."""

    phase: str = "unsupervised"   # "unsupervised" | "supervised" | "done"
    layer: int = 0
    epoch: int = 0
    batch: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FitCursor":
        return cls(phase=str(d["phase"]), layer=int(d["layer"]),
                   epoch=int(d["epoch"]), batch=int(d["batch"]))


@functools.partial(jax.jit, static_argnames=("spec", "layer"),
                   donate_argnums=(0,))
def unsupervised_layer_epoch(state: DeepState, spec: NetworkSpec,
                             xs: jax.Array, layer: int) -> DeepState:
    """xs: (nbatch, B, Ni) — one unsupervised epoch on stack projection
    ``layer``, fully on device."""
    def body(st, x):
        return unsupervised_layer_step(st, spec, x, layer), None
    state, _ = jax.lax.scan(body, state, xs)
    return state


def unsupervised_epoch(state: DeepState, spec_or_cfg, xs: jax.Array,
                       layer: int = 0) -> DeepState:
    """Legacy entry point (depth-1 networks train their only projection)."""
    return unsupervised_layer_epoch(state, as_spec(spec_or_cfg), xs, layer)


@functools.partial(jax.jit, static_argnames=("spec", "layer"),
                   donate_argnums=(0,))
def _train_projection_epoch(state: DeepState, spec: NetworkSpec,
                            hs: jax.Array, layer: int) -> DeepState:
    """One epoch over PRECOMPUTED layer-input rates hs: (nbatch, B, N_l)."""
    def body(st, h):
        return train_projection_step(st, spec, h, layer), None
    state, _ = jax.lax.scan(body, state, hs)
    return state


@functools.partial(jax.jit, static_argnames=("spec", "layer"))
def _propagate_batches(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                       layer: int) -> jax.Array:
    """Push batched rates through the (now frozen) projection ``layer``."""
    return jax.lax.map(
        lambda xb: forward(state.projs[layer], spec.projs[layer], xb), xs)


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(0,))
def _supervised_epoch(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                      ys: jax.Array) -> DeepState:
    def body(st, xy):
        x, y = xy
        return supervised_readout_step(st, spec, x, y), None
    state, _ = jax.lax.scan(body, state, (xs, ys))
    return state


def supervised_epoch(state: DeepState, spec_or_cfg, xs: jax.Array,
                     ys: jax.Array) -> DeepState:
    return _supervised_epoch(state, as_spec(spec_or_cfg), xs, ys)


@functools.partial(jax.jit, static_argnames=("spec", "layer"),
                   donate_argnums=(0,))
def _train_projection_epoch_masked(state: DeepState, spec: NetworkSpec,
                                   hs: jax.Array, valid: jax.Array,
                                   layer: int) -> DeepState:
    """The masked twin of ``_train_projection_epoch``: ``valid`` (nb, B)
    marks genuine rows, so the zero-padded tail batch divides its stats
    by the REAL row count instead of diluting the traces (or, before the
    pad existed at all, being silently dropped).  Only fits whose data
    does not divide the batch take this program — whole-batch fits keep
    the unmasked epoch (and its fused-kernel dispatch) bit-for-bit."""
    def body(st, hv):
        h, v = hv
        return train_projection_step(st, spec, h, layer, valid=v), None
    state, _ = jax.lax.scan(body, state, (hs, valid))
    return state


@functools.partial(jax.jit, static_argnames=("spec",), donate_argnums=(0,))
def _supervised_epoch_masked(state: DeepState, spec: NetworkSpec,
                             xs: jax.Array, ys: jax.Array,
                             valid: jax.Array) -> DeepState:
    def body(st, xyv):
        x, y, v = xyv
        return supervised_readout_step(st, spec, x, y, valid=v), None
    state, _ = jax.lax.scan(body, state, (xs, ys, valid))
    return state


@functools.partial(jax.jit, static_argnames=("spec",))
def _eval_batches(state: DeepState, spec: NetworkSpec, xs: jax.Array,
                  ys: jax.Array, valid: jax.Array) -> jax.Array:
    """Accuracy over genuine samples only: correct/total are accumulated
    under the validity mask, so a zero-padded tail batch neither skews the
    mean (the old per-batch average weighted short batches equally) nor
    contributes phantom predictions."""
    def body(carry, xyv):
        x, y, v = xyv
        _, pred = infer(state, spec, x, valid=v)
        correct, total = carry
        correct = correct + jnp.sum((pred == y).astype(jnp.float32) * v)
        return (correct, total + jnp.sum(v)), None
    (correct, total), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (xs, ys, valid))
    return correct / jnp.maximum(total, 1.0)


def eval_batches(state: DeepState, spec_or_cfg, xs: jax.Array,
                 ys: jax.Array, valid: Optional[jax.Array] = None) -> jax.Array:
    """Mean accuracy over (nbatch, B, ...) eval data; ``valid`` (optional,
    (nbatch, B) 0/1) masks padded rows out of the mean."""
    if valid is None:
        valid = jnp.ones(ys.shape[:2], jnp.float32)
    return _eval_batches(state, as_spec(spec_or_cfg), xs, ys, valid)


def evaluate_padded(state: DeepState, spec_or_cfg, x: np.ndarray,
                    y: np.ndarray, batch: int = 128) -> float:
    """Accuracy of ``state`` over the FULL unbatched eval set: the tail is
    zero-padded to a whole batch and masked out of the mean, not dropped.
    Shared by ``Trainer.evaluate`` and the serving drivers."""
    if len(x) != len(y):
        raise ValueError(f"x has {len(x)} samples but y has {len(y)} labels")
    xs, valid = _batchify_padded(np.asarray(x), batch)
    ys, _ = _batchify_padded(np.asarray(y, np.int32), batch)
    return float(eval_batches(state, spec_or_cfg, jnp.asarray(xs),
                              jnp.asarray(ys), jnp.asarray(valid)))


class Trainer:
    """End-to-end driver mirroring the paper's experimental protocol.

    Accepts either a legacy ``BCPNNConfig`` (the paper's depth-1 network)
    or a ``NetworkSpec`` of any depth; ``epochs`` in ``fit`` applies per
    stack projection (layerwise greedy schedule).

    ``mesh`` (optional ``jax.sharding.Mesh`` with a ``data_axis`` axis)
    turns every epoch into the shard_map data-parallel program — batches
    shard over rows, learning all-reduces disjoint-support trace partials
    (distributed/data_parallel.py), and the resulting state is
    bit-for-bit what the single-device fit produces.  Checkpointing and
    cursor resume (``fit``'s ``ckpt_*``/``resume`` arguments) work in
    both modes and across mesh changes, which is what makes worker-loss
    recovery exact: rebuild a smaller mesh with ``elastic_mesh``, resume
    from the cursor, and the final state matches the uninterrupted run.
    """

    def __init__(self, cfg, seed: int = 0, mesh=None,
                 data_axis: str = "data"):
        self.cfg = cfg
        self.spec = as_spec(cfg)
        self.state = init_deep(self.spec, jax.random.PRNGKey(seed))
        self.mesh = mesh
        self.data_axis = data_axis
        self.timer = None  # the last fit's StepTimer
        self._epoch_cache: Dict[tuple, Callable] = {}
        if mesh is not None:
            # Fail at construction, not mid-fit: every projection the DP
            # programs touch needs whole post-HCs per shard.
            from ..distributed.data_parallel import _check_geometry
            _check_geometry(self.spec, self.spec.depth - 1,
                            mesh.shape[data_axis])

    def reset(self, seed: int = 0) -> None:
        """Re-initialize the network state (fresh PRNG chain) while
        keeping the compiled epoch programs — what a warmup-then-measure
        benchmark run wants."""
        self.state = init_deep(self.spec, jax.random.PRNGKey(seed))

    # -------------------------------------------------- epoch programs --
    def _unsup_fn(self, layer: int, masked: bool) -> Callable:
        """Epoch program for one greedy phase — single-device jit or the
        mesh's shard_map scan, cached per (layer, masked)."""
        key = ("unsup", layer, masked)
        if key not in self._epoch_cache:
            if self.mesh is None:
                if masked:
                    fn = lambda st, hs, v: _train_projection_epoch_masked(  # noqa: E731
                        st, self.spec, hs, v, layer)
                else:
                    fn = lambda st, hs: _train_projection_epoch(  # noqa: E731
                        st, self.spec, hs, layer)
            else:
                from ..distributed.data_parallel import (
                    make_data_parallel_projection_epoch)
                fn = make_data_parallel_projection_epoch(
                    self.spec, self.mesh, layer=layer, axis=self.data_axis,
                    masked=masked)
            self._epoch_cache[key] = fn
        return self._epoch_cache[key]

    def _sup_fn(self, masked: bool) -> Callable:
        key = ("sup", masked)
        if key not in self._epoch_cache:
            if self.mesh is None:
                if masked:
                    fn = lambda st, xs, ys, v: _supervised_epoch_masked(  # noqa: E731
                        st, self.spec, xs, ys, v)
                else:
                    fn = lambda st, xs, ys: _supervised_epoch(  # noqa: E731
                        st, self.spec, xs, ys)
            else:
                from ..distributed.data_parallel import (
                    make_data_parallel_supervised_epoch)
                fn = make_data_parallel_supervised_epoch(
                    self.spec, self.mesh, axis=self.data_axis, masked=masked)
            self._epoch_cache[key] = fn
        return self._epoch_cache[key]

    def fit(
        self,
        x_train: np.ndarray,
        y_train: np.ndarray,
        epochs: int,
        batch: int = 128,
        log: bool = False,
        ckpt_dir: Optional[str] = None,
        ckpt_every_batches: int = 0,
        resume: bool = False,
        on_chunk: Optional[Callable[[FitCursor], None]] = None,
    ) -> Dict[str, float]:
        """Layerwise unsupervised epochs + one supervised pass.

        The tail batch is zero-padded and masked, never dropped: a fit on
        n samples trains on all n (stats divide by genuine rows —
        ``learn_masked``), where it used to silently discard up to
        ``batch - 1`` of them.  Whole-batch data takes the exact same
        programs as before.

        Fault tolerance: with ``ckpt_dir`` + ``ckpt_every_batches > 0``
        the fit checkpoints every k batches (state + spec + schedule
        cursor, blocking) and ``resume=True`` continues from the latest
        such checkpoint.  ``on_chunk(cursor)`` fires after every chunk
        (post-checkpoint) — the fault-injection seam: raising
        ``WorkerLost`` from it aborts the fit with the checkpoint
        already on disk.  With ``ckpt_dir`` alone the fit writes one
        final resumable checkpoint.

        Returns timings (per-image latency covers the whole unsupervised
        phase, i.e. depth * epochs passes over the data).
        """
        from ..distributed.fault import StepTimer

        xs_np, valid_np = _batchify_padded(np.asarray(x_train), batch)
        ys_np, _ = _batchify_padded(np.asarray(y_train, np.int32), batch)
        masked = bool(float(valid_np.min()) < 1.0)
        xs = jnp.asarray(xs_np)
        ys = jnp.asarray(ys_np)
        valid = jnp.asarray(valid_np)
        nb = int(xs.shape[0])
        if self.mesh is not None:
            n_shards = int(self.mesh.shape[self.data_axis])
            if batch % n_shards:
                raise ValueError(
                    f"batch={batch} rows cannot shard over the "
                    f"{n_shards}-way '{self.data_axis}' mesh axis")
        mgr = CheckpointManager(ckpt_dir) if ckpt_dir is not None else None
        if resume and mgr is None:
            raise ValueError("fit(resume=True) requires ckpt_dir")
        cursor = FitCursor()
        if resume and mgr.latest_step() is not None:
            step = mgr.latest_step()
            extra = mgr.read_extra(step) or {}
            if "cursor" not in extra:
                raise ValueError(
                    f"checkpoint step_{step} under {ckpt_dir} carries no "
                    f"fit cursor — it is a final artifact, not a mid-fit "
                    f"checkpoint (restore it with Trainer.restore)")
            self.state = mgr.restore(step, self.state)
            cursor = FitCursor.from_dict(extra["cursor"])
            if log:
                print(f"  resumed step_{step} at {cursor}")
        timer = StepTimer()
        self.timer = timer

        def save(cur: FitCursor) -> None:
            if mgr is not None and ckpt_every_batches > 0:
                mgr.save(int(self.state.step), self.state, blocking=True,
                         extra={"spec": spec_to_dict(self.spec),
                                "cursor": cur.to_dict()})

        def run_epoch(fn: Callable, operands: tuple, start_b: int,
                      tag: str, cursor_at: Callable[[int], FitCursor]):
            """One epoch from batch ``start_b``, in checkpoint-delimited
            chunks (the whole epoch at once when not checkpointing).
            Chunking cannot change the result: the scan carries the state
            through bit-unchanged, and each step's arithmetic is pinned
            by its optimization barriers."""
            b0 = start_b
            while b0 < nb:
                n = (nb - b0 if ckpt_every_batches <= 0
                     else min(ckpt_every_batches, nb - b0))
                sl = tuple(op[b0:b0 + n] for op in operands)
                timer.start()
                self.state = fn(self.state, *sl)
                jax.block_until_ready(self.state)
                timer.stop(int(self.state.step), tag=tag)
                b0 += n
                cur = cursor_at(b0)
                save(cur)
                if on_chunk is not None:
                    on_chunk(cur)

        t0 = time.perf_counter()
        if cursor.phase == "unsupervised":
            # Greedy phases reuse the frozen representation: ``cur`` holds
            # the dataset's rates at the current layer's input, computed
            # once per phase instead of once per step inside every epoch —
            # and recomputed (deterministic) up to the cursor on resume.
            cur = xs
            for l in range(cursor.layer):
                cur = _propagate_batches(self.state, self.spec, cur, l)
            for layer in range(cursor.layer, self.spec.depth):
                first = layer == cursor.layer
                fn = self._unsup_fn(layer, masked)
                operands = (cur, valid) if masked else (cur,)
                for e in range(cursor.epoch if first else 0, epochs):
                    start_b = cursor.batch if first and e == cursor.epoch \
                        else 0

                    def cursor_at(b, layer=layer, e=e):
                        if b < nb:
                            return FitCursor("unsupervised", layer, e, b)
                        if e + 1 < epochs:
                            return FitCursor("unsupervised", layer, e + 1, 0)
                        if layer + 1 < self.spec.depth:
                            return FitCursor("unsupervised", layer + 1, 0, 0)
                        return FitCursor("supervised", self.spec.depth, 0, 0)

                    run_epoch(fn, operands, start_b,
                              f"unsup/L{layer}/e{e}", cursor_at)
                    if log:
                        print(f"  layer {layer + 1}/{self.spec.depth} "
                              f"unsupervised epoch {e + 1}/{epochs} done")
                if layer + 1 < self.spec.depth:
                    cur = _propagate_batches(self.state, self.spec, cur,
                                             layer)
            cursor = FitCursor("supervised", self.spec.depth, 0, 0)
        jax.block_until_ready(self.state.projs[-1].w)
        t1 = time.perf_counter()
        if cursor.phase == "supervised":
            fn = self._sup_fn(masked)
            operands = (xs, ys, valid) if masked else (xs, ys)

            def sup_cursor_at(b):
                if b < nb:
                    return FitCursor("supervised", self.spec.depth, 0, b)
                return FitCursor("done", self.spec.depth, 0, 0)

            run_epoch(fn, operands, cursor.batch, "sup/readout",
                      sup_cursor_at)
            cursor = FitCursor("done", self.spec.depth, 0, 0)
        jax.block_until_ready(self.state.readout.w)
        t2 = time.perf_counter()
        if mgr is not None:
            mgr.save(int(self.state.step), self.state, blocking=True,
                     extra={"spec": spec_to_dict(self.spec),
                            "cursor": cursor.to_dict()})
        n_img = int(valid_np.sum())
        return {
            "unsup_s": t1 - t0,
            "sup_s": t2 - t1,
            "train_ms_per_img": 1e3 * (t1 - t0)
            / max(1, n_img * epochs * self.spec.depth),
            "straggler_events": float(len(timer.events)),
        }

    def evaluate(self, x: np.ndarray, y: np.ndarray, batch: int = 128) -> float:
        """Accuracy over the FULL eval set: the last partial batch is
        zero-padded and masked out of the mean rather than dropped."""
        return evaluate_padded(self.state, self.spec, x, y, batch)

    def predict(self, x: np.ndarray) -> np.ndarray:
        _, pred = infer(self.state, self.spec, jnp.asarray(x))
        return np.asarray(pred)

    # ------------------------------------------------------ checkpoints --
    def save(self, directory: str, step: Optional[int] = None) -> None:
        """Blocking checkpoint of the full DeepState pytree.  The spec is
        stored alongside (manifest ``extra``), so serving can rebuild the
        network from the checkpoint directory alone."""
        mgr = CheckpointManager(directory)
        mgr.save(step if step is not None else int(self.state.step),
                 self.state, blocking=True,
                 extra={"spec": spec_to_dict(self.spec)})

    def restore(self, directory: str, step: Optional[int] = None) -> int:
        """Restore the latest (or a specific) checkpoint into this trainer.
        The target structure comes from the current spec, so depth or
        geometry mismatches fail with a clear error."""
        mgr = CheckpointManager(directory)
        step = step if step is not None else mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        self.state = mgr.restore(step, self.state)
        return step
