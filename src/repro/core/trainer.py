"""Streaming trainer for BCPNN — the host-side driver of the accelerator.

The paper's semi-unsupervised protocol (§5): N epochs of unsupervised
representation learning on the input-hidden projection, ONE supervised
pass on the hidden-output projection, then inference.  Epochs run as a
single jit'd ``lax.scan`` over batch-major data, so the whole epoch is one
device program — the TPU analogue of keeping the FPGA pipeline hot.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .network import (
    BCPNNConfig,
    BCPNNState,
    infer,
    init_network,
    supervised_step,
    unsupervised_step,
)


def _batchify(x: np.ndarray, batch: int) -> np.ndarray:
    """Trim to a whole number of batches and reshape batch-major."""
    nb = x.shape[0] // batch
    return x[: nb * batch].reshape(nb, batch, *x.shape[1:])


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def unsupervised_epoch(state: BCPNNState, cfg: BCPNNConfig, xs: jax.Array) -> BCPNNState:
    """xs: (nbatch, B, Ni) — one full unsupervised epoch on device."""
    def body(st, x):
        return unsupervised_step(st, cfg, x), None
    state, _ = jax.lax.scan(body, state, xs)
    return state


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def supervised_epoch(state: BCPNNState, cfg: BCPNNConfig, xs: jax.Array,
                     ys: jax.Array) -> BCPNNState:
    def body(st, xy):
        x, y = xy
        return supervised_step(st, cfg, x, y), None
    state, _ = jax.lax.scan(body, state, (xs, ys))
    return state


@functools.partial(jax.jit, static_argnames=("cfg",))
def eval_batches(state: BCPNNState, cfg: BCPNNConfig, xs: jax.Array,
                 ys: jax.Array) -> jax.Array:
    """Mean accuracy over (nbatch, B, ...) eval data."""
    def body(_, xy):
        x, y = xy
        _, pred = infer(state, cfg, x)
        return None, jnp.mean((pred == y).astype(jnp.float32))
    _, accs = jax.lax.scan(body, None, (xs, ys))
    return jnp.mean(accs)


class Trainer:
    """End-to-end driver mirroring the paper's experimental protocol."""

    def __init__(self, cfg: BCPNNConfig, seed: int = 0):
        self.cfg = cfg
        self.state = init_network(cfg, jax.random.PRNGKey(seed))

    def fit(
        self,
        x_train: np.ndarray,
        y_train: np.ndarray,
        epochs: int,
        batch: int = 128,
        log: bool = False,
    ) -> Dict[str, float]:
        """Unsupervised epochs + one supervised pass.  Returns timings."""
        xs = jnp.asarray(_batchify(x_train, batch))
        ys = jnp.asarray(_batchify(y_train, batch))
        t0 = time.perf_counter()
        for e in range(epochs):
            self.state = unsupervised_epoch(self.state, self.cfg, xs)
            if log:
                jax.block_until_ready(self.state.ih.w)
                print(f"  unsupervised epoch {e + 1}/{epochs} done")
        jax.block_until_ready(self.state.ih.w)
        t1 = time.perf_counter()
        self.state = supervised_epoch(self.state, self.cfg, xs, ys)
        jax.block_until_ready(self.state.ho.w)
        t2 = time.perf_counter()
        n_img = xs.shape[0] * xs.shape[1]
        return {
            "unsup_s": t1 - t0,
            "sup_s": t2 - t1,
            "train_ms_per_img": 1e3 * (t1 - t0) / max(1, n_img * epochs),
        }

    def evaluate(self, x: np.ndarray, y: np.ndarray, batch: int = 128) -> float:
        xs = jnp.asarray(_batchify(x, batch))
        ys = jnp.asarray(_batchify(y, batch))
        return float(eval_batches(self.state, self.cfg, xs, ys))

    def predict(self, x: np.ndarray) -> np.ndarray:
        _, pred = infer(self.state, self.cfg, jnp.asarray(x))
        return np.asarray(pred)
