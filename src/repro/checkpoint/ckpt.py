"""Sharded checkpointing with async writes and elastic restore.

Layout: <dir>/step_<N>/
    manifest.json           tree structure + leaf shapes/dtypes + step
    arrays.npz              host-gathered leaves (addressable shards only)

Design points for the 1000+ node story:
  * per-host writes — each process saves only its addressable shards (in
    this single-process environment that is the whole array, but the code
    paths go through `jax.device_get` per shard and are process-safe);
  * async — the serialize+write happens on a worker thread off the train
    loop's critical path; `wait()` joins before the next save or exit;
  * elastic restore — leaves are restored by name onto WHATEVER sharding
    the current mesh prescribes (device_put with the target sharding), so
    a checkpoint from a 16x16 run restores onto 2x16x16 or a single CPU;
  * retention — keep_last N checkpoints, atomic rename on completion.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np


def _key_str(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (registered
    # dataclasses like DeepState/Projection/Traces) -> .name
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(_key_str(k) for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------ save --
    def save(self, step: int, tree: Any, blocking: bool = False,
             extra: Optional[dict] = None) -> None:
        """``extra`` (optional, JSON-serializable) is stored in the
        manifest — e.g. the serialized NetworkSpec
        (``core.network.spec_to_dict``), so a server can rebuild the
        network from the checkpoint directory alone (``read_extra``)."""
        self.wait()
        names, leaves, _ = _flatten_with_names(tree)
        host_leaves = []
        for x in leaves:
            a = np.asarray(jax.device_get(x))
            if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
                a = a.astype(np.float32)  # npz has no bf16; manifest keeps dtype
            host_leaves.append(a)

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{n: a for n, a in zip(names, host_leaves)})
            manifest = {
                "step": step,
                "leaves": {n: {"shape": list(a.shape), "dtype": str(a.dtype)}
                           for n, a in zip(names, host_leaves)},
            }
            if extra is not None:
                manifest["extra"] = extra
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------- restore --
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_extra(self, step: int) -> Optional[dict]:
        """The ``extra`` metadata stored with ``save`` (None if absent)."""
        self.wait()
        with open(os.path.join(self.dir, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f).get("extra")

    def resume_point(self) -> Optional[Tuple[int, dict]]:
        """``(step, extra)`` of the latest checkpoint, or None when the
        directory holds none — the one-call lookup the elastic-restart
        ladder uses before rebuilding a mesh (DESIGN.md §12): the
        ``extra`` carries the spec and, for mid-fit checkpoints, the
        ``FitCursor`` naming the next work item."""
        step = self.latest_step()
        if step is None:
            return None
        return step, self.read_extra(step) or {}

    def restore(self, step: int, target: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `target`, resharding elastically.

        `shardings` (optional pytree of NamedSharding matching target)
        places each leaf directly onto the current mesh — this is what
        makes restarting on a different mesh size work.
        """
        self.wait()
        path = os.path.join(self.dir, f"step_{step}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        names, leaves, treedef = _flatten_with_names(target)
        if shardings is not None:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: x is None)
            if len(shard_leaves) != len(leaves):
                raise ValueError(
                    f"shardings tree has {len(shard_leaves)} leaves for "
                    f"{len(leaves)} target leaves")
        else:
            shard_leaves = [None] * len(leaves)
        missing = sorted(set(names) - set(arrays.files))
        extra = sorted(set(arrays.files) - set(names))
        if missing or extra:
            def _fmt(kind, items):
                return (f"{kind} leaves {items[:5]}"
                        + (f" ... +{len(items) - 5} more"
                           if len(items) > 5 else ""))
            detail = "; ".join(_fmt(k, v) for k, v in
                               (("missing", missing), ("extra", extra)) if v)
            hint = ""
            # A table leaf present on one side only is the signature of a
            # dense<->compact patchy layout mismatch, not a different
            # network: name the one-shot fix instead of a generic error.
            if any(n.endswith("table") for n in missing + extra):
                hint = (" — this looks like a dense vs compact-resident "
                        "patchy layout mismatch (ProjSpec.compact): migrate "
                        "the checkpoint with scripts/migrate_ckpt.py, or "
                        "restore with the spec the checkpoint was saved "
                        "under (manifest extra['spec'])")
            raise ValueError(
                f"checkpoint step_{step} does not match the target "
                f"structure (e.g. a different network depth/geometry): "
                f"{detail}{hint}")
        out = []
        for name, ref, shd in zip(names, leaves, shard_leaves):
            a = arrays[name]
            if tuple(a.shape) != tuple(ref.shape):
                hint = ""
                if a.ndim != ref.ndim and {a.ndim, ref.ndim} == {2, 3}:
                    hint = (" — a 2-D vs 3-D trace/weight leaf means the "
                            "checkpoint and target disagree on the patchy "
                            "state layout (dense (Ni, Nj) vs "
                            "compact-resident (Hj, K, Mj)); migrate with "
                            "scripts/migrate_ckpt.py")
                raise ValueError(
                    f"checkpoint leaf {name!r} has shape {tuple(a.shape)}, "
                    f"target expects {tuple(ref.shape)}{hint}")
            a = jax.numpy.asarray(a).astype(ref.dtype)
            out.append(jax.device_put(a, shd) if shd is not None else a)
        return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------- serving-model loading --

def load_model(directory: str, step: Optional[int] = None,
               seed: int = 0) -> Tuple[Any, Any, int]:
    """(state, spec, step) from a checkpoint directory ALONE — the serving
    deployment loader: the NetworkSpec rides in the manifest ``extra``
    (written by ``Trainer.save``), so no out-of-band config is needed to
    rebuild and restore the network.  Raises FileNotFoundError when the
    directory holds no checkpoint and ValueError when the manifest lacks
    the spec (pre-serving checkpoints: re-save with ``Trainer.save``)."""
    # Lazy: core.trainer imports this package, so the dependency must
    # point one way at import time.
    from ..core.network import init_deep, spec_from_dict

    mgr = CheckpointManager(directory)
    step = step if step is not None else mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    extra = mgr.read_extra(step)
    if not extra or "spec" not in extra:
        raise ValueError(
            f"checkpoint step_{step} under {directory} has no spec "
            f"metadata; re-save it with Trainer.save")
    spec = spec_from_dict(extra["spec"])
    state = mgr.restore(step, init_deep(spec, jax.random.PRNGKey(seed)))
    return state, spec, step


def load_models(directories: Sequence[str],
                seed: int = 0) -> Dict[str, Tuple[Any, Any]]:
    """Multi-model manifest load: ``{name: (state, spec)}`` for one
    serving engine from several checkpoint directories.  Names derive
    from each directory's basename (deduplicated with ``#i`` suffixes so
    two deployments of the same artifact can be hosted side by side)."""
    out: Dict[str, Tuple[Any, Any]] = {}
    for d in directories:
        base = os.path.basename(os.path.normpath(d)) or "model"
        name, i = base, 1
        while name in out:
            i += 1
            name = f"{base}#{i}"
        state, spec, _ = load_model(d, seed=seed)
        out[name] = (state, spec)
    return out
