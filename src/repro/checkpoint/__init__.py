from .ckpt import CheckpointManager, load_model, load_models

__all__ = ["CheckpointManager", "load_model", "load_models"]
