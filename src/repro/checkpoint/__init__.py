from .ckpt import CheckpointManager
