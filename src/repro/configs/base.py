"""Model configuration schema shared by every architecture in the zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # --- attention variants -------------------------------------------
    qk_norm: bool = False          # qwen3: RMSNorm on q/k heads
    qkv_bias: bool = False         # qwen1.5
    attn_softcap: float = 0.0      # gemma2: tanh logit soft-capping
    final_softcap: float = 0.0     # gemma2: final-logit soft-capping
    window: int = 0                # sliding window for local layers
    layer_pattern: str = "g"       # repeating unit: g=global l=local r=RG-LRU m=mamba
    post_norms: bool = False       # gemma2: post-attn/post-ffn RMSNorm
    embed_scale: bool = False      # gemma2: scale embeddings by sqrt(d)
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE -----------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 0            # dispatch groups (0 -> data shards at runtime)
    # --- SSM / recurrent -------------------------------------------------
    ssm_state: int = 16
    ssm_chunk: int = 0             # >1: chunked scan w/ remat (see §Perf)
    d_conv: int = 4
    expand: int = 2                # mamba d_inner = expand * d_model
    dt_rank: int = 0               # 0 -> ceil(d_model / 16)
    lru_width: int = 0             # 0 -> d_model
    # --- encoder-decoder (whisper) / vlm --------------------------------
    enc_layers: int = 0
    enc_seq: int = 1504            # stub conv-frontend output frames (pre-padded)
    vision_patches: int = 0        # vlm: patch embeddings prepended to sequence
    # --- execution -------------------------------------------------------
    subquadratic: bool = False     # eligible for long_500k decode
    remat: bool = True
    scan_layers: bool = True
    dtype: str = "bfloat16"
    lmhead_chunk: int = 512        # seq chunking of the softmax-xent loss

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def lru_width_eff(self) -> int:
        return self.lru_width or self.d_model

    @property
    def pattern_blocks(self) -> Tuple[int, int]:
        """(#scanned pattern repeats, #tail layers)."""
        p = len(self.layer_pattern)
        return self.n_layers // p, self.n_layers % p

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
