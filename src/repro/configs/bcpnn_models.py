"""The paper's three BCPNN model configurations (Table 1)."""
from __future__ import annotations

from ..core.network import BCPNNConfig

# nactHi = 128 (Table 1) prescribes the receptive-field sparsity; the
# fields are FOUND by structural plasticity (Fig. 5).  Without structural
# plasticity a random fixed 128-HC patch is uninformative (verified in
# tests), so the non-struct variants run densely connected and the struct
# variants carry the nactHi sparsity + periodic rewiring.

# Model 1: MNIST — 28x28 input, hidden 32x128, 10 classes, 5 epochs
MODEL1_MNIST = BCPNNConfig(
    input_hc=28 * 28, input_mc=2, hidden_hc=32, hidden_mc=128,
    n_classes=10, nact_hi=28 * 28, alpha=2e-3, support_noise=3.0,
    noise_steps=1500, struct_every=0,
)

# Model 2: Pneumonia — 28x28 input, hidden 32x256, 2 classes, 20 epochs
MODEL2_PNEUMONIA = BCPNNConfig(
    input_hc=28 * 28, input_mc=2, hidden_hc=32, hidden_mc=256,
    n_classes=2, nact_hi=28 * 28, alpha=2e-3, support_noise=3.0,
    noise_steps=500, struct_every=0,
)

# Model 3: Breast — 64x64 input, hidden 32x128, 2 classes, 100 epochs
MODEL3_BREAST = BCPNNConfig(
    input_hc=64 * 64, input_mc=2, hidden_hc=32, hidden_mc=128,
    n_classes=2, nact_hi=64 * 64, alpha=2e-3, support_noise=3.0,
    noise_steps=300, struct_every=0,
)

# Structural-plasticity variants (paper's "struct" rows): nactHi=128
MODEL1_MNIST_STRUCT = MODEL1_MNIST.__class__(
    **{**MODEL1_MNIST.__dict__, "struct_every": 64, "nact_hi": 128})
MODEL2_PNEUMONIA_STRUCT = MODEL2_PNEUMONIA.__class__(
    **{**MODEL2_PNEUMONIA.__dict__, "struct_every": 16, "nact_hi": 128})
MODEL3_BREAST_STRUCT = MODEL3_BREAST.__class__(
    **{**MODEL3_BREAST.__dict__, "struct_every": 8, "nact_hi": 128})

BCPNN_MODELS = {
    "model1-mnist": (MODEL1_MNIST, "mnist", 5),
    "model2-pneumonia": (MODEL2_PNEUMONIA, "pneumonia", 20),
    "model3-breast": (MODEL3_BREAST, "breast", 100),
    "model1-mnist-struct": (MODEL1_MNIST_STRUCT, "mnist", 5),
    "model2-pneumonia-struct": (MODEL2_PNEUMONIA_STRUCT, "pneumonia", 20),
    "model3-breast-struct": (MODEL3_BREAST_STRUCT, "breast", 100),
}
