"""BCPNN model zoo: the paper's three Table-1 configurations (thin
depth-1 presets) plus deep multi-layer presets for the stacked protocol
(DESIGN.md §1) with per-network backend variants."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..core.hypercolumns import LayerGeom
from ..core.network import BCPNNConfig, NetworkSpec, make_network_spec

# nactHi = 128 (Table 1) prescribes the receptive-field sparsity; the
# fields are FOUND by structural plasticity (Fig. 5).  Without structural
# plasticity a random fixed 128-HC patch is uninformative (verified in
# tests), so the non-struct variants run densely connected and the struct
# variants carry the nactHi sparsity + periodic rewiring.

# Model 1: MNIST — 28x28 input, hidden 32x128, 10 classes, 5 epochs
MODEL1_MNIST = BCPNNConfig(
    input_hc=28 * 28, input_mc=2, hidden_hc=32, hidden_mc=128,
    n_classes=10, nact_hi=28 * 28, alpha=2e-3, support_noise=3.0,
    noise_steps=1500, struct_every=0,
)

# Model 2: Pneumonia — 28x28 input, hidden 32x256, 2 classes, 20 epochs
MODEL2_PNEUMONIA = BCPNNConfig(
    input_hc=28 * 28, input_mc=2, hidden_hc=32, hidden_mc=256,
    n_classes=2, nact_hi=28 * 28, alpha=2e-3, support_noise=3.0,
    noise_steps=500, struct_every=0,
)

# Model 3: Breast — 64x64 input, hidden 32x128, 2 classes, 100 epochs
MODEL3_BREAST = BCPNNConfig(
    input_hc=64 * 64, input_mc=2, hidden_hc=32, hidden_mc=128,
    n_classes=2, nact_hi=64 * 64, alpha=2e-3, support_noise=3.0,
    noise_steps=300, struct_every=0,
)

# Structural-plasticity variants (paper's "struct" rows): nactHi=128
MODEL1_MNIST_STRUCT = dataclasses.replace(
    MODEL1_MNIST, struct_every=64, nact_hi=128)
MODEL2_PNEUMONIA_STRUCT = dataclasses.replace(
    MODEL2_PNEUMONIA, struct_every=16, nact_hi=128)
MODEL3_BREAST_STRUCT = dataclasses.replace(
    MODEL3_BREAST, struct_every=8, nact_hi=128)

BCPNN_MODELS = {
    "model1-mnist": (MODEL1_MNIST, "mnist", 5),
    "model2-pneumonia": (MODEL2_PNEUMONIA, "pneumonia", 20),
    "model3-breast": (MODEL3_BREAST, "breast", 100),
    "model1-mnist-struct": (MODEL1_MNIST_STRUCT, "mnist", 5),
    "model2-pneumonia-struct": (MODEL2_PNEUMONIA_STRUCT, "pneumonia", 20),
    "model3-breast-struct": (MODEL3_BREAST_STRUCT, "breast", 100),
}


# ----------------------------------------------------------- deep presets --

def deep_mnist_spec(depth: int = 2, backend: str = "jnp",
                    hidden_hc: int = 32, hidden_mc: int = 64) -> NetworkSpec:
    """MNIST-shaped deep stack: 784x2 input, ``depth`` hidden layers of
    hidden_hc x hidden_mc, 10-way readout.  Upper layers get a shorter
    noise anneal: they see already-structured rates and need less
    symmetry breaking."""
    hidden = [LayerGeom(hidden_hc, hidden_mc)] * depth
    spec = make_network_spec(
        LayerGeom(28 * 28, 2), hidden, n_classes=10, alpha=2e-3,
        backend=backend, support_noise=3.0, noise_steps=1500,
    )
    projs = tuple(
        p if l == 0 else dataclasses.replace(p, noise_steps=500)
        for l, p in enumerate(spec.projs)
    )
    return NetworkSpec(projs=projs, readout=spec.readout)


def deep_synth_spec(side: int = 12, depth: int = 2, n_classes: int = 5,
                    backend: str = "jnp", hidden_hc: int = 16,
                    hidden_mc: int = 32,
                    nact: Optional[Sequence[Optional[int]]] = None,
                    alpha: float = 1e-2, patchy_traces: bool = False,
                    compact: bool = False,
                    struct_every: int = 0) -> NetworkSpec:
    """Deep stack sized for the synthetic surrogate datasets (tests, CI,
    benchmarks): side*side*2 input, ``depth`` hidden layers.
    ``patchy_traces``/``compact`` opt nact-budgeted projections into
    patchy plasticity and the compact-resident state layout;
    ``struct_every`` enables structural plasticity (without it a patchy
    mask stays at its random init, which caps what the stack can learn)."""
    hidden = [LayerGeom(hidden_hc, hidden_mc)] * depth
    return make_network_spec(
        LayerGeom(side * side, 2), hidden, n_classes=n_classes, alpha=alpha,
        nact=nact, backend=backend, support_noise=3.0, noise_steps=200,
        patchy_traces=patchy_traces, compact=compact,
        struct_every=struct_every,
    )
