from .base import SHAPES, ModelConfig, ShapeConfig
from .archs import ARCHS, get_config, smoke

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "ARCHS", "get_config", "smoke"]
