"""The ten assigned architectures, exact dims from the assignment brief.

Each is selectable via --arch <id> in the launchers.  smoke() returns the
reduced same-family config used by per-arch CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ModelConfig

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- InternVL2-26B: InternViT stub frontend + InternLM2-20B backbone ----
internvl2_26b = _register(ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, head_dim=128, rope_theta=1e6,
    vision_patches=256,
))

# --- Gemma2-2B: local/global alternating, softcaps, post-norms ----------
gemma2_2b = _register(ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256, layer_pattern="lg", window=4096,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    embed_scale=True, mlp="geglu", tie_embeddings=True,
))

# --- Mistral-Nemo-12B: 128k ctx ------------------------------------------
mistral_nemo_12b = _register(ModelConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=131072, head_dim=128, rope_theta=1e6,
))

# --- Qwen3-32B: qk-norm, GQA ---------------------------------------------
qwen3_32b = _register(ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
))

# --- Qwen1.5-0.5B: QKV bias ----------------------------------------------
qwen15_05b = _register(ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, head_dim=64, qkv_bias=True, tie_embeddings=True,
))

# --- Moonlight-16B-A3B: 64 experts top-6 ----------------------------------
moonshot_v1_16b_a3b = _register(ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, head_dim=128,
    n_experts=64, n_experts_active=6,
))

# --- Qwen3-MoE-30B-A3B: 128 experts top-8 ---------------------------------
qwen3_moe_30b_a3b = _register(ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    n_experts=128, n_experts_active=8,
))

# --- Falcon-Mamba-7B: pure mamba1 ------------------------------------------
falcon_mamba_7b = _register(ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, layer_pattern="m", ssm_state=16, d_conv=4, expand=2,
    subquadratic=True,
))

# --- RecurrentGemma-2B: RG-LRU + local attention, 1:2 ----------------------
recurrentgemma_2b = _register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, layer_pattern="rrl", window=2048,
    lru_width=2560, embed_scale=True, mlp="geglu", tie_embeddings=True,
    subquadratic=True,
))

# --- Whisper-tiny: enc-dec, conv frontend stub ------------------------------
whisper_tiny = _register(ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab=51865, head_dim=64, rope_theta=0.0, mlp="gelu",
    enc_layers=4, enc_seq=1500, tie_embeddings=True,
))


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.n_layers % 2 == 0 else 3),
        d_model=128, d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab=512, head_dim=32,
        n_heads=min(cfg.n_heads, 4) if cfg.n_heads else 0,
        lmhead_chunk=64, dtype="float32", remat=False,
    )
    if cfg.n_kv_heads:
        kw["n_kv_heads"] = min(cfg.n_kv_heads, kw["n_heads"])
    if cfg.n_experts:
        kw["n_experts"] = 8
        kw["n_experts_active"] = min(cfg.n_experts_active, 2)
        kw["capacity_factor"] = 8.0
    if cfg.window:
        kw["window"] = 8
    if cfg.lru_width:
        kw["lru_width"] = 128
    if cfg.enc_layers:
        kw["enc_layers"] = 2
        kw["enc_seq"] = 16
    if cfg.vision_patches:
        kw["vision_patches"] = 8
    if cfg.layer_pattern == "rrl":
        kw["n_layers"] = 5  # 1 full pattern + 2 tail -> exercises both paths
    if cfg.layer_pattern == "lg":
        kw["n_layers"] = 4
    return cfg.with_(**kw)
