"""Paper §4.1 analogue: sequential (unfused, every intermediate in HBM)
vs stream-dataflow (fused Pallas kernels) BCPNN step.

On CPU, the Pallas interpreter adds Python overhead per tile, so the
honest CPU-side comparison is between the unfused jnp stages and the
FUSION-EQUIVALENT jnp composition (XLA fuses within one jit, mirroring
what the Pallas kernel does structurally on TPU).  We also report the
Pallas-interpret timing for completeness, and — the number that matters
for the TPU target — the HBM-traffic model for both schedules
(the paper's Opt#1+#2 ~70% claim is a traffic claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.bcpnn_layer import ProjSpec, forward, init_projection, learn
from repro.core.hypercolumns import LayerGeom
from repro.kernels import fused_forward, fused_learn


def _time(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def hbm_traffic_model(b, ni, nj):
    """Bytes moved per combined step (f32)."""
    seq = {
        # sequential: support, softmax out, co, pij rw, w write, reads
        "support_write": b * nj, "support_read": b * nj,
        "h_write": b * nj, "co_write": ni * nj, "co_read": ni * nj,
        "pij_read": ni * nj, "pij_write": ni * nj,
        "pij_read2": ni * nj, "w_write": ni * nj, "mask_read": ni * nj,
        "x_read": 2 * b * ni, "w_read": ni * nj, "h_read": b * nj,
    }
    fused = {
        # stream: x,w in once; h out once; pij in/out once; w out once
        "x_read": 2 * b * ni, "w_read": ni * nj, "h_write": b * nj,
        "h_read": b * nj, "pij_read": ni * nj, "pij_write": ni * nj,
        "w_write": ni * nj, "mask_read": ni * nj,
    }
    return 4 * sum(seq.values()), 4 * sum(fused.values())


def run(csv=True):
    b, hi, mi, hj, mj = 256, 512, 2, 16, 128
    spec = ProjSpec(LayerGeom(hi, mi), LayerGeom(hj, mj), alpha=1e-2)
    proj = init_projection(spec, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (b, spec.pre.N))

    # sequential: two separate jits, intermediates cross HBM
    fwd_seq = jax.jit(lambda p, xb: forward(p, spec, xb))
    lrn_seq = jax.jit(lambda p, xb, yb: learn(p, spec, xb, yb))

    def seq_step(p, xb):
        h = fwd_seq(p, xb)
        return lrn_seq(p, xb, h)

    # stream: single fused jit (XLA fusion ~ Pallas dataflow on TPU)
    @jax.jit
    def stream_step(p, xb):
        h = forward(p, spec, xb)
        return learn(p, spec, xb, h)

    t_seq = _time(seq_step, proj, x)
    t_stream = _time(stream_step, proj, x)
    seq_bytes, fused_bytes = hbm_traffic_model(b, spec.pre.N, spec.post.N)
    if csv:
        print(f"stream_vs_seq,{t_seq*1e6:.0f},sequential_us")
        print(f"stream_vs_seq,{t_stream*1e6:.0f},stream_fused_us")
        print(f"stream_vs_seq,{(t_seq/t_stream-1)*100:.0f},speedup_pct")
        print(f"stream_vs_seq,{seq_bytes/1e6:.1f},seq_traffic_MB")
        print(f"stream_vs_seq,{fused_bytes/1e6:.1f},fused_traffic_MB")
        print(f"stream_vs_seq,{(seq_bytes/fused_bytes-1)*100:.0f},traffic_reduction_pct")
    return {"t_seq": t_seq, "t_stream": t_stream,
            "seq_bytes": seq_bytes, "fused_bytes": fused_bytes}


if __name__ == "__main__":
    run()
