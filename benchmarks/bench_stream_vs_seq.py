"""Paper §4.1 analogue: sequential (unfused, every intermediate in HBM)
vs stream-dataflow (fused) BCPNN step — now swept over network depth
(1-3 hidden layers) x execution backend (jnp reference vs fused Pallas).

On CPU, the Pallas interpreter adds Python overhead per tile, so the
honest CPU-side comparison is between the unfused jnp stages and the
FUSION-EQUIVALENT jnp composition (XLA fuses within one jit, mirroring
what the Pallas kernel does structurally on TPU).  We still run the
Pallas-dispatch path for completeness — on TPU the same calls compile to
Mosaic and it becomes the production number — and report the HBM-traffic
model for both schedules (the paper's Opt#1+#2 ~70% claim is a traffic
claim).

Output: ``name,value,unit`` CSV rows for the table harness, plus one
machine-readable JSON summary line (``stream_vs_seq_json={...}``) and an
optional ``--json PATH`` dump for the bench trajectory.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import (
    LayerGeom, infer, init_deep, make_network_spec, unsupervised_layer_step,
)
from repro.core.bcpnn_layer import ProjSpec, forward, init_projection, learn


def _time(fn, *args, iters=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def hbm_traffic_model(b, ni, nj):
    """Bytes moved per combined step (f32)."""
    seq = {
        # sequential: support, softmax out, co, pij rw, w write, reads
        "support_write": b * nj, "support_read": b * nj,
        "h_write": b * nj, "co_write": ni * nj, "co_read": ni * nj,
        "pij_read": ni * nj, "pij_write": ni * nj,
        "pij_read2": ni * nj, "w_write": ni * nj, "mask_read": ni * nj,
        "x_read": 2 * b * ni, "w_read": ni * nj, "h_read": b * nj,
    }
    fused = {
        # stream: x,w in once; h out once; pij in/out once; w out once
        "x_read": 2 * b * ni, "w_read": ni * nj, "h_write": b * nj,
        "h_read": b * nj, "pij_read": ni * nj, "pij_write": ni * nj,
        "w_write": ni * nj, "mask_read": ni * nj,
    }
    return 4 * sum(seq.values()), 4 * sum(fused.values())


def single_projection_comparison(csv=True):
    """The original §4.1 microbenchmark: one projection, three schedules."""
    b, hi, mi, hj, mj = 256, 512, 2, 16, 128
    spec = ProjSpec(LayerGeom(hi, mi), LayerGeom(hj, mj), alpha=1e-2)
    proj = init_projection(spec, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (b, spec.pre.N))

    # sequential: two separate jits, intermediates cross HBM
    fwd_seq = jax.jit(lambda p, xb: forward(p, spec, xb))
    lrn_seq = jax.jit(lambda p, xb, yb: learn(p, spec, xb, yb))

    def seq_step(p, xb):
        h = fwd_seq(p, xb)
        return lrn_seq(p, xb, h)

    # stream: single fused jit (XLA fusion ~ Pallas dataflow on TPU)
    @jax.jit
    def stream_step(p, xb):
        h = forward(p, spec, xb)
        return learn(p, spec, xb, h)

    # pallas dispatch: the production path (Mosaic on TPU; interpret here)
    pspec = spec.with_backend("pallas")

    @jax.jit
    def pallas_step(p, xb):
        h = forward(p, pspec, xb)
        return learn(p, pspec, xb, h)

    t_seq = _time(seq_step, proj, x)
    t_stream = _time(stream_step, proj, x)
    t_pallas = _time(pallas_step, proj, x, iters=3)
    seq_bytes, fused_bytes = hbm_traffic_model(b, spec.pre.N, spec.post.N)
    if csv:
        print(f"stream_vs_seq,{t_seq*1e6:.0f},sequential_us")
        print(f"stream_vs_seq,{t_stream*1e6:.0f},stream_fused_us")
        print(f"stream_vs_seq,{t_pallas*1e6:.0f},pallas_dispatch_us")
        print(f"stream_vs_seq,{(t_seq/t_stream-1)*100:.0f},speedup_pct")
        print(f"stream_vs_seq,{seq_bytes/1e6:.1f},seq_traffic_MB")
        print(f"stream_vs_seq,{fused_bytes/1e6:.1f},fused_traffic_MB")
        print(f"stream_vs_seq,{(seq_bytes/fused_bytes-1)*100:.0f},traffic_reduction_pct")
    return {"t_seq": t_seq, "t_stream": t_stream, "t_pallas": t_pallas,
            "seq_bytes": seq_bytes, "fused_bytes": fused_bytes}


def depth_backend_sweep(depths=(1, 2, 3), backends=("jnp", "pallas"),
                        csv=True):
    """Train-step + infer-step latency for a deep stack, per backend.

    The timed train step is the protocol's steady-state hot path:
    unsupervised plasticity on the TOP projection, which streams the batch
    through all frozen lower layers first — so cost grows with depth.
    """
    b, side = 128, 12
    results = []
    for depth in depths:
        for backend in backends:
            spec = make_network_spec(
                LayerGeom(side * side, 2), [(16, 32)] * depth, n_classes=5,
                alpha=1e-2, backend=backend, support_noise=2.0,
                noise_steps=100)
            state = init_deep(spec, jax.random.PRNGKey(0))
            x = jax.random.uniform(jax.random.PRNGKey(1),
                                   (b, spec.input_geom.N))
            train = jax.jit(lambda s, xb, _spec=spec: unsupervised_layer_step(
                s, _spec, xb, _spec.depth - 1))
            inf = jax.jit(lambda s, xb, _spec=spec: infer(s, _spec, xb)[1])
            iters = 10 if backend == "jnp" else 3
            t_train = _time(train, state, x, iters=iters)
            t_infer = _time(inf, state, x, iters=iters)
            row = {
                "depth": depth,
                "backend": backend,
                "train_us_per_batch": t_train * 1e6,
                "infer_us_per_batch": t_infer * 1e6,
                "train_us_per_img": t_train / b * 1e6,
                "infer_us_per_img": t_infer / b * 1e6,
            }
            results.append(row)
            if csv:
                print(f"stream_vs_seq_d{depth}_{backend},"
                      f"{row['train_us_per_img']:.1f},train_us_per_img")
                print(f"stream_vs_seq_d{depth}_{backend},"
                      f"{row['infer_us_per_img']:.1f},infer_us_per_img")
    return results


def run(csv=True, json_path=None):
    single = single_projection_comparison(csv=csv)
    sweep = depth_backend_sweep(csv=csv)
    summary = {
        "single_projection": single,
        "depth_backend_sweep": sweep,
        "device": jax.default_backend(),
    }
    if csv:
        print("stream_vs_seq_json=" + json.dumps(summary))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the JSON summary to this path")
    args = ap.parse_args()
    run(json_path=args.json)
