"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV rows:
  * bench_bcpnn           — Table 2 latency/accuracy rows (CPU baseline)
  * bench_struct          — Table 2 'struct' rows (on-device rewire cost)
  * bench_stream_vs_seq   — §4.1 sequential vs stream-dataflow
  * bench_kernels         — dense vs padded vs patchy kernel schedules
                            (writes BENCH_kernels.json)
  * bench_roofline_bcpnn  — Fig. 6 roofline placement (TPU target)
  * bench_lm_rooflines    — assigned-arch dry-run roofline table
  * bench_train_dp        — Trainer DP fit images/s at 1/2/4-way CPU
                            meshes + elastic kill-resume overhead
                            (writes BENCH_train_dp.json; subprocesses)

``--assert-patchy-speedup`` is the CI smoke gate for the compact patchy
schedule: it reruns the kernels bench and fails if the measured
patchy-vs-padded step ratio regressed by more than 20% against the
committed ``BENCH_kernels.json``.  The RATIO is compared, not absolute
step_ms — CI hardware differs from whatever produced the committed
snapshot, but both schedules of one run share the machine and geometry,
so their ratio is the transportable signal.  The run must use the same
``--scale`` as the committed snapshot (the ratio is geometry-dependent;
the gate enforces this).

``--assert-quant-accuracy`` is the CI gate for the low-precision serving
path (DESIGN.md §8): it trains a small compact-patchy network on the
synthetic task and fails if bf16 or int8 inference loses more than
0.5pp eval accuracy vs the same state's fp32 path.  Accuracy, unlike
step time, IS machine-transportable, so this gate compares absolutes.
"""
import argparse
import json
import sys

REGRESSION_HEADROOM = 0.8  # fresh ratio must be >= 80% of committed
MAX_QUANT_ACC_DELTA_PP = 0.5  # low-precision eval may lose at most this
# Only gate geometries whose committed patchy-vs-padded margin is material:
# a ratio barely above parity (e.g. model1's 1.04x) leaves less slack than
# shared-runner timing noise, which is exactly the flaky assert the old CI
# step removed.  Near-parity geometries are reported but not enforced.
MIN_GATED_RATIO = 1.2


def assert_patchy_speedup(fresh: dict, baseline: dict) -> None:
    if fresh.get("scale") != baseline.get("scale"):
        raise SystemExit(
            f"--assert-patchy-speedup: this run used --scale "
            f"{fresh.get('scale')} but the committed baseline was recorded "
            f"at --scale {baseline.get('scale')}; the patchy/padded ratio "
            f"is geometry-dependent, so the gate only compares same-scale "
            f"runs — pass --scale {baseline.get('scale')}")
    checked = 0
    for name, row in fresh["geometries"].items():
        base_row = baseline.get("geometries", {}).get(name)
        if base_row is None or "patchy_speedup_vs_padded" not in base_row:
            continue
        committed = base_row["patchy_speedup_vs_padded"]
        got = row["patchy_speedup_vs_padded"]
        if committed < MIN_GATED_RATIO:
            print(f"assert_patchy_speedup,{got:.3f},{name}_ratio "
                  f"(informational: committed {committed:.3f} is below the "
                  f"{MIN_GATED_RATIO} gating margin)")
            continue
        want = committed * REGRESSION_HEADROOM
        print(f"assert_patchy_speedup,{got:.3f},{name}_ratio "
              f"(floor {want:.3f}, committed {committed:.3f})")
        if got < want:
            raise SystemExit(
                f"patchy speedup regression on {name}: patchy/padded step "
                f"ratio {got:.3f} fell below {want:.3f} (committed "
                f"{committed:.3f} with 20% headroom) — the scatter-free "
                f"compact schedule lost its edge; inspect "
                f"BENCH_kernels.json")
        checked += 1
    if checked == 0:
        raise SystemExit(
            "--assert-patchy-speedup: no comparable geometries between "
            "this run and the committed baseline")
    print(f"assert_patchy_speedup,OK,{checked}_geometries")


def assert_quant_accuracy(max_delta_pp: float = MAX_QUANT_ACC_DELTA_PP,
                          epochs: int = 6, seed: int = 0) -> dict:
    """Train small, eval the SAME fp32 state under each serving dtype
    (``infer`` reroutes low-precision specs through the packed path, so
    this measures exactly what the engine serves)."""
    from repro.configs.bcpnn_models import deep_synth_spec
    from repro.core import Trainer, evaluate_padded
    from repro.data.synthetic import encode_images, make_synthetic

    ds = make_synthetic(768, 256, 8, 4, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=8, depth=1, n_classes=4, hidden_hc=8,
                           hidden_mc=16, nact=[32], patchy_traces=True,
                           compact=True, struct_every=25, backend="pallas")
    tr = Trainer(spec, seed=seed)
    tr.fit(xt, ds.y_train, epochs=epochs, batch=64)
    acc32 = evaluate_padded(tr.state, spec, xe, ds.y_test, 64)
    print(f"assert_quant_accuracy,{acc32*100:.2f},fp32_acc_pct")
    out = {"fp32": acc32}
    for dt in ("bf16", "int8"):
        acc = evaluate_padded(tr.state, spec.with_infer_dtype(dt),
                              xe, ds.y_test, 64)
        delta = (acc32 - acc) * 100
        out[dt] = acc
        print(f"assert_quant_accuracy,{acc*100:.2f},{dt}_acc_pct "
              f"(delta {delta:+.2f}pp, max {max_delta_pp}pp)")
        if delta > max_delta_pp:
            raise SystemExit(
                f"low-precision accuracy regression: {dt} inference lost "
                f"{delta:.2f}pp vs fp32 ({acc32*100:.2f}% -> "
                f"{acc*100:.2f}%), more than the {max_delta_pp}pp budget "
                f"— inspect the quantization path (kernels/quant.py, "
                f"DESIGN.md §8)")
    print("assert_quant_accuracy,OK,2_dtypes")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow BCPNN latency benches")
    ap.add_argument("--scale", type=int, default=None,
                    help="geometry shrink factor for bench_kernels")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations for bench_kernels")
    ap.add_argument("--assert-patchy-speedup", action="store_true",
                    help="fail if the kernels bench's patchy/padded step "
                         "ratio regressed >20%% vs --baseline")
    ap.add_argument("--assert-quant-accuracy", action="store_true",
                    help="fail if bf16/int8 inference loses more than "
                         f"{MAX_QUANT_ACC_DELTA_PP}pp eval accuracy vs "
                         "fp32 on the synthetic task")
    ap.add_argument("--baseline", default="BENCH_kernels.json",
                    help="committed snapshot the speedup gate compares to")
    args = ap.parse_args()
    from . import (bench_bcpnn, bench_kernels, bench_lm_rooflines,
                   bench_roofline_bcpnn, bench_stream_vs_seq, bench_struct,
                   bench_train_dp)

    kernels_kw = {}
    if args.scale is not None:
        kernels_kw["scale"] = args.scale
    if args.iters is not None:
        kernels_kw["iters"] = args.iters

    def run_kernels():
        # Snapshot the committed baseline BEFORE the bench runs: the bench
        # rewrites BENCH_kernels.json (its default json_path), which is
        # also the default baseline.
        baseline = None
        if args.assert_patchy_speedup:
            try:
                with open(args.baseline) as f:
                    baseline = json.load(f)
            except FileNotFoundError:
                raise SystemExit(
                    f"--assert-patchy-speedup: baseline file "
                    f"{args.baseline!r} does not exist — run the kernels "
                    f"bench once to record it, or point --baseline at the "
                    f"committed snapshot")
            except json.JSONDecodeError as e:
                raise SystemExit(
                    f"--assert-patchy-speedup: baseline {args.baseline!r} "
                    f"is not valid JSON ({e}) — re-record it with the "
                    f"kernels bench")
            if "geometries" not in baseline or "scale" not in baseline:
                raise SystemExit(
                    f"--assert-patchy-speedup: baseline {args.baseline!r} "
                    f"carries no geometries/scale spec — it is not a "
                    f"kernels-bench snapshot; re-record it")
            # keep the committed snapshot pristine: the gate run records
            # its (machine/scale-specific) numbers next to it instead
            kernels_kw.setdefault("json_path", "BENCH_kernels.latest.json")
        out = bench_kernels.run(**kernels_kw)
        if baseline is not None:
            assert_patchy_speedup(out, baseline)
        return out

    benches = {
        "roofline_bcpnn": bench_roofline_bcpnn.run,
        "lm_rooflines": bench_lm_rooflines.run,
        "stream_vs_seq": bench_stream_vs_seq.run,
        "kernels": run_kernels,
        "bcpnn": bench_bcpnn.run,
        "struct": bench_struct.run,
        "train_dp": bench_train_dp.run,
        "quant_accuracy": assert_quant_accuracy,
    }
    selected = (args.only.split(",") if args.only
                else [k for k in benches
                      if not (args.quick and k in ("bcpnn", "struct",
                                                   "train_dp"))
                      and k != "quant_accuracy"])
    if args.assert_quant_accuracy and "quant_accuracy" not in selected:
        selected.append("quant_accuracy")
    if args.assert_patchy_speedup and "kernels" not in selected:
        print("--assert-patchy-speedup requires the kernels bench",
              file=sys.stderr)
        raise SystemExit(2)
    for name in selected:
        benches[name]()


if __name__ == "__main__":
    main()
