"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV rows:
  * bench_bcpnn           — Table 2 latency/accuracy rows (CPU baseline)
  * bench_struct          — Table 2 'struct' rows (on-device rewire cost)
  * bench_stream_vs_seq   — §4.1 sequential vs stream-dataflow
  * bench_kernels         — dense vs padded vs patchy kernel schedules
                            (writes BENCH_kernels.json)
  * bench_roofline_bcpnn  — Fig. 6 roofline placement (TPU target)
  * bench_lm_rooflines    — assigned-arch dry-run roofline table

``--assert-patchy-speedup`` is the CI smoke gate for the compact patchy
schedule: it reruns the kernels bench and fails if the measured
patchy-vs-padded step ratio regressed by more than 20% against the
committed ``BENCH_kernels.json``.  The RATIO is compared, not absolute
step_ms — CI hardware differs from whatever produced the committed
snapshot, but both schedules of one run share the machine and geometry,
so their ratio is the transportable signal.  The run must use the same
``--scale`` as the committed snapshot (the ratio is geometry-dependent;
the gate enforces this).
"""
import argparse
import json
import sys

REGRESSION_HEADROOM = 0.8  # fresh ratio must be >= 80% of committed
# Only gate geometries whose committed patchy-vs-padded margin is material:
# a ratio barely above parity (e.g. model1's 1.04x) leaves less slack than
# shared-runner timing noise, which is exactly the flaky assert the old CI
# step removed.  Near-parity geometries are reported but not enforced.
MIN_GATED_RATIO = 1.2


def assert_patchy_speedup(fresh: dict, baseline: dict) -> None:
    if fresh.get("scale") != baseline.get("scale"):
        raise SystemExit(
            f"--assert-patchy-speedup: this run used --scale "
            f"{fresh.get('scale')} but the committed baseline was recorded "
            f"at --scale {baseline.get('scale')}; the patchy/padded ratio "
            f"is geometry-dependent, so the gate only compares same-scale "
            f"runs — pass --scale {baseline.get('scale')}")
    checked = 0
    for name, row in fresh["geometries"].items():
        base_row = baseline.get("geometries", {}).get(name)
        if base_row is None or "patchy_speedup_vs_padded" not in base_row:
            continue
        committed = base_row["patchy_speedup_vs_padded"]
        got = row["patchy_speedup_vs_padded"]
        if committed < MIN_GATED_RATIO:
            print(f"assert_patchy_speedup,{got:.3f},{name}_ratio "
                  f"(informational: committed {committed:.3f} is below the "
                  f"{MIN_GATED_RATIO} gating margin)")
            continue
        want = committed * REGRESSION_HEADROOM
        print(f"assert_patchy_speedup,{got:.3f},{name}_ratio "
              f"(floor {want:.3f}, committed {committed:.3f})")
        if got < want:
            raise SystemExit(
                f"patchy speedup regression on {name}: patchy/padded step "
                f"ratio {got:.3f} fell below {want:.3f} (committed "
                f"{committed:.3f} with 20% headroom) — the scatter-free "
                f"compact schedule lost its edge; inspect "
                f"BENCH_kernels.json")
        checked += 1
    if checked == 0:
        raise SystemExit(
            "--assert-patchy-speedup: no comparable geometries between "
            "this run and the committed baseline")
    print(f"assert_patchy_speedup,OK,{checked}_geometries")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow BCPNN latency benches")
    ap.add_argument("--scale", type=int, default=None,
                    help="geometry shrink factor for bench_kernels")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations for bench_kernels")
    ap.add_argument("--assert-patchy-speedup", action="store_true",
                    help="fail if the kernels bench's patchy/padded step "
                         "ratio regressed >20%% vs --baseline")
    ap.add_argument("--baseline", default="BENCH_kernels.json",
                    help="committed snapshot the speedup gate compares to")
    args = ap.parse_args()
    from . import (bench_bcpnn, bench_kernels, bench_lm_rooflines,
                   bench_roofline_bcpnn, bench_stream_vs_seq, bench_struct)

    kernels_kw = {}
    if args.scale is not None:
        kernels_kw["scale"] = args.scale
    if args.iters is not None:
        kernels_kw["iters"] = args.iters

    def run_kernels():
        # Snapshot the committed baseline BEFORE the bench runs: the bench
        # rewrites BENCH_kernels.json (its default json_path), which is
        # also the default baseline.
        baseline = None
        if args.assert_patchy_speedup:
            with open(args.baseline) as f:
                baseline = json.load(f)
            # keep the committed snapshot pristine: the gate run records
            # its (machine/scale-specific) numbers next to it instead
            kernels_kw.setdefault("json_path", "BENCH_kernels.latest.json")
        out = bench_kernels.run(**kernels_kw)
        if baseline is not None:
            assert_patchy_speedup(out, baseline)
        return out

    benches = {
        "roofline_bcpnn": bench_roofline_bcpnn.run,
        "lm_rooflines": bench_lm_rooflines.run,
        "stream_vs_seq": bench_stream_vs_seq.run,
        "kernels": run_kernels,
        "bcpnn": bench_bcpnn.run,
        "struct": bench_struct.run,
    }
    selected = (args.only.split(",") if args.only
                else [k for k in benches
                      if not (args.quick and k in ("bcpnn", "struct"))])
    if args.assert_patchy_speedup and "kernels" not in selected:
        print("--assert-patchy-speedup requires the kernels bench",
              file=sys.stderr)
        raise SystemExit(2)
    for name in selected:
        benches[name]()


if __name__ == "__main__":
    main()
