"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,unit`` CSV rows:
  * bench_bcpnn           — Table 2 latency/accuracy rows (CPU baseline)
  * bench_struct          — Table 2 'struct' rows (on-device rewire cost)
  * bench_stream_vs_seq   — §4.1 sequential vs stream-dataflow
  * bench_kernels         — dense vs padded vs patchy kernel schedules
                            (writes BENCH_kernels.json)
  * bench_roofline_bcpnn  — Fig. 6 roofline placement (TPU target)
  * bench_lm_rooflines    — assigned-arch dry-run roofline table
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow BCPNN latency benches")
    args = ap.parse_args()
    from . import (bench_bcpnn, bench_kernels, bench_lm_rooflines,
                   bench_roofline_bcpnn, bench_stream_vs_seq, bench_struct)
    benches = {
        "roofline_bcpnn": bench_roofline_bcpnn.run,
        "lm_rooflines": bench_lm_rooflines.run,
        "stream_vs_seq": bench_stream_vs_seq.run,
        "kernels": bench_kernels.run,
        "bcpnn": bench_bcpnn.run,
        "struct": bench_struct.run,
    }
    selected = (args.only.split(",") if args.only
                else [k for k in benches
                      if not (args.quick and k in ("bcpnn", "struct"))])
    for name in selected:
        benches[name]()


if __name__ == "__main__":
    main()
