"""Paper Fig. 6 analogue: roofline placement of the three BCPNN models,
on the TPU v5e target (197 TF/s bf16 / 819 GB/s HBM -> machine balance
~240 FLOP/B) — the same first-principles methodology as the paper's
Eq. 2-5, with TPU resource terms instead of LUT/DSP counts.

Two placements per model:

  * **combined step** (always f32): support + co-activation + EMA/weight
    epilogue, the training/online-learning configuration — trace state
    never leaves fp32 (DESIGN.md §8), so this point has no dtype axis;
  * **inference-only forward**, one row per serving dtype (fp32 / bf16 /
    int8): traffic from ``repro.launch.roofline.bcpnn_fwd_traffic`` with
    bytes-per-element as the free variable.  Weight streaming dominates
    the byte count at serving batch sizes, so bf16 roughly doubles and
    int8 roughly quadruples arithmetic intensity — the ``intensity_gain``
    column states the honest ratio vs the same model's fp32 row.
"""
from __future__ import annotations

from repro.configs.bcpnn_models import BCPNN_MODELS
from repro.launch.roofline import bcpnn_fwd_traffic

PEAK_FLOPS = 197e12
HBM_BW = 819e9

INFER_DTYPES = ("fp32", "bf16", "int8")


def _place(flops: float, bytes_: float, batch: int) -> dict:
    intensity = flops / bytes_
    achievable = min(PEAK_FLOPS, intensity * HBM_BW)
    return {"intensity": intensity,
            "achievable_tflops": achievable / 1e12,
            "roofline_frac": achievable / PEAK_FLOPS,
            "proj_us_per_img": flops / achievable / batch * 1e6}


def roofline_point(cfg, batch=128):
    """Combined learn+infer step, all-f32 (the trace EMA pins it)."""
    ni = cfg.input_hc * cfg.input_mc
    nj = cfg.hidden_hc * cfg.hidden_mc
    b = batch
    flops = 2 * b * ni * nj * 2 + 8 * ni * nj + 6 * b * nj
    # fused traffic (f32): x, w, h, pij in/out, w out, mask
    bytes_ = 4 * (2 * b * ni + ni * nj * 4 + 2 * b * nj)
    return _place(flops, bytes_, b)


def infer_point(cfg, dtype: str, batch=128):
    """Inference-only forward in one serving dtype (weights stream in
    ``dtype``; activations arrive f32 and quantize on-chip)."""
    t = bcpnn_fwd_traffic(batch, cfg.input_hc * cfg.input_mc,
                          cfg.hidden_hc * cfg.hidden_mc,
                          weight_dtype=dtype, act_dtype="fp32",
                          n_hc=cfg.hidden_hc)
    return _place(t["flops"], t["bytes"], batch)


def run(csv=True):
    rows = []
    for name, (cfg, _ds, _ep) in BCPNN_MODELS.items():
        if name.endswith("-struct"):
            continue
        r = roofline_point(cfg)
        r["name"] = name
        rows.append(r)
        if csv:
            print(f"roofline_{name},{r['intensity']:.1f},flop_per_byte")
            print(f"roofline_{name},{r['achievable_tflops']:.1f},achievable_tflops")
            print(f"roofline_{name},{r['roofline_frac']*100:.0f},roofline_pct")
            print(f"roofline_{name},{r['proj_us_per_img']:.2f},proj_us_per_img")
        base = infer_point(cfg, "fp32")
        for dt in INFER_DTYPES:
            ri = infer_point(cfg, dt)
            ri["name"] = f"{name}-infer-{dt}"
            ri["intensity_gain"] = ri["intensity"] / base["intensity"]
            rows.append(ri)
            if csv:
                tag = f"roofline_{name}_infer_{dt}"
                print(f"{tag},{ri['intensity']:.1f},flop_per_byte")
                print(f"{tag},{ri['intensity_gain']:.2f},intensity_gain_vs_fp32")
                print(f"{tag},{ri['proj_us_per_img']:.2f},proj_us_per_img")
    return rows


if __name__ == "__main__":
    run()
