"""Paper Fig. 6 analogue: roofline placement of the three BCPNN models,
on the TPU v5e target (197 TF/s bf16 / 819 GB/s HBM -> machine balance
~240 FLOP/B) — the same first-principles methodology as the paper's
Eq. 2-5, with TPU resource terms instead of LUT/DSP counts.

Arithmetic intensity of a combined BCPNN step (per batch of B images):
    FLOPs  = 2*B*Ni*Nj (support) + 2*B*Ni*Nj (co-activation)
             + ~8*Ni*Nj (EMA + log-weight epilogue) + softmax small
    Bytes  = fused-schedule traffic (see bench_stream_vs_seq)
"""
from __future__ import annotations

from repro.configs.bcpnn_models import BCPNN_MODELS

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def roofline_point(cfg, batch=128):
    ni = cfg.input_hc * cfg.input_mc
    nj = cfg.hidden_hc * cfg.hidden_mc
    b = batch
    flops = 2 * b * ni * nj * 2 + 8 * ni * nj + 6 * b * nj
    # fused traffic (f32): x, w, h, pij in/out, w out, mask
    bytes_ = 4 * (2 * b * ni + ni * nj * 4 + 2 * b * nj)
    intensity = flops / bytes_
    achievable = min(PEAK_FLOPS, intensity * HBM_BW)
    frac = achievable / PEAK_FLOPS
    # projected time per image on the TPU target
    t_img = flops / achievable / b
    return {"intensity": intensity, "achievable_tflops": achievable / 1e12,
            "roofline_frac": frac, "proj_us_per_img": t_img * 1e6}


def run(csv=True):
    rows = []
    for name, (cfg, _ds, _ep) in BCPNN_MODELS.items():
        if name.endswith("-struct"):
            continue
        r = roofline_point(cfg)
        r["name"] = name
        rows.append(r)
        if csv:
            print(f"roofline_{name},{r['intensity']:.1f},flop_per_byte")
            print(f"roofline_{name},{r['achievable_tflops']:.1f},achievable_tflops")
            print(f"roofline_{name},{r['roofline_frac']*100:.0f},roofline_pct")
            print(f"roofline_{name},{r['proj_us_per_img']:.2f},proj_us_per_img")
    return rows


if __name__ == "__main__":
    run()
