"""Block-size autotuner for the Pallas BCPNN kernels.

Sweeps candidate block sizes per (kernel, geometry) on the ACTIVE jax
backend, times each candidate end-to-end (pad + kernel + unpad, jit'd,
best-of-``--iters``), and persists the winners into the autotune cache
(src/repro/kernels/tuning.py) that ``kernels/ops.py`` consults — so
Model-1/2/3-scale geometries run on measured blocks instead of guessed
defaults.  On TPU the numbers are Mosaic wall-clock; on CPU they time the
interpreter (useful for exercising the machinery — CI runs ``--smoke`` —
not for picking TPU blocks).

    PYTHONPATH=src python -m benchmarks.autotune --models model1-mnist
    PYTHONPATH=src python -m benchmarks.autotune --smoke   # CI: tiny sweep

Cache location: ``$REPRO_AUTOTUNE_CACHE`` or ``--out`` (see DESIGN.md §7
for the file format).
"""
from __future__ import annotations

import argparse
import itertools
import time

import jax
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.bcpnn_fwd import bcpnn_fwd_pallas
from repro.kernels.bcpnn_update import bcpnn_update_pallas
from repro.kernels.hc_softmax import hc_softmax_pallas
from repro.kernels.ops import _interpret
from repro.kernels.patchy import (compact_forward, compact_update,
                                  patchy_forward, patchy_update)

# Geometry per model (Table 1 shapes): hi*mi pre units, hj*mj post units,
# nact the struct-variant connectivity budget.
GEOMS = {
    "model1-mnist": dict(b=128, hi=28 * 28, mi=2, hj=32, mj=128, nact=128),
    "model2-pneumonia": dict(b=128, hi=28 * 28, mi=2, hj=32, mj=256, nact=128),
    "model3-breast": dict(b=128, hi=64 * 64, mi=2, hj=32, mj=128, nact=128),
    "smoke": dict(b=32, hi=49, mi=2, hj=4, mj=10, nact=8),
}

FULL_CANDIDATES = {
    "hc_softmax": {"block_b": (128, 256), "block_h": (4, 8, 16)},
    "bcpnn_fwd": {"block_b": (128, 256), "block_j": (256, 512, 1024),
                  "block_k": (256, 512)},
    "bcpnn_update": {"block_i": (256, 512), "block_j": (256, 512, 1024),
                     "block_k": (64, 128)},
    "patchy_forward": {"block_b": (128, 256), "block_k": (256, 512)},
    "patchy_update": {"block_i": (256, 512), "block_k": (64, 128)},
    "compact_forward": {"block_b": (128, 256), "block_k": (256, 512)},
    "compact_update": {"block_i": (256, 512), "block_k": (64, 128)},
}
# The interpreter pays per-tile Python overhead, so a wide sweep is slow
# and meaningless off-TPU; exercise the machinery with two points each.
SMOKE_CANDIDATES = {
    "hc_softmax": {"block_b": (32, 64)},
    "bcpnn_fwd": {"block_j": (64, 128)},
    "bcpnn_update": {"block_i": (64, 128)},
    "patchy_forward": {"block_b": (16, 32)},
    "patchy_update": {"block_i": (16, 32)},
    "compact_forward": {"block_b": (16, 32)},
    "compact_update": {"block_i": (16, 32)},
}


def _time(fn, iters: int) -> float:
    out = fn()
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _make_operands(g: dict):
    k = jax.random.split(jax.random.PRNGKey(0), 6)
    ni, nj = g["hi"] * g["mi"], g["hj"] * g["mj"]
    x = jax.random.uniform(k[0], (g["b"], ni))
    y = jax.random.uniform(k[1], (g["b"], nj))
    w = jax.random.normal(k[2], (ni, nj)) * 0.1
    bias = jax.random.normal(k[3], (nj,))
    pij = jax.random.uniform(k[4], (ni, nj)) * 0.01 + 1e-5
    from repro.core.bcpnn_layer import topk_mask
    from repro.core.compact import build_table, gather_dense, unit_indices
    nact = min(g["nact"], g["hi"])
    mask_hc = topk_mask(jax.random.uniform(k[5], (g["hi"], g["hj"])), nact)
    mask = jnp.repeat(jnp.repeat(mask_hc, g["mi"], 0), g["mj"], 1)
    table = build_table(mask_hc, nact)
    ui = unit_indices(table, g["mi"], sentinel=ni)
    lpi = jnp.log(jnp.full((ni,), 0.5))
    lpj = jnp.log(jnp.full((nj,), 1.0 / g["mj"]))
    alpha = jnp.asarray(0.01)
    return dict(x=x, y=y, w=w, bias=bias, pij=pij, mask=mask,
                mask_hc=mask_hc, table=table,
                w_c=gather_dense(w, ui, g["hj"], g["mj"]),
                pij_c=gather_dense(pij, ui, g["hj"], g["mj"]),
                lpi=lpi, lpj=lpj, alpha=alpha)


def _calls(g: dict, ops: dict, interpret: bool):
    """kernel name -> (dims-for-cache-key, candidate-kwargs -> thunk)."""
    b, hi, mi, hj, mj = g["b"], g["hi"], g["mi"], g["hj"], g["mj"]
    nact = min(g["nact"], hi)
    ni, nj = hi * mi, hj * mj
    k_units = nact * mi
    return {
        "hc_softmax": (dict(b=b, n_hc=hj, n_mc=mj), lambda kw: lambda:
                       hc_softmax_pallas(ops["y"], hj, mj,
                                         interpret=interpret, **kw)),
        "bcpnn_fwd": (dict(b=b, ni=ni, n_hc=hj, n_mc=mj), lambda kw: lambda:
                      bcpnn_fwd_pallas(ops["x"], ops["w"], ops["bias"], hj,
                                       mj, interpret=interpret, **kw)),
        "bcpnn_update": (dict(b=b, ni=ni, nj=nj), lambda kw: lambda:
                         bcpnn_update_pallas(
                             ops["pij"], ops["lpi"], ops["lpj"], ops["x"],
                             ops["y"], ops["mask"], ops["alpha"],
                             interpret=interpret, **kw)),
        "patchy_forward": (dict(b=b, k=k_units, hj=hj, mj=mj), lambda kw:
                           lambda: patchy_forward(
                               ops["x"], ops["w"], ops["bias"],
                               ops["table"], mi, hj, mj,
                               interpret=interpret, **kw)),
        "patchy_update": (dict(b=b, k=k_units, hj=hj, mj=mj), lambda kw:
                          lambda: patchy_update(
                              ops["pij"], ops["lpi"], ops["lpj"], ops["x"],
                              ops["y"], ops["table"], ops["alpha"],
                              mi, hj, mj, interpret=interpret, **kw)),
        "compact_forward": (dict(b=b, k=k_units, hj=hj, mj=mj), lambda kw:
                            lambda: compact_forward(
                                ops["x"], ops["w_c"], ops["bias"],
                                ops["table"], mi,
                                interpret=interpret, **kw)),
        "compact_update": (dict(b=b, k=k_units, hj=hj, mj=mj), lambda kw:
                           lambda: compact_update(
                               ops["pij_c"], ops["lpi"], ops["lpj"],
                               ops["x"], ops["y"], ops["table"],
                               ops["alpha"], mi,
                               interpret=interpret, **kw)),
    }


def autotune(models, candidates, iters: int, out=None, verbose=True):
    interpret = _interpret()
    entries, report = {}, []
    for name in models:
        g = GEOMS[name]
        ops = _make_operands(g)
        for kernel, (dims, make) in _calls(g, ops, interpret).items():
            grid = candidates[kernel]
            keys = sorted(grid)
            best_kw, best_t = None, float("inf")
            for combo in itertools.product(*(grid[k] for k in keys)):
                kw = dict(zip(keys, combo))
                t = _time(make(kw), iters)
                if verbose:
                    print(f"autotune,{t*1e6:.0f},{name}.{kernel}."
                          + "_".join(f"{k}{v}" for k, v in kw.items()))
                if t < best_t:
                    best_kw, best_t = kw, t
            entries[tuning.entry_key(kernel, **dims)] = best_kw
            report.append((name, kernel, best_kw, best_t))
    path = tuning.save_entries(entries, out)
    if verbose:
        for name, kernel, kw, t in report:
            print(f"autotune_winner,{t*1e6:.0f},{name}.{kernel}={kw}")
        print(f"autotune: {len(entries)} entries -> {path}")
    return entries, path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="model1-mnist",
                    help="comma-separated geometry names "
                         f"(choices: {', '.join(GEOMS)})")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + 2-point sweep; asserts the cache "
                         "round-trips through kernels.ops (what CI runs)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=None,
                    help="cache file (default: $REPRO_AUTOTUNE_CACHE or "
                         "~/.cache/repro_bcpnn/autotune.json)")
    args = ap.parse_args()
    if args.smoke:
        entries, path = autotune(["smoke"], SMOKE_CANDIDATES, iters=1,
                                 out=args.out)
        # the cache must be consultable exactly as ops.py will ask for it
        import os
        os.environ[tuning.ENV_CACHE] = path
        g = GEOMS["smoke"]
        tuned = tuning.lookup("bcpnn_fwd", b=g["b"], ni=g["hi"] * g["mi"],
                              n_hc=g["hj"], n_mc=g["mj"])
        assert tuned, "smoke autotune produced no consultable bcpnn_fwd entry"
        assert len(entries) == len(SMOKE_CANDIDATES)
        print(f"autotune --smoke OK: bcpnn_fwd -> {tuned}")
        return
    autotune([m.strip() for m in args.models.split(",")],
             FULL_CANDIDATES, iters=args.iters, out=args.out)


if __name__ == "__main__":
    main()
