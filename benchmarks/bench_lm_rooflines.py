"""Roofline table over the assigned-architecture dry-run artifacts.

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints the per-cell roofline terms — the §Roofline deliverable's data.
"""
from __future__ import annotations

import glob
import json
import os


def load(dirpath="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def run(csv=True, dirpath="experiments/dryrun"):
    rows = []
    for r in load(dirpath):
        tag = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        if r["status"] != "ok":
            if csv:
                print(f"dryrun_{tag},0,{r['status']}")
            continue
        rf = r["roofline"]
        dom = rf["bottleneck"]
        total = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        rows.append(r)
        if csv:
            print(f"dryrun_{tag},{rf['compute_s']*1e3:.2f},compute_ms")
            print(f"dryrun_{tag},{rf['memory_s']*1e3:.2f},memory_ms")
            print(f"dryrun_{tag},{rf['collective_s']*1e3:.2f},collective_ms")
            print(f"dryrun_{tag},{rf['useful_ratio']:.3f},useful_flop_ratio")
            print(f"dryrun_{tag},0,{dom}_bound")
    return rows


if __name__ == "__main__":
    run()
