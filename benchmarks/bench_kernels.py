"""Kernel-level BENCH: masked-dense (jnp) vs padded-aligned Pallas vs
patchy-sparse Pallas, per model geometry.

Times one projection's hot-path pair — activation (forward) and
plasticity (learn) — under the execution schedules the codebase offers
(DESIGN.md §3/§7):

  * ``jnp_dense``      — the XLA reference: dense matmul over the masked
                         weights, dense trace EMA + mask multiply;
  * ``pallas_padded``  — the fused dense kernels on pad-to-aligned tiles
                         (the pre-patchy production path);
  * ``pallas_patchy``  — the COMPACT-RESIDENT patchy path
                         (``ProjSpec.compact``): state lives as
                         (Hj, K, Mj), the learn kernel is scatter-free —
                         the production patchy schedule;
  * ``pallas_patchy_scatter`` — the dense-resident patchy path
                         (``patchy_traces`` without ``compact``): the
                         same compact kernels but paying the per-step
                         O(Ni·Nj) gather/scatter round-trip, kept as the
                         cost-of-the-dense-layout data point;
  * ``pallas_patchy_bf16`` / ``pallas_patchy_int8`` — the low-precision
                         SERVING forwards (DESIGN.md §8) over the compact
                         layout: weights packed once at a fold boundary
                         (cast / per-HC-quantized), learn unchanged fp32.
                         Each row carries the modeled roofline intensity
                         gain vs the fp32 forward (the bandwidth win the
                         dtype buys on real hardware; the CPU interpreter
                         only shows compute parity).

Emits ``name,value,unit`` CSV rows plus a ``BENCH_kernels.json`` dump so
the perf trajectory has machine-readable data points
(``benchmarks/run.py --assert-patchy-speedup`` gates CI on the
patchy-vs-padded ratio recorded here).  By default the paper geometries
are scaled down by ``--scale`` (the CPU interpreter pays per-tile Python
overhead; the nact/Hi sparsity ratio is preserved, so the
patchy-vs-dense proportionality claim is still measured); pass
``--scale 1`` on real hardware.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.core.bcpnn_layer import (
    ProjSpec, forward, init_projection, learn, pack_projection,
)
from repro.core.hypercolumns import LayerGeom
from repro.kernels import fused_forward, fused_learn, fused_packed_forward
from repro.kernels.ops import bcpnn_fwd
from repro.launch.roofline import bcpnn_fwd_traffic

MODEL_GEOMS = {
    "model1-mnist": dict(b=128, hi=28 * 28, mi=2, hj=32, mj=128, nact=128),
    "model2-pneumonia": dict(b=128, hi=28 * 28, mi=2, hj=32, mj=256, nact=128),
    "model3-breast": dict(b=128, hi=64 * 64, mi=2, hj=32, mj=128, nact=128),
}


def scale_geom(g: dict, s: int) -> dict:
    """Shrink a geometry by ~s while preserving the nact/Hi ratio."""
    if s <= 1:
        return dict(g)
    return dict(b=max(32, g["b"] // s), hi=max(8, g["hi"] // s), mi=g["mi"],
                hj=max(4, g["hj"] // s), mj=max(16, g["mj"] // s),
                nact=max(2, g["nact"] // s))


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_geometry(name: str, g: dict, iters: int, csv: bool) -> dict:
    pre = LayerGeom(g["hi"], g["mi"])
    post = LayerGeom(g["hj"], g["mj"])
    nact = min(g["nact"], g["hi"])
    spec_jnp = ProjSpec(pre, post, alpha=1e-2, nact=nact, backend="jnp")
    spec_scatter = ProjSpec(pre, post, alpha=1e-2, nact=nact,
                            backend="pallas", patchy_traces=True)
    spec_compact = dataclasses.replace(spec_scatter, compact=True)
    spec_dense = dataclasses.replace(spec_scatter, patchy_traces=False)
    proj = init_projection(spec_jnp, jax.random.PRNGKey(0))
    from repro.core.compact import compactify_projection
    proj_c = compactify_projection(proj, spec_compact)
    x = jax.random.uniform(jax.random.PRNGKey(1), (g["b"], pre.N))
    y = forward(proj, spec_jnp, x)

    schedules = {
        # XLA reference: dense masked matmul + dense EMA with mask multiply
        "jnp_dense": (
            proj,
            jax.jit(lambda p, xb: forward(p, spec_jnp, xb)),
            jax.jit(lambda p, xb, yb: learn(p, spec_jnp, xb, yb)),
        ),
        # fused dense kernels on padded-aligned tiles (mask streamed in);
        # bcpnn_fwd directly so the nact spec doesn't divert to patchy
        "pallas_padded": (
            proj,
            jax.jit(lambda p, xb: bcpnn_fwd(
                xb, p.w, p.b, post.H, post.M, spec_jnp.gain)),
            jax.jit(lambda p, xb, yb: fused_learn(p, spec_dense, xb, yb)),
        ),
        # compact-RESIDENT patchy: scatter-free in-place kernels (the
        # production patchy schedule)
        "pallas_patchy": (
            proj_c,
            jax.jit(lambda p, xb: fused_forward(p, spec_compact, xb)),
            jax.jit(lambda p, xb, yb: fused_learn(p, spec_compact, xb, yb)),
        ),
        # dense-resident patchy: same kernels + the O(Ni·Nj) round-trip
        "pallas_patchy_scatter": (
            proj,
            jax.jit(lambda p, xb: fused_forward(p, spec_scatter, xb)),
            jax.jit(lambda p, xb, yb: fused_learn(p, spec_scatter, xb, yb)),
        ),
    }
    row = {"b": g["b"], "ni": pre.N, "nj": post.N, "hi": g["hi"],
           "nact": nact, "nact_over_hi": nact / g["hi"],
           # modeled MXU work per step (fwd + learn matmuls, MACs*2):
           # the dense schedules touch every (Ni, Nj) pair, the patchy
           # schedule only the nact live pre-blocks — ratio = Hi/nact.
           "model_flops_dense": 4 * g["b"] * pre.N * post.N,
           "model_flops_patchy": 4 * g["b"] * nact * g["mi"] * post.N}
    for sched, (p0, fwd, lrn) in schedules.items():
        t_f = _time(fwd, p0, x, iters=iters)
        t_l = _time(lrn, p0, x, y, iters=iters)
        step = t_f + t_l
        row[sched] = {"fwd_ms": t_f * 1e3, "learn_ms": t_l * 1e3,
                      "step_ms": step * 1e3,
                      "images_per_s": g["b"] / step}
        if csv:
            print(f"bench_kernels_{name}_{sched},{step*1e3:.2f},step_ms")
            print(f"bench_kernels_{name}_{sched},"
                  f"{g['b']/step:.0f},images_per_s")
    # Low-precision serving forwards over the compact layout: the pack is
    # derived ONCE (the fold-boundary cost, excluded from the per-step
    # timing exactly as the engine amortizes it) and the fp32 compact
    # learn rides along so step_ms stays comparable.
    base_traffic = bcpnn_fwd_traffic(g["b"], nact * g["mi"], post.N,
                                     weight_dtype="fp32", n_hc=post.H)
    for dt in ("bf16", "int8"):
        spec_q = dataclasses.replace(spec_compact, infer_dtype=dt)
        pack = pack_projection(proj_c, spec_q)
        jax.block_until_ready(pack.w)
        fwd_q = jax.jit(lambda pk, xb, _s=spec_q:
                        fused_packed_forward(pk, _s, xb))
        t_f = _time(fwd_q, pack, x, iters=iters)
        t_l = row["pallas_patchy"]["learn_ms"] * 1e-3
        step = t_f + t_l
        traffic = bcpnn_fwd_traffic(g["b"], nact * g["mi"], post.N,
                                    weight_dtype=dt, n_hc=post.H)
        row[f"pallas_patchy_{dt}"] = {
            "fwd_ms": t_f * 1e3, "learn_ms": t_l * 1e3,
            "step_ms": step * 1e3, "images_per_s": g["b"] / step,
            "model_intensity_flop_per_byte": traffic["intensity"],
            "intensity_gain_vs_fp32":
                traffic["intensity"] / base_traffic["intensity"],
        }
        row[f"{dt}_step_ratio_vs_fp32_patchy"] = (
            row["pallas_patchy"]["step_ms"] / (step * 1e3))
        if csv:
            tag = f"bench_kernels_{name}_pallas_patchy_{dt}"
            gain = traffic["intensity"] / base_traffic["intensity"]
            print(f"{tag},{step*1e3:.2f},step_ms")
            print(f"{tag},{g['b']/step:.0f},images_per_s")
            print(f"{tag},{gain:.2f},intensity_gain_vs_fp32")
    row["patchy_speedup_vs_padded"] = (
        row["pallas_padded"]["step_ms"] / row["pallas_patchy"]["step_ms"])
    row["compact_speedup_vs_scatter"] = (
        row["pallas_patchy_scatter"]["step_ms"]
        / row["pallas_patchy"]["step_ms"])
    if csv:
        print(f"bench_kernels_{name},"
              f"{row['patchy_speedup_vs_padded']:.2f},patchy_speedup_x")
        print(f"bench_kernels_{name},{g['hi']/nact:.2f},hi_over_nact_x")
    return row


def run(csv=True, json_path="BENCH_kernels.json", scale=4, iters=3,
        models=None):
    out = {"device": jax.default_backend(), "scale": scale, "geometries": {}}
    for name in models or MODEL_GEOMS:
        g = scale_geom(MODEL_GEOMS[name], scale)
        out["geometries"][name] = bench_geometry(name, g, iters, csv)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        if csv:
            print(f"bench_kernels_json={json.dumps(out)}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=4,
                    help="geometry shrink factor (1 = paper scale)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--models", default=None,
                    help="comma-separated subset of geometries")
    ap.add_argument("--json", default="BENCH_kernels.json")
    args = ap.parse_args()
    run(scale=args.scale, iters=args.iters, json_path=args.json,
        models=args.models.split(",") if args.models else None)
