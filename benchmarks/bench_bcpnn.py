"""Paper Table 2 analogue: per-image latency + derived energy model for the
three BCPNN models x {infer, train, train+struct}.

This container is CPU-only, so wall-clock numbers characterize the CPU
baseline column of Table 2; the TPU-side performance is projected from the
roofline model (bench_roofline_bcpnn) the same way the paper projects its
FPGA peak from first principles (Eq. 2-5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bcpnn_models import BCPNN_MODELS
from repro.core import (BCPNNConfig, eval_batches, infer, init_network,
                        supervised_epoch, unsupervised_epoch)
from repro.data.synthetic import encode_images, load_or_synthesize


def bench_model(name: str, cfg: BCPNNConfig, dataset: str, batch: int = 128,
                subset: int = 2048, bench_steps: int = 20):
    ds = load_or_synthesize(dataset)
    x = encode_images(ds.x_train[:subset])
    y = ds.y_train[:subset].astype(np.int32)
    nb = len(x) // batch
    xs = jnp.asarray(x[: nb * batch].reshape(nb, batch, -1))
    ys = jnp.asarray(y[: nb * batch].reshape(nb, batch))

    state = init_network(cfg, jax.random.PRNGKey(0))
    # --- train latency (one unsupervised epoch, steady-state) ----------
    state = unsupervised_epoch(state, cfg, xs)           # warm-up/compile
    jax.block_until_ready(state.ih.w)
    t0 = time.perf_counter()
    state = unsupervised_epoch(state, cfg, xs)
    jax.block_until_ready(state.ih.w)
    train_ms_img = (time.perf_counter() - t0) / (nb * batch) * 1e3

    state = supervised_epoch(state, cfg, xs, ys)
    jax.block_until_ready(state.ho.w)

    # --- inference latency ---------------------------------------------
    infer_j = jax.jit(lambda s, xb: infer(s, cfg, xb)[1])
    pred = infer_j(state, xs[0])
    jax.block_until_ready(pred)
    t0 = time.perf_counter()
    for i in range(bench_steps):
        pred = infer_j(state, xs[i % nb])
    jax.block_until_ready(pred)
    infer_ms_img = (time.perf_counter() - t0) / (bench_steps * batch) * 1e3

    acc = float(eval_batches(state, cfg, xs, ys))
    return {
        "name": name,
        "train_ms_per_img": train_ms_img,
        "infer_ms_per_img": infer_ms_img,
        "train_acc": acc,
    }


def run(csv=True):
    rows = []
    for name, (cfg, dataset, _epochs) in BCPNN_MODELS.items():
        if name.endswith("-struct"):
            continue  # struct variants benched in bench_struct
        r = bench_model(name, cfg, dataset)
        rows.append(r)
        if csv:
            print(f"bcpnn_{r['name']},{r['infer_ms_per_img']*1e3:.1f},"
                  f"infer_us_per_img")
            print(f"bcpnn_{r['name']},{r['train_ms_per_img']*1e3:.1f},"
                  f"train_us_per_img")
            print(f"bcpnn_{r['name']},{r['train_acc']*100:.1f},train_acc_pct")
    return rows


if __name__ == "__main__":
    run()
