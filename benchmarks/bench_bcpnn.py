"""Paper Table 2 analogue: per-image latency + derived energy model for the
three BCPNN models x {infer, train, train+struct}, plus a deep-stack row
(the multi-layer protocol of DESIGN.md §1).

This container is CPU-only, so wall-clock numbers characterize the CPU
baseline column of Table 2; the TPU-side performance is projected from the
roofline model (bench_roofline_bcpnn) the same way the paper projects its
FPGA peak from first principles (Eq. 2-5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.bcpnn_models import BCPNN_MODELS, deep_synth_spec
from repro.core import (as_spec, eval_batches, infer, init_deep,
                        supervised_epoch, unsupervised_layer_epoch)
from repro.data.synthetic import encode_images, load_or_synthesize


def bench_model(name: str, cfg, dataset: str, batch: int = 128,
                subset: int = 2048, bench_steps: int = 20):
    """cfg: BCPNNConfig or NetworkSpec — both drive the same engine."""
    spec = as_spec(cfg)
    ds = load_or_synthesize(dataset)
    x = encode_images(ds.x_train[:subset])
    y = ds.y_train[:subset].astype(np.int32)
    nb = len(x) // batch
    xs = jnp.asarray(x[: nb * batch].reshape(nb, batch, -1))
    ys = jnp.asarray(y[: nb * batch].reshape(nb, batch))

    state = init_deep(spec, jax.random.PRNGKey(0))
    # --- train latency (one unsupervised epoch per layer, steady-state) --
    for layer in range(spec.depth):                      # warm-up/compile
        state = unsupervised_layer_epoch(state, spec, xs, layer)
    jax.block_until_ready(state.projs[-1].w)
    t0 = time.perf_counter()
    for layer in range(spec.depth):
        state = unsupervised_layer_epoch(state, spec, xs, layer)
    jax.block_until_ready(state.projs[-1].w)
    train_ms_img = (time.perf_counter() - t0) / (nb * batch * spec.depth) * 1e3

    state = supervised_epoch(state, spec, xs, ys)
    jax.block_until_ready(state.readout.w)

    # --- inference latency ---------------------------------------------
    infer_j = jax.jit(lambda s, xb: infer(s, spec, xb)[1])
    pred = infer_j(state, xs[0])
    jax.block_until_ready(pred)
    t0 = time.perf_counter()
    for i in range(bench_steps):
        pred = infer_j(state, xs[i % nb])
    jax.block_until_ready(pred)
    infer_ms_img = (time.perf_counter() - t0) / (bench_steps * batch) * 1e3

    acc = float(eval_batches(state, spec, xs, ys))
    return {
        "name": name,
        "depth": spec.depth,
        "train_ms_per_img": train_ms_img,
        "infer_ms_per_img": infer_ms_img,
        "train_acc": acc,
    }


def run(csv=True):
    jobs = [(name, cfg, dataset)
            for name, (cfg, dataset, _epochs) in BCPNN_MODELS.items()
            if not name.endswith("-struct")]  # struct benched in bench_struct
    # deep-stack row: 2 hidden layers on the MNIST-shaped surrogate
    jobs.append(("deep2-synth",
                 deep_synth_spec(side=28, depth=2, n_classes=10,
                                 hidden_hc=32, hidden_mc=64, alpha=2e-3),
                 "mnist"))
    rows = []
    for name, cfg, dataset in jobs:
        r = bench_model(name, cfg, dataset)
        rows.append(r)
        if csv:
            print(f"bcpnn_{r['name']},{r['infer_ms_per_img']*1e3:.1f},"
                  f"infer_us_per_img")
            print(f"bcpnn_{r['name']},{r['train_ms_per_img']*1e3:.1f},"
                  f"train_us_per_img")
            print(f"bcpnn_{r['name']},{r['train_acc']*100:.1f},train_acc_pct")
    return rows


if __name__ == "__main__":
    run()
