"""Paper Table 2 'struct' rows: the cost of structural plasticity.

The paper computed structural plasticity ON THE HOST and measured models
2-3 losing their total-time advantage to that overhead.  Our rewire is
on-device (DESIGN.md §2), so the benchmark quantifies the delta directly:
unsupervised epoch with struct_every=k vs without.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.bcpnn_models import BCPNN_MODELS
from repro.core import init_network, unsupervised_epoch
from repro.data.synthetic import encode_images, load_or_synthesize


def bench(name, cfg, dataset, batch=128, subset=2048):
    ds = load_or_synthesize(dataset)
    x = encode_images(ds.x_train[:subset])
    nb = len(x) // batch
    xs = jnp.asarray(x[: nb * batch].reshape(nb, batch, -1))
    state = init_network(cfg, jax.random.PRNGKey(0))
    state = unsupervised_epoch(state, cfg, xs)  # compile
    jax.block_until_ready(state.ih.w)
    t0 = time.perf_counter()
    state = unsupervised_epoch(state, cfg, xs)
    jax.block_until_ready(state.ih.w)
    return (time.perf_counter() - t0) / (nb * batch) * 1e3


def run(csv=True):
    out = []
    for base in ("model1-mnist", "model2-pneumonia", "model3-breast"):
        cfg, dataset, _ = BCPNN_MODELS[base]
        cfg_s, _, _ = BCPNN_MODELS[base + "-struct"]
        t_plain = bench(base, cfg, dataset)
        t_struct = bench(base + "-struct", cfg_s, dataset)
        overhead = (t_struct / t_plain - 1) * 100
        out.append({"model": base, "plain_ms": t_plain,
                    "struct_ms": t_struct, "overhead_pct": overhead})
        if csv:
            print(f"struct_{base},{t_plain*1e3:.1f},plain_us_per_img")
            print(f"struct_{base},{t_struct*1e3:.1f},struct_us_per_img")
            print(f"struct_{base},{overhead:.0f},overhead_pct")
    return out


if __name__ == "__main__":
    run()
