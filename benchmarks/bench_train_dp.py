"""Trainer-driven data-parallel training: scaling + kill-resume rows.

Measures the DESIGN.md §12 fit path end to end: images/s of
``Trainer.fit`` on 1/2/4-way CPU meshes (scan-over-batches shard_map
epoch programs, padded-tail masked learning included — the default
train_n does not divide the batch), plus the elastic kill-resume row
from the 2-way run (a ``WorkerLost`` is raised mid-schedule, the mesh
is rebuilt from the survivors, and the fit resumes from the latest
checkpoint cursor; ``resumed_bit_identical`` must stay 1).

Each device count needs its own ``--xla_force_host_platform_device_count``
BEFORE jax initializes, so every row runs ``repro.launch.train_dp`` in a
fresh subprocess.  Host-CPU "scaling" here is a plumbing check, not a
speedup claim: the fake devices share the machine's cores, so the
transportable signals are images/s per width and the recovery overhead,
not a linear-scaling curve.
"""
import json
import os
import subprocess
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cli(n_devices: int, *, train_n: int, epochs: int, batch: int,
             no_kill: bool) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(_ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    with tempfile.TemporaryDirectory() as td:
        out_json = os.path.join(td, "out.json")
        cmd = [sys.executable, "-m", "repro.launch.train_dp",
               "--devices", str(n_devices), "--train-n", str(train_n),
               "--epochs", str(epochs), "--batch", str(batch),
               "--warmup", "--no-single", "--json", out_json]
        if no_kill:
            cmd.append("--no-kill")
        proc = subprocess.run(cmd, cwd=_ROOT, env=env,
                              capture_output=True, text=True)
        if proc.returncode != 0:
            raise SystemExit(
                f"train_dp subprocess ({n_devices}-way) failed:\n"
                f"{proc.stdout}\n{proc.stderr}")
        with open(out_json) as f:
            return json.load(f)


def run(devices=(1, 2, 4), json_path="BENCH_train_dp.json", train_n=328,
        epochs=2, batch=64, kill_devices=2) -> dict:
    out = {"train_n": train_n, "epochs": epochs, "batch": batch,
           "scaling": {}, "kill_resume": None}
    base_img_s = None
    for n in devices:
        row = _run_cli(n, train_n=train_n, epochs=epochs, batch=batch,
                       no_kill=(n != kill_devices))
        img_s = row["dp_images_per_s"]
        entry = {"dp_s": row["dp_s"], "dp_images_per_s": img_s,
                 "dp_acc": row["dp_acc"]}
        print(f"train_dp,{img_s:.0f},images_per_s_{n}way")
        if base_img_s is None:
            base_img_s = img_s
        else:
            entry["scaling_vs_1way"] = img_s / base_img_s
            print(f"train_dp,{img_s / base_img_s:.2f},"
                  f"scaling_{n}way_vs_{devices[0]}way")
        out["scaling"][str(n)] = entry
        if n == kill_devices and "kill_resume_s" in row:
            out["kill_resume"] = {
                "devices": n,
                "kill_resume_s": row["kill_resume_s"],
                "recovery_overhead_s": row["recovery_overhead_s"],
                "resumed_bit_identical": row["resumed_bit_identical"],
                "resumed_acc": row["resumed_acc"],
            }
            print(f"train_dp,{row['kill_resume_s']:.2f},kill_resume_s")
            print(f"train_dp,{row['recovery_overhead_s']:.2f},"
                  f"recovery_overhead_s")
            print(f"train_dp,{int(row['resumed_bit_identical'])},"
                  f"resumed_bit_identical")
    if json_path:
        with open(os.path.join(_ROOT, json_path)
                  if not os.path.isabs(json_path) else json_path, "w") as f:
            json.dump(out, f, indent=2)
            f.write("\n")
    return out


if __name__ == "__main__":
    run()
