"""Serving benchmark: offered-load sweep through the microbatched engine.

Two sections:

* **Single-model backend sweep** — for each backend (jnp reference,
  fused Pallas dispatch) and each offered arrival rate, drives the
  open-loop generator through ``BCPNNService`` and records achieved
  images/s, p50/p99 latency and batch occupancy.  A very high offered
  rate measures capacity (the admission queue saturates and microbatches
  run back-to-back at the largest bucket); a moderate rate measures
  latency at sustainable load.
* **Multi-model fairness sweep** — two checkpointed models behind ONE
  admission front under a 10:1 skewed Poisson mix, recording per-model
  images/s + p50/p99 and the minority completion share — the fairness
  surface the round-robin scheduler is designed for.
* **Overload scenario** — capacity is probed with an unbounded queue,
  then the same checkpoint is offered ~2x that rate behind a bounded
  admission front (``max_queue`` + per-request deadline, DESIGN.md
  §10), recording the shed/reject rates and the p99 of what was
  actually served.
* **Serving-dtype sweep** — ONE trained compact-patchy model served at
  capacity under each ``infer_dtype`` (fp32 / bf16 / int8, DESIGN.md
  §8): same checkpoint, same engine, only the packed inference weights
  change — images/s, p99 and served accuracy per dtype.
* **Router chaos scenario** — >= 3 engines behind one ``BCPNNRouter``,
  two replicated models under a superposed Poisson mix offered at ~10x
  single-engine capacity, one replica-hosting engine KILLED mid-run
  (DESIGN.md §11): router throughput, served p99 across reroute hops,
  per-model fairness ratio under the weighted quanta, and the
  engine-loss recovery time (loss detection -> replacement serving).

Output: ``name,value,unit`` CSV rows, one machine-readable
``bench_serve_json={...}`` line, and a JSON dump (default
``BENCH_serve.json``; the committed snapshot is refreshed from CI runs).
"""
from __future__ import annotations

import argparse
import json
import threading

import jax
import numpy as np

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import Trainer
from repro.data.synthetic import encode_images, make_synthetic
from repro.serve import (
    BCPNNRouter, BCPNNService, ServeMetrics, StreamSpec,
    run_multi_open_loop, run_open_loop,
)


def bench_backend(backend: str, rates, depth: int = 2, side: int = 8,
                  n_classes: int = 4, requests: int = 128,
                  max_batch: int = 16, epochs: int = 2, seed: int = 0,
                  csv: bool = True):
    ds = make_synthetic(512, 128, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=side, depth=depth, n_classes=n_classes,
                           hidden_hc=8, hidden_mc=16, backend=backend)
    tr = Trainer(spec, seed=seed)
    tr.fit(xt, ds.y_train, epochs=epochs, batch=64)

    # One service per backend, reused across rates: the per-instance jit
    # cache keeps every bucket shape compiled once (a per-rate instance
    # would pay the whole warmup again), with fresh metrics per run.
    svc = BCPNNService(tr.state, spec, max_batch=max_batch)
    svc.warmup()
    rows = []
    for rate in rates:
        svc.metrics = ServeMetrics()
        svc.start(warmup=False)
        rep = run_open_loop(svc, xe, ds.y_test, n_requests=requests,
                            rate_hz=rate, seed=seed)
        svc.stop()
        snap = svc.snapshot()
        row = {
            "backend": backend,
            "depth": depth,
            "offered_hz": rate,
            "achieved_hz": rep.achieved_rate_hz,
            "images_per_s": snap["images_per_s"],
            "p50_ms": snap["p50_ms"],
            "p99_ms": snap["p99_ms"],
            "batch_occupancy": snap["batch_occupancy"],
            "served_accuracy": rep.accuracy(),
        }
        rows.append(row)
        if csv:
            tag = f"serve_{backend}_d{depth}_r{rate:g}"
            print(f"{tag},{row['images_per_s']:.1f},images_per_s")
            print(f"{tag},{row['p50_ms']:.2f},p50_ms")
            print(f"{tag},{row['p99_ms']:.2f},p99_ms")
            print(f"{tag},{row['batch_occupancy']*100:.0f},occupancy_pct")
    return rows


def bench_multi_model(rates=(400.0,), skew: float = 10.0, side: int = 8,
                      n_classes: int = 4, requests: int = 256,
                      max_batch: int = 16, epochs: int = 2, seed: int = 0,
                      backend: str = "pallas", csv: bool = True):
    """Two models, one engine, ``skew``:1 Poisson mix at each combined
    rate: per-model throughput/latency + the minority fairness ratio
    (completion share / arrival share — 1.0 is perfectly proportional)."""
    ds = make_synthetic(512, 128, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec_a = deep_synth_spec(side=side, depth=2, n_classes=n_classes,
                             hidden_hc=8, hidden_mc=16, backend=backend)
    spec_b = deep_synth_spec(side=side, depth=1, n_classes=n_classes,
                             hidden_hc=4, hidden_mc=8, backend=backend)
    tr_a, tr_b = Trainer(spec_a, seed=seed), Trainer(spec_b, seed=seed + 1)
    tr_a.fit(xt, ds.y_train, epochs=epochs, batch=64)
    tr_b.fit(xt, ds.y_train, epochs=epochs, batch=64)
    svc = BCPNNService.multi(
        {"major": (tr_a.state, spec_a), "minor": (tr_b.state, spec_b)},
        max_batch=max_batch)
    svc.warmup()
    rows = []
    for rate in rates:
        for slot in ("major", "minor"):
            svc._slots[slot].metrics = ServeMetrics()
        svc.start(warmup=False)
        r_major = rate * skew / (skew + 1.0)
        r_minor = rate / (skew + 1.0)
        reports = run_multi_open_loop(
            svc,
            {"major": StreamSpec(xe, ds.y_test, rate_hz=r_major),
             "minor": StreamSpec(xe, ds.y_test, rate_hz=r_minor)},
            n_requests=requests, seed=seed)
        svc.stop()
        snap = svc.snapshot()
        total = max(1.0, snap["completed"])
        for name in ("major", "minor"):
            per = snap["per_model"][name]
            arrival_share = len(reports[name].results) / total
            completion_share = per["completed"] / total
            row = {
                "model": name,
                "offered_hz": rate,
                "images_per_s": per["images_per_s"],
                "p50_ms": per["p50_ms"],
                "p99_ms": per["p99_ms"],
                "batch_occupancy": per["batch_occupancy"],
                "completion_share": completion_share,
                "fairness_ratio": (completion_share / arrival_share
                                   if arrival_share else 0.0),
                "max_latency_ms": reports[name].max_latency_ms,
            }
            rows.append(row)
            if csv:
                tag = f"serve_multi_{name}_r{rate:g}"
                print(f"{tag},{row['images_per_s']:.1f},images_per_s")
                print(f"{tag},{row['p99_ms']:.2f},p99_ms")
                print(f"{tag},{row['fairness_ratio']:.3f},fairness_ratio")
    return rows


def bench_infer_dtype(dtypes=("fp32", "bf16", "int8"), rate: float = 1e5,
                      side: int = 8, n_classes: int = 4,
                      requests: int = 128, max_batch: int = 16,
                      epochs: int = 6, seed: int = 0, csv: bool = True):
    """One compact-patchy checkpoint served under each serving dtype:
    the engine packs (casts / per-HC-quantizes) the SAME fp32 state at
    registration, so the sweep isolates the packed-forward cost and the
    served-accuracy delta of the dtype.  Uses the same dataset size as
    ``run.py --assert-quant-accuracy`` so the served model is well above
    chance and the delta is informative."""
    ds = make_synthetic(768, 256, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=side, depth=1, n_classes=n_classes,
                           hidden_hc=8, hidden_mc=16,
                           nact=[max(2, side * side // 2)],
                           patchy_traces=True, compact=True,
                           struct_every=25, backend="pallas")
    tr = Trainer(spec, seed=seed)
    tr.fit(xt, ds.y_train, epochs=epochs, batch=64)
    rows = []
    base_acc = None
    for dt in dtypes:
        svc = BCPNNService(tr.state, spec, max_batch=max_batch,
                           infer_dtype=dt).start()
        rep = run_open_loop(svc, xe, ds.y_test, n_requests=requests,
                            rate_hz=rate, seed=seed)
        svc.stop()
        snap = svc.snapshot()
        acc = rep.accuracy()
        if dt == "fp32":
            base_acc = acc
        row = {
            "infer_dtype": dt,
            "offered_hz": rate,
            "images_per_s": snap["images_per_s"],
            "p50_ms": snap["p50_ms"],
            "p99_ms": snap["p99_ms"],
            "batch_occupancy": snap["batch_occupancy"],
            "served_accuracy": acc,
            "accuracy_delta_pp": ((base_acc - acc) * 100
                                  if base_acc is not None else 0.0),
        }
        rows.append(row)
        if csv:
            tag = f"serve_dtype_{dt}"
            print(f"{tag},{row['images_per_s']:.1f},images_per_s")
            print(f"{tag},{row['p99_ms']:.2f},p99_ms")
            print(f"{tag},{acc*100:.1f},served_accuracy_pct")
            print(f"{tag},{row['accuracy_delta_pp']:.2f},acc_delta_pp")
    return rows


def bench_overload(side: int = 8, n_classes: int = 4,
                   requests: int = 256, max_batch: int = 16,
                   epochs: int = 2, seed: int = 0,
                   backend: str = "pallas", max_queue: int = 32,
                   deadline_ms: float = 250.0, csv: bool = True):
    """Overload scenario (DESIGN.md §10): measure capacity with an
    unbounded queue, then offer ~2x that rate against a BOUNDED engine
    (``max_queue`` + per-request deadline) and record how the excess is
    turned away — rejected at admission, shed at dequeue — and the p99
    of what was actually served.  The point of the row: under 2x
    saturation a bounded engine keeps served p99 near the deadline
    instead of letting queueing latency grow without bound, at the cost
    of an explicit shed/reject rate."""
    ds = make_synthetic(512, 128, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=side, depth=2, n_classes=n_classes,
                           hidden_hc=8, hidden_mc=16, backend=backend)
    tr = Trainer(spec, seed=seed)
    tr.fit(xt, ds.y_train, epochs=epochs, batch=64)

    # capacity probe: saturating offered rate, no admission bound
    svc = BCPNNService(tr.state, spec, max_batch=max_batch)
    svc.warmup()
    svc.start(warmup=False)
    rep0 = run_open_loop(svc, xe, ds.y_test, n_requests=requests,
                         rate_hz=1e5, seed=seed)
    svc.stop()
    capacity_hz = rep0.achieved_rate_hz
    offered_hz = 2.0 * capacity_hz

    # same checkpoint behind a bounded front at 2x that capacity
    svc = BCPNNService(tr.state, spec, max_batch=max_batch,
                       max_queue=max_queue,
                       default_deadline_s=deadline_ms / 1e3)
    svc.warmup()
    svc.start(warmup=False)
    rep = run_open_loop(svc, xe, ds.y_test, n_requests=requests,
                        rate_hz=offered_hz, seed=seed)
    svc.stop()
    snap = svc.snapshot()
    offered = float(len(rep.results) + len(rep.errors) + rep.n_rejected)
    row = {
        "backend": backend,
        "capacity_hz": capacity_hz,
        "offered_hz": offered_hz,
        "max_queue": max_queue,
        "deadline_ms": deadline_ms,
        "served": len(rep.results),
        "rejected": rep.n_rejected,
        "shed": snap["shed"],
        "rejected_rate": rep.n_rejected / max(offered, 1.0),
        "shed_rate": snap["shed"] / max(offered, 1.0),
        "served_p50_ms": snap["p50_ms"],
        "served_p99_ms": snap["p99_ms"],
        "served_accuracy": rep.accuracy() if rep.results else 0.0,
    }
    if csv:
        tag = "serve_overload_2x"
        print(f"{tag},{row['capacity_hz']:.1f},capacity_hz")
        print(f"{tag},{row['shed_rate']*100:.1f},shed_pct")
        print(f"{tag},{row['rejected_rate']*100:.1f},rejected_pct")
        print(f"{tag},{row['served_p99_ms']:.2f},served_p99_ms")
    return row


def bench_router(n_engines: int = 3, replicas: int = 2, skew: float = 3.0,
                 side: int = 8, n_classes: int = 4, requests: int = 4000,
                 max_batch: int = 16, epochs: int = 2, seed: int = 0,
                 backend: str = "pallas", max_queue: int = 64,
                 deadline_ms: float = 500.0,
                 kill_after_frac: float = 0.3, csv: bool = True):
    """Router chaos scenario (DESIGN.md §11): ``n_engines`` >= 3 behind
    one ``BCPNNRouter``, two models each placed ``replicas``-wide with
    ``skew``:1 weights, a superposed Poisson mix offered at ~10x the
    single-engine capacity, and one replica-hosting engine killed
    ``kill_after_frac`` into the run.  The row records what the failure
    ladder buys: router throughput and served p99 while requests reroute
    around the loss, the per-model fairness ratio under the weighted
    quanta, and the recovery time from loss detection to a replacement
    replica serving."""
    ds = make_synthetic(512, 128, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec_a = deep_synth_spec(side=side, depth=2, n_classes=n_classes,
                             hidden_hc=8, hidden_mc=16, backend=backend)
    spec_b = deep_synth_spec(side=side, depth=1, n_classes=n_classes,
                             hidden_hc=4, hidden_mc=8, backend=backend)
    tr_a, tr_b = Trainer(spec_a, seed=seed), Trainer(spec_b, seed=seed + 1)
    tr_a.fit(xt, ds.y_train, epochs=epochs, batch=64)
    tr_b.fit(xt, ds.y_train, epochs=epochs, batch=64)

    # single-engine capacity probe for the heavier model: the router run
    # is offered ~10x this, so every replica runs saturated and reroutes
    # land on genuinely busy peers
    svc = BCPNNService(tr_a.state, spec_a, max_batch=max_batch)
    svc.warmup()
    svc.start(warmup=False)
    rep0 = run_open_loop(svc, xe, ds.y_test, n_requests=128,
                         rate_hz=1e5, seed=seed)
    svc.stop()
    capacity_hz = rep0.achieved_rate_hz
    offered_hz = 10.0 * capacity_hz

    router = BCPNNRouter.local(n_engines, max_batch=max_batch,
                               max_queue=max_queue)
    router.add_model("major", tr_a.state, spec_a, replicas=replicas,
                     weight=skew)
    router.add_model("minor", tr_b.state, spec_b, replicas=replicas,
                     weight=1.0)
    router.start()
    victim = router.placement("major")["replicas"][0]

    # progress-triggered chaos: the kill lands when kill_after_frac of
    # the offered stream has arrived (submitted + rejected tracks the
    # arrival loop directly), NOT on a wall-clock guess — the run is
    # milliseconds long and a timer would routinely miss it entirely
    run_over = threading.Event()

    def _chaos():
        import time
        target = kill_after_frac * requests
        t_end = time.perf_counter() + 60.0
        while time.perf_counter() < t_end and not run_over.is_set():
            snap = router.metrics.snapshot()
            if snap["submitted"] + snap["rejected"] >= target:
                break
            time.sleep(0.001)
        if run_over.is_set():
            return  # run finished first — do not fake a post-run loss
        try:
            router._engines[victim].kill("bench chaos: engine loss")
        except Exception:
            pass  # engine already down — row still valid

    killer = threading.Thread(target=_chaos, daemon=True)
    killer.start()
    try:
        r_major = offered_hz * skew / (skew + 1.0)
        r_minor = offered_hz / (skew + 1.0)
        reports = run_multi_open_loop(
            router,
            {"major": StreamSpec(xe, ds.y_test, rate_hz=r_major),
             "minor": StreamSpec(xe, ds.y_test, rate_hz=r_minor)},
            n_requests=requests, deadline_s=deadline_ms / 1e3, seed=seed)
    finally:
        run_over.set()
        killer.join()
        router.check_engines()
        router.heal()
        snap = router.metrics.snapshot()
        router.stop()

    wall_s = max(rep.wall_s for rep in reports.values())
    lat_ms = [r.latency_ms for rep in reports.values()
              for r in rep.results]
    served = sum(len(rep.results) for rep in reports.values())
    row = {
        "n_engines": n_engines,
        "replicas": replicas,
        "capacity_hz": capacity_hz,
        "offered_hz": offered_hz,
        "deadline_ms": deadline_ms,
        "served": served,
        "throughput_hz": served / max(wall_s, 1e-9),
        "served_p99_ms": (float(np.percentile(lat_ms, 99))
                          if lat_ms else 0.0),
        "reroutes": snap["reroutes"],
        "engine_losses": snap["engine_losses"],
        "replacements": snap["replacements"],
        "recovery_s": snap.get("recovery_s_max", 0.0),
    }
    for name in ("major", "minor"):
        rep = reports[name]
        offered = len(rep.results) + len(rep.errors) + rep.n_rejected
        total_offered = sum(len(r.results) + len(r.errors) + r.n_rejected
                            for r in reports.values())
        arrival_share = offered / max(total_offered, 1)
        completion_share = len(rep.results) / max(served, 1)
        row[f"fairness_ratio_{name}"] = (completion_share / arrival_share
                                         if arrival_share else 0.0)
    if csv:
        tag = "serve_router_chaos"
        print(f"{tag},{row['throughput_hz']:.1f},images_per_s")
        print(f"{tag},{row['served_p99_ms']:.2f},served_p99_ms")
        print(f"{tag},{row['fairness_ratio_minor']:.3f},"
              f"fairness_ratio_minor")
        print(f"{tag},{row['recovery_s']*1e3:.1f},recovery_ms")
        print(f"{tag},{row['engine_losses']:.0f},engine_losses")
    return row


def run(csv=True, json_path="BENCH_serve.json", rates=(200.0, 1e5),
        backends=("jnp", "pallas"), requests=128,
        multi_rates=(400.0, 1e5), dtypes=("fp32", "bf16", "int8")):
    rows = []
    for backend in backends:
        rows += bench_backend(backend, rates, requests=requests, csv=csv)
    multi_rows = bench_multi_model(rates=multi_rates,
                                   requests=max(requests, 256), csv=csv)
    dtype_rows = bench_infer_dtype(dtypes=dtypes, requests=requests,
                                   csv=csv)
    overload_row = bench_overload(requests=max(requests, 256), csv=csv)
    router_row = bench_router(csv=csv)
    summary = {"rows": rows, "multi_model": multi_rows,
               "infer_dtype": dtype_rows,
               "overload": overload_row,
               "router": router_row,
               "device": jax.default_backend()}
    if csv:
        print("bench_serve_json=" + json.dumps(summary))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="write the JSON summary to this path "
                         "('' disables)")
    ap.add_argument("--rates", default="200,100000",
                    help="comma-separated offered rates (req/s)")
    ap.add_argument("--multi-rates", default="400,100000",
                    help="combined offered rates for the 10:1 "
                         "multi-model sweep")
    ap.add_argument("--backends", default="jnp,pallas")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--infer-dtype", default="fp32,bf16,int8",
                    help="comma-separated serving dtypes for the "
                         "precision sweep")
    args = ap.parse_args()
    run(json_path=args.json or None,
        rates=tuple(float(r) for r in args.rates.split(",")),
        backends=tuple(args.backends.split(",")),
        requests=args.requests,
        multi_rates=tuple(float(r) for r in args.multi_rates.split(",")),
        dtypes=tuple(args.infer_dtype.split(",")))
