"""Serving benchmark: offered-load sweep through the microbatched engine.

For each backend (jnp reference, fused Pallas dispatch) and each offered
arrival rate, drives the open-loop generator through ``BCPNNService`` and
records achieved images/s, p50/p99 latency and batch occupancy — the
serving-side perf trajectory (the training side records via
bench_stream_vs_seq).  A very high offered rate measures capacity (the
admission queue saturates and microbatches run back-to-back at the
largest bucket); a moderate rate measures latency at sustainable load.

Output: ``name,value,unit`` CSV rows, one machine-readable
``bench_serve_json={...}`` line, and an optional ``--json PATH`` dump.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import Trainer
from repro.data.synthetic import encode_images, make_synthetic
from repro.serve import BCPNNService, ServeMetrics, run_open_loop


def bench_backend(backend: str, rates, depth: int = 2, side: int = 8,
                  n_classes: int = 4, requests: int = 128,
                  max_batch: int = 16, epochs: int = 2, seed: int = 0,
                  csv: bool = True):
    ds = make_synthetic(512, 128, side, n_classes, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=side, depth=depth, n_classes=n_classes,
                           hidden_hc=8, hidden_mc=16, backend=backend)
    tr = Trainer(spec, seed=seed)
    tr.fit(xt, ds.y_train, epochs=epochs, batch=64)

    # One service per backend, reused across rates: the per-instance jit
    # cache keeps every bucket shape compiled once (a per-rate instance
    # would pay the whole warmup again), with fresh metrics per run.
    svc = BCPNNService(tr.state, spec, max_batch=max_batch)
    svc.warmup()
    rows = []
    for rate in rates:
        svc.metrics = ServeMetrics()
        svc.start(warmup=False)
        rep = run_open_loop(svc, xe, ds.y_test, n_requests=requests,
                            rate_hz=rate, seed=seed)
        svc.stop()
        snap = svc.snapshot()
        row = {
            "backend": backend,
            "depth": depth,
            "offered_hz": rate,
            "achieved_hz": rep.achieved_rate_hz,
            "images_per_s": snap["images_per_s"],
            "p50_ms": snap["p50_ms"],
            "p99_ms": snap["p99_ms"],
            "batch_occupancy": snap["batch_occupancy"],
            "served_accuracy": rep.accuracy(),
        }
        rows.append(row)
        if csv:
            tag = f"serve_{backend}_d{depth}_r{rate:g}"
            print(f"{tag},{row['images_per_s']:.1f},images_per_s")
            print(f"{tag},{row['p50_ms']:.2f},p50_ms")
            print(f"{tag},{row['p99_ms']:.2f},p99_ms")
            print(f"{tag},{row['batch_occupancy']*100:.0f},occupancy_pct")
    return rows


def run(csv=True, json_path=None, rates=(200.0, 1e5),
        backends=("jnp", "pallas"), requests=128):
    rows = []
    for backend in backends:
        rows += bench_backend(backend, rates, requests=requests, csv=csv)
    summary = {"rows": rows, "device": jax.default_backend()}
    if csv:
        print("bench_serve_json=" + json.dumps(summary))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the JSON summary to this path")
    ap.add_argument("--rates", default="200,100000",
                    help="comma-separated offered rates (req/s)")
    ap.add_argument("--backends", default="jnp,pallas")
    ap.add_argument("--requests", type=int, default=128)
    args = ap.parse_args()
    run(json_path=args.json,
        rates=tuple(float(r) for r in args.rates.split(",")),
        backends=tuple(args.backends.split(",")),
        requests=args.requests)
