"""Deep-stack engine + backend dispatch tests: the fused Pallas path must
be a drop-in for the jnp reference at every depth, and a deep network must
train end-to-end through the layerwise greedy protocol."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import (
    BCPNNConfig,
    LayerGeom,
    NetworkSpec,
    ProjSpec,
    Trainer,
    forward,
    infer,
    init_deep,
    init_projection,
    learn,
    make_network_spec,
    supervised_readout_step,
    unsupervised_layer_step,
)
from repro.core.bcpnn_layer import Projection, rewire, topk_mask
from repro.core.traces import init_traces, weights_from_traces
from repro.data.synthetic import encode_images, make_synthetic


def _spec_pair(**kw):
    """(jnp, pallas) variants of the same spec."""
    spec = make_network_spec(**kw)
    return spec, spec.with_backend("pallas")


# ------------------------------------------------------------- dispatch --

def test_projspec_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        ProjSpec(LayerGeom(4, 2), LayerGeom(2, 4), backend="cuda")


@pytest.mark.parametrize("nact", [None, 5])
def test_backend_parity_forward_and_multistep_learn(nact):
    """Dispatch parity on one projection: forward + 5 chained learn steps
    (weights feed back into the next forward), dense and patchy."""
    spec_j = ProjSpec(LayerGeom(17, 2), LayerGeom(6, 16), alpha=1e-2,
                      nact=nact, backend="jnp")
    spec_p = spec_j.with_backend("pallas")
    proj_j = init_projection(spec_j, jax.random.PRNGKey(0))
    proj_p = jax.tree.map(jnp.array, proj_j)
    keys = jax.random.split(jax.random.PRNGKey(1), 5)
    for k in keys:
        x = jax.random.uniform(k, (32, spec_j.pre.N))
        h_j = forward(proj_j, spec_j, x)
        h_p = forward(proj_p, spec_p, x)
        np.testing.assert_allclose(np.asarray(h_p), np.asarray(h_j),
                                   atol=1e-5)
        proj_j = learn(proj_j, spec_j, x, h_j)
        proj_p = learn(proj_p, spec_p, x, h_j)
        np.testing.assert_allclose(np.asarray(proj_p.traces.pij),
                                   np.asarray(proj_j.traces.pij), atol=1e-6)
        np.testing.assert_allclose(np.asarray(proj_p.w),
                                   np.asarray(proj_j.w), atol=1e-4)
    if nact is not None:  # the patchy mask must actually mask
        assert float(jnp.sum(proj_p.mask)) == nact * spec_j.post.H
        dead = np.asarray(proj_p.w)[np.repeat(
            np.asarray(proj_p.mask) == 0, spec_j.pre.M, axis=0).repeat(
                spec_j.post.M, axis=1)]
        np.testing.assert_array_equal(dead, 0.0)


def test_backend_parity_deep_stack_protocol():
    """Full protocol parity on a 2-hidden-layer stack: layerwise greedy
    unsupervised steps, one supervised step, inference."""
    spec_j, spec_p = _spec_pair(
        input_geom=LayerGeom(18, 2), hidden=[(4, 8), (4, 8)], n_classes=3,
        alpha=1e-2, nact=[9, None], support_noise=2.0, noise_steps=50)
    ks = jax.random.split(jax.random.PRNGKey(2), 7)
    xs = [jax.random.uniform(k, (16, 36)) for k in ks[:6]]
    labels = jax.random.randint(ks[6], (16,), 0, 3)

    def run(spec):
        state = init_deep(spec, jax.random.PRNGKey(0))
        for layer in range(spec.depth):
            for x in xs[layer * 3:(layer + 1) * 3]:
                state = unsupervised_layer_step(state, spec, x, layer)
        state = supervised_readout_step(state, spec, xs[0], labels)
        probs, pred = infer(state, spec, xs[1])
        return state, probs, pred

    st_j, probs_j, pred_j = run(spec_j)
    st_p, probs_p, pred_p = run(spec_p)
    np.testing.assert_allclose(np.asarray(probs_p), np.asarray(probs_j),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(pred_p), np.asarray(pred_j))
    for a, b in zip(jax.tree.leaves(st_j), jax.tree.leaves(st_p)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)


# ------------------------------------------------- exact-nact mask budget --

def test_rewire_exact_nact_with_tied_mi():
    """Regression: early in training many HC pairs share identical ~0 MI;
    a threshold mask admitted every tie and blew the nact budget.  With
    noiseless uniform traces ALL MI scores tie, the worst case."""
    spec = ProjSpec(LayerGeom(10, 2), LayerGeom(5, 4), nact=3)
    tr = init_traces(spec.pre.N, spec.post.N, 2, 4)  # no key -> exact ties
    w, b = weights_from_traces(tr)
    proj = Projection(traces=tr, w=w, b=b,
                      mask=jnp.ones((10, 5), jnp.float32))
    out = rewire(proj, spec)
    np.testing.assert_array_equal(np.asarray(out.mask).sum(0), 3.0)
    # the masked weights honor the shrunk mask
    dead = np.asarray(out.w)[np.repeat(np.asarray(out.mask) == 0, 2, axis=0)
                             .repeat(4, axis=1)]
    np.testing.assert_array_equal(dead, 0.0)


@pytest.mark.parametrize("k", [1, 3, 7])
def test_topk_mask_always_exact(k):
    for seed in range(5):
        scores = jax.random.normal(jax.random.PRNGKey(seed), (7, 4))
        # quantize to force frequent ties
        scores = jnp.round(scores)
        m = topk_mask(scores, k)
        np.testing.assert_array_equal(np.asarray(m).sum(0), float(k))
        assert set(np.unique(np.asarray(m))) <= {0.0, 1.0}


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_patchy_budget_exact_under_learn_and_rewire(backend):
    """The connectivity budget is exactly min(nact, H_pre) per post-HC at
    init, after chained learn steps, and after rewire — on both backends."""
    spec = ProjSpec(LayerGeom(9, 2), LayerGeom(4, 8), alpha=0.1, nact=5,
                    backend=backend)
    proj = init_projection(spec, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(proj.mask).sum(0), 5.0)
    for k in jax.random.split(jax.random.PRNGKey(1), 3):
        x = jax.random.uniform(k, (8, spec.pre.N))
        h = forward(proj, spec, x)
        proj = learn(proj, spec, x, h)
        np.testing.assert_array_equal(np.asarray(proj.mask).sum(0), 5.0)
    out = rewire(proj, spec)
    np.testing.assert_array_equal(np.asarray(out.mask).sum(0), 5.0)
    # nact >= H_pre degenerates to dense = min(nact, H_pre) active
    dense_spec = ProjSpec(LayerGeom(4, 2), LayerGeom(2, 4), nact=9)
    dense = init_projection(dense_spec, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(dense.mask).sum(0), 4.0)


# ----------------------------------------------------------- deep engine --

def test_network_spec_validates_population_chain():
    good = ProjSpec(LayerGeom(4, 2), LayerGeom(2, 4))
    bad = ProjSpec(LayerGeom(3, 3), LayerGeom(2, 4))
    with pytest.raises(ValueError, match="population mismatch"):
        NetworkSpec(projs=(good, bad), readout=ProjSpec(LayerGeom(2, 4),
                                                        LayerGeom(1, 3)))
    with pytest.raises(ValueError, match="readout"):
        NetworkSpec(projs=(good,), readout=ProjSpec(LayerGeom(9, 9),
                                                    LayerGeom(1, 3)))


def test_legacy_config_is_depth1_preset():
    cfg = BCPNNConfig(input_hc=8, input_mc=2, hidden_hc=2, hidden_mc=4,
                      n_classes=3, nact_hi=8)
    spec = cfg.network_spec()
    assert spec.depth == 1 and spec.n_classes == 3
    state = init_deep(spec, jax.random.PRNGKey(0))
    assert state.ih is state.projs[0] and state.ho is state.readout


def test_deep_unsupervised_step_freezes_other_layers():
    spec = deep_synth_spec(side=4, depth=2, n_classes=3, hidden_hc=2,
                           hidden_mc=8)
    state = init_deep(spec, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, spec.input_geom.N))
    new = unsupervised_layer_step(state, spec, x, layer=1)
    np.testing.assert_array_equal(np.asarray(new.projs[0].w),
                                  np.asarray(state.projs[0].w))
    np.testing.assert_array_equal(np.asarray(new.readout.w),
                                  np.asarray(state.readout.w))
    assert not np.allclose(np.asarray(new.projs[1].w),
                           np.asarray(state.projs[1].w))


def test_deep_network_learns_end_to_end_pallas_default():
    """Acceptance: a >=2-hidden-layer stack, layerwise unsupervised + one
    supervised pass, beats chance on the synthetic task — with the fused
    Pallas kernels as the default hot path (backend="pallas" on every
    projection)."""
    ds = make_synthetic(768, 256, 8, 4, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=8, depth=2, n_classes=4, hidden_hc=8,
                           hidden_mc=16, backend="pallas")
    assert all(p.backend == "pallas" for p in spec.projs)
    assert spec.readout.backend == "pallas"
    tr = Trainer(spec, seed=0)
    tr.fit(xt, ds.y_train, epochs=6, batch=64)
    acc = tr.evaluate(xe, ds.y_test, batch=64)
    assert acc > 0.5, f"deep pallas stack should beat chance (0.25): {acc}"


def test_deep_state_checkpoint_roundtrip(tmp_path):
    spec = deep_synth_spec(side=4, depth=2, n_classes=3, hidden_hc=2,
                           hidden_mc=8)
    tr = Trainer(spec, seed=0)
    x = np.random.default_rng(0).uniform(size=(64, spec.input_geom.N)) \
        .astype(np.float32)
    y = np.zeros((64,), np.int32)
    tr.fit(x, y, epochs=1, batch=32)
    tr.save(str(tmp_path), step=7)
    tr2 = Trainer(spec, seed=1)
    assert tr2.restore(str(tmp_path)) == 7
    for a, b in zip(jax.tree.leaves(tr.state), jax.tree.leaves(tr2.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # depth mismatch must fail loudly, not garble state
    tr3 = Trainer(deep_synth_spec(side=4, depth=3, n_classes=3, hidden_hc=2,
                                  hidden_mc=8), seed=0)
    with pytest.raises(ValueError, match="missing leaves"):
        tr3.restore(str(tmp_path))


def test_projection_shardings_place_deep_state():
    from repro.distributed.sharding import (
        make_rules, projection_shardings, sharding_context)
    spec = deep_synth_spec(side=4, depth=2, n_classes=3, hidden_hc=2,
                           hidden_mc=8)
    state = init_deep(spec, jax.random.PRNGKey(0))
    assert projection_shardings(state) is None  # no mesh -> no-op
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, make_rules(mesh)), mesh:
        shardings = projection_shardings(state)
        placed = jax.tree.map(jax.device_put, state, shardings)
    np.testing.assert_array_equal(np.asarray(placed.projs[1].w),
                                  np.asarray(state.projs[1].w))
