"""Low-precision serving (DESIGN.md §8): bf16 cast-on-fold and the int8
per-hypercolumn-quantized kernels, against the fp32 reference path.

Learning state is always fp32 — precision only enters through the packed
inference view (``InferPack``/``InferParams``), derived at fold
boundaries.  These tests pin: kernel-level parity on the three Table-1
model geometries and hostile shapes, pad-HC NaN safety, the
quantize→dequantize error bound, checkpoint round-trip of the
``infer_dtype`` tag, table memoization across folds, and the serving
engine's fold-boundary requantization (stale-scale regression).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bcpnn_layer import INFER_DTYPES, ProjSpec
from repro.core.compact import cached_table
from repro.core.hypercolumns import LayerGeom
from repro.core.network import (
    BCPNNConfig, infer, infer_packed, init_network, online_learn_step,
    pack_state, spec_from_dict, spec_to_dict,
)
from repro.kernels import (
    dequantize_compact, dequantize_dense, quant_compact_forward,
    quant_fwd_pallas, quantize_compact, quantize_dense, ref_bcpnn_fwd,
)
from repro.kernels.quant import (
    quant_support_compact_jnp, quant_support_dense_jnp,
)
from repro.kernels.ops import hc_softmax


def _net(backend="pallas", compact=True, infer_dtype="fp32", **kw):
    cfg = BCPNNConfig(input_hc=kw.pop("input_hc", 16), input_mc=2,
                      hidden_hc=kw.pop("hidden_hc", 4),
                      hidden_mc=kw.pop("hidden_mc", 8),
                      n_classes=kw.pop("n_classes", 4),
                      nact_hi=kw.pop("nact_hi", 6), backend=backend,
                      patchy_traces=compact, compact=compact,
                      infer_dtype=infer_dtype, **kw)
    spec = cfg.network_spec()
    state = init_network(spec, jax.random.PRNGKey(0))
    return state, spec


def _learned(state, spec, steps=5, b=16, seed=0):
    rng = np.random.default_rng(seed)
    ni = spec.projs[0].pre.N
    x = rng.random((b, ni)).astype(np.float32)
    y = rng.integers(0, spec.n_classes, b).astype(np.int32)
    for _ in range(steps):
        state = online_learn_step(state, spec, jnp.asarray(x),
                                  jnp.asarray(y))
    return state, x


# --------------------------------------------------- kernel-level parity --

# The paper's three Table-1 geometries (full Ni x Nj weight panes, small
# batch) + hostile shapes: prime batch, odd minicolumn counts, an
# all-pad-HC block (7 HCs x 10 MCs pads to 8 HCs x 16 lanes).
GEOMETRIES = [
    (8, 1568, 32, 128),    # Model 1 (MNIST): 784x2 -> 32x128
    (8, 1568, 32, 256),    # Model 2 (pneumonia): 784x2 -> 32x256
    (8, 8192, 32, 128),    # Model 3 (breast): 4096x2 -> 32x128
    (13, 33, 7, 10),       # hostile: prime batch, pad rows/lanes/HCs
    (1, 5, 1, 2),          # degenerate toy
]


@pytest.mark.parametrize("b,ni,hj,mj", GEOMETRIES)
def test_quant_fwd_matches_jnp_ref(b, ni, hj, mj):
    """Padded-dense int8 kernel == jnp fixed-point reference (same codes,
    same scales — only the schedule differs), finite through pad HCs."""
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.uniform(k[0], (b, ni))
    w = jax.random.normal(k[1], (ni, hj * mj)) * 0.5
    bias = jax.random.normal(k[2], (hj * mj,))
    w_q, scale = quantize_dense(w, hj, mj)
    ref = hc_softmax(quant_support_dense_jnp(x, w_q, scale, bias, hj, mj),
                     hj, mj, 1.0)
    got = quant_fwd_pallas(x, w_q, bias, scale, hj, mj, 1.0, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("b,ni,hj,mj", GEOMETRIES)
def test_quant_fwd_close_to_fp32(b, ni, hj, mj):
    """Quantized forward tracks the fp32 kernel: probabilities within the
    per-HC quantization tolerance on every geometry."""
    k = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.uniform(k[0], (b, ni))
    w = jax.random.normal(k[1], (ni, hj * mj)) * 0.1
    bias = jax.random.normal(k[2], (hj * mj,))
    w_q, scale = quantize_dense(w, hj, mj)
    got = quant_fwd_pallas(x, w_q, bias, scale, hj, mj, 1.0, interpret=True)
    want = ref_bcpnn_fwd(x, w, bias, hj, mj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-2)


def test_quant_compact_forward_matches_ref():
    """Compact-resident int8 kernel == jnp fixed-point reference on a
    hostile patchy geometry."""
    hi, mi, hj, mj, nact, b = 11, 3, 5, 10, 4, 13
    k = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.uniform(k[0], (b, hi * mi))
    w_c = jax.random.normal(k[1], (hj, nact * mi, mj)) * 0.5
    bias = jax.random.normal(k[2], (hj * mj,))
    # exactly-nact mask -> persistent index table
    mask = np.zeros((hi, hj), np.float32)
    rng = np.random.default_rng(0)
    for j in range(hj):
        mask[rng.choice(hi, nact, replace=False), j] = 1.0
    table = cached_table(jnp.asarray(mask), nact)
    w_q, scale = quantize_compact(w_c)
    ref = hc_softmax(
        quant_support_compact_jnp(x, w_q, scale, bias, table, mi),
        hj, mj, 1.0)
    got = quant_compact_forward(x, w_q, bias, scale, table, mi, 1.0,
                                interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_quantize_dequantize_round_trip_bound():
    """Per-post-HC symmetric int8: |w - dq(q(w))| <= scale_j / 2 element-
    wise, and the absmax element of every HC is exactly representable."""
    k = jax.random.PRNGKey(4)
    hj, mj, ni = 6, 10, 40
    w = jax.random.normal(k, (ni, hj * mj)) * 2.0
    w_q, scale = quantize_dense(w, hj, mj)
    assert w_q.dtype == jnp.int8 and scale.shape == (hj,)
    wd = np.asarray(dequantize_dense(w_q, scale, hj, mj))
    bound = np.repeat(np.asarray(scale), mj)[None, :] / 2 + 1e-6
    assert np.all(np.abs(wd - np.asarray(w)) <= bound)
    # compact layout: same contract on (Hj, K, Mj)
    w_c = jax.random.normal(k, (hj, 12, mj)) * 2.0
    cq, cs = quantize_compact(w_c)
    cd = np.asarray(dequantize_compact(cq, cs))
    assert np.all(np.abs(cd - np.asarray(w_c))
                  <= np.asarray(cs)[:, None, None] / 2 + 1e-6)


# ----------------------------------------------- network-level parity ----

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("compact", [True, False])
@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_low_precision_infer_tracks_fp32(backend, compact, dtype):
    """End-to-end ``infer`` under a low-precision spec: probabilities
    within quantization tolerance of fp32, NaN-free, on both backends and
    both patchy layouts (compact-resident and dense-resident)."""
    state, spec = _net(backend=backend, compact=compact)
    state, x = _learned(state, spec)
    p32, _ = infer(state, spec, jnp.asarray(x))
    p, _ = infer(state, spec.with_infer_dtype(dtype), jnp.asarray(x))
    assert np.isfinite(np.asarray(p)).all()
    np.testing.assert_allclose(np.asarray(p), np.asarray(p32), atol=5e-2)


def test_fp32_pack_aliases_state_and_matches_infer():
    """All-fp32 packs are free (alias the state's arrays) and the packed
    path is bit-identical to ``infer`` — the serving engine can route
    every dtype through ``infer_packed`` without perturbing fp32."""
    state, spec = _net()
    state, x = _learned(state, spec)
    params = pack_state(state, spec)
    assert params.projs[0].w is state.projs[0].w
    assert params.readout.b is state.readout.b
    p_ref, pred_ref = infer(state, spec, jnp.asarray(x))
    p, pred = infer_packed(params, spec, jnp.asarray(x))
    assert np.array_equal(np.asarray(p), np.asarray(p_ref))
    assert np.array_equal(np.asarray(pred), np.asarray(pred_ref))


def test_valid_mask_semantics_survive_quantization():
    """Padded-batch masking under int8: pad rows stay inert (probs 0,
    pred -1) exactly as in fp32."""
    state, spec = _net()
    state, x = _learned(state, spec)
    sp = spec.with_infer_dtype("int8")
    valid = jnp.asarray([1.0] * 8 + [0.0] * 8)
    probs, pred = infer(state, sp, jnp.asarray(x), valid=valid)
    assert np.all(np.asarray(probs)[8:] == 0.0)
    assert np.all(np.asarray(pred)[8:] == -1)
    assert np.isfinite(np.asarray(probs)).all()


def test_infer_dtype_validation():
    with pytest.raises(ValueError, match="infer_dtype"):
        ProjSpec(LayerGeom(4, 2), LayerGeom(2, 4), infer_dtype="fp16")
    for dt in INFER_DTYPES:
        ProjSpec(LayerGeom(4, 2), LayerGeom(2, 4), infer_dtype=dt)


def test_spec_infer_dtype_roundtrips_serialization():
    """The infer_dtype tag rides the checkpoint manifest; manifests from
    before the field existed (no key) default to fp32."""
    _, spec = _net(infer_dtype="int8")
    spec2 = spec_from_dict(spec_to_dict(spec))
    assert spec2 == spec
    assert all(p.infer_dtype == "int8" for p in spec2.projs)
    d = spec_to_dict(spec)
    for p in d["projs"] + [d["readout"]]:
        p.pop("infer_dtype")
    old = spec_from_dict(d)
    assert all(p.infer_dtype == "fp32" for p in old.projs)


# ------------------------------------------------- table memoization ----

def test_cached_table_survives_fold_rebuilds_on_rewire():
    """Satellite contract: a learn fold returns a NEW mask buffer with
    unchanged values — the (Hj, nact) index table must be reused, not
    rebuilt; only an actual rewire (content change) rebuilds it."""
    mask = np.zeros((8, 3), np.float32)
    rng = np.random.default_rng(1)
    for j in range(3):
        mask[rng.choice(8, 4, replace=False), j] = 1.0
    m1 = jnp.asarray(mask)
    t1 = cached_table(m1, 4)
    m2 = jnp.array(m1)               # new buffer, same values (a fold)
    assert m2 is not m1
    assert cached_table(m2, 4) is t1  # content-level hit
    mask2 = mask.copy()               # a rewire: move one connection
    j = 0
    on = np.flatnonzero(mask2[:, j] > 0)
    off = np.flatnonzero(mask2[:, j] == 0)
    mask2[on[0], j], mask2[off[0], j] = 0.0, 1.0
    t2 = cached_table(jnp.asarray(mask2), 4)
    assert t2 is not t1
    assert not np.array_equal(np.asarray(t2), np.asarray(t1))


def test_cached_table_never_returns_a_deleted_buffer():
    """Donation regression: Trainer's train steps donate the state, so a
    cached table's buffer can be consumed (deleted) after it was handed
    out as a compact-state leaf.  Both cache levels must rebuild instead
    of serving the dead array (seen as ``Array has been deleted`` from
    ``compactify_projection`` in the serve CLI smoke)."""
    mask = np.zeros((8, 3), np.float32)
    rng = np.random.default_rng(2)
    for j in range(3):
        mask[rng.choice(8, 4, replace=False), j] = 1.0
    m1 = jnp.asarray(mask)
    t1 = cached_table(m1, 4)
    expect = np.asarray(t1).copy()
    t1.delete()                       # what a donating jit does
    t_again = cached_table(m1, 4)     # identity-level hit path
    assert not t_again.is_deleted()
    np.testing.assert_array_equal(np.asarray(t_again), expect)
    t_again.delete()
    m2 = jnp.array(m1)                # content-level hit path
    t_content = cached_table(m2, 4)
    assert not t_content.is_deleted()
    np.testing.assert_array_equal(np.asarray(t_content), expect)


# --------------------------------------------------- serving engine ----

def test_serve_requantizes_at_fold_boundaries():
    """Stale-scale regression: after online-learning folds (including a
    struct_every rewire inside learn_fn), the slot's packed int8 weights
    must equal a FRESH quantization of the post-fold state — never the
    registration-time codes/scales."""
    from repro.serve.engine import BCPNNService

    cfg = BCPNNConfig(input_hc=16, input_mc=2, hidden_hc=4, hidden_mc=8,
                      n_classes=4, nact_hi=6, backend="pallas",
                      patchy_traces=True, compact=True, struct_every=2)
    spec = cfg.network_spec()
    state = init_network(spec, jax.random.PRNGKey(0))
    state, x = _learned(state, spec, steps=2)
    y = np.random.default_rng(2).integers(0, 4, len(x)).astype(np.int32)
    svc = BCPNNService(state, spec, online_learning=True, learn_stack=True,
                       feedback_batch=4, infer_dtype="int8",
                       max_batch=8).start()
    pack0 = svc.model_pack()
    assert pack0.readout.w.dtype == jnp.int8
    r = svc.classify(x[0])
    assert np.isfinite(r.probs).all()
    for i in range(12):               # crosses struct_every boundaries
        svc.feedback(x[i % len(x)], int(y[i % len(y)]))
    svc.stop()
    st1, pack1 = svc.model_state(), svc.model_pack()
    wq, sc = quantize_compact(st1.projs[0].w)
    assert np.array_equal(np.asarray(pack1.projs[0].w), np.asarray(wq))
    assert np.array_equal(np.asarray(pack1.projs[0].scale), np.asarray(sc))
    rq, rs = quantize_dense(st1.readout.w, spec.readout.post.H,
                            spec.readout.post.M)
    assert np.array_equal(np.asarray(pack1.readout.w), np.asarray(rq))
    assert np.array_equal(np.asarray(pack1.readout.scale), np.asarray(rs))
    # the folds really moved the readout (the regression is only
    # meaningful if a stale pack WOULD have differed)
    assert not np.array_equal(np.asarray(pack1.readout.w),
                              np.asarray(pack0.readout.w))
    # the rewire moved the mask -> the pack's table tracked it
    assert np.array_equal(
        np.asarray(pack1.projs[0].table),
        np.asarray(cached_table(st1.projs[0].mask, spec.projs[0].nact)))


def test_serve_infer_dtype_validation():
    from repro.serve.engine import BCPNNService

    state, spec = _net()
    with pytest.raises(ValueError, match="infer_dtype"):
        BCPNNService(state, spec, infer_dtype="fp16")


# -------------------------------------------------- roofline traffic ----

def test_roofline_dtype_traffic_ordering():
    """Modeled arithmetic intensity must rise with narrower weights, and
    the int8 model must account for its f32 scale vector."""
    from repro.launch.roofline import bcpnn_fwd_traffic, dtype_bytes

    assert dtype_bytes("fp32") == 4 and dtype_bytes("bf16") == 2
    assert dtype_bytes("int8") == 1 and dtype_bytes("f32") == 4
    with pytest.raises(ValueError, match="unknown dtype"):
        dtype_bytes("q4")
    args = dict(batch=64, n_in=1568, n_out=4096, n_hc=32)
    t32 = bcpnn_fwd_traffic(**args, weight_dtype="fp32")
    t16 = bcpnn_fwd_traffic(**args, weight_dtype="bf16")
    t8 = bcpnn_fwd_traffic(**args, weight_dtype="int8")
    assert t32["intensity"] < t16["intensity"] < t8["intensity"]
    assert t32["flops"] == t16["flops"] == t8["flops"]
    t8_nh = bcpnn_fwd_traffic(**{**args, "n_hc": 1}, weight_dtype="int8")
    assert t8["bytes"] - t8_nh["bytes"] == pytest.approx(4 * 31)
