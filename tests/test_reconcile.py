"""Replica-reconciliation tests (serve/reconcile.py): the generalized
disjoint-support merge's unit contracts, the broadcast-replica protocol
against a single reference engine (bit-exact), and the hypothesis
property (CI installs hypothesis; skipped where it is absent) that
merging replicas of any served feedback prefix is bit-identical to
serving the interleaved stream on one engine with
``feedback_eager=False``."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import init_deep, supervised_readout_step
from repro.serve import (
    BCPNNService, chunk_bounds, cycle_batch, merge_replica_states,
    state_divergence, state_finite, states_bitwise_equal,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # local runs without the optional dep; CI has it
    given = None


@functools.lru_cache(maxsize=None)
def _net():
    spec = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                           hidden_mc=8, backend="jnp")
    return spec, init_deep(spec, jax.random.PRNGKey(0))


def _stream(spec, n, seed):
    rng = np.random.default_rng(seed)
    xs = rng.random((n, spec.input_geom.N)).astype(np.float32)
    ys = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
    return xs, ys


FEEDBACK_BATCH = 4


@functools.lru_cache(maxsize=None)
def _readout_step():
    spec, _ = _net()
    return jax.jit(lambda st_, x, y: supervised_readout_step(
        st_, spec, x, y))


def _replay(state, xs, ys):
    """The engine's feedback_eager=False fold compositions: full batches
    in stream order, one cycled tail (the offline form test_serve pins
    bit-exactly against the served engine)."""
    fn = _readout_step()
    items = list(zip(xs, ys))
    while items:
        chunk, items = items[:FEEDBACK_BATCH], items[FEEDBACK_BATCH:]
        x, y = cycle_batch(chunk, FEEDBACK_BATCH)
        state = fn(state, jnp.asarray(x), jnp.asarray(y))
    return state


# ------------------------------------------------------------ chunking --

def test_chunk_bounds_cover_range_disjointly():
    for n, k in [(0, 1), (1, 1), (7, 3), (8, 2), (3, 5), (10, 10),
                 (1, 4), (100, 7)]:
        bounds = chunk_bounds(n, k)
        assert len(bounds) == k
        assert bounds[0][0] == 0 and bounds[-1][1] == n
        for (a0, b0), (a1, b1) in zip(bounds, bounds[1:]):
            assert b0 == a1 and a0 <= b0  # contiguous, non-overlapping
        # array_split convention: first n % k chunks one longer
        sizes = [b - a for a, b in bounds]
        assert sizes == sorted(sizes, reverse=True)
        assert sum(sizes) == n
    with pytest.raises(ValueError, match="k >= 1"):
        chunk_bounds(4, 0)


# --------------------------------------------------------------- merge --

def test_merge_of_agreeing_replicas_is_bit_identical():
    spec, state0 = _net()
    xs, ys = _stream(spec, 11, seed=1)
    s = _replay(state0, xs, ys)
    for k in (1, 2, 3, 4):
        merged = merge_replica_states([s] * k)
        assert states_bitwise_equal(merged, s)
        assert state_divergence(merged, s) == []


def test_merge_exposes_a_diverged_replica():
    """If replicas disagree, the merged state cannot equal all of them —
    the detection contract reconcile() rests on."""
    spec, state0 = _net()
    xs, ys = _stream(spec, 8, seed=2)
    a = _replay(state0, xs, ys)
    b = state0  # a stale replica
    merged = merge_replica_states([a, b])
    assert not (states_bitwise_equal(merged, a)
                and states_bitwise_equal(merged, b))
    div = state_divergence(a, b)
    assert div and any("byte" in d for d in div)


def test_merge_rejects_incongruent_states():
    with pytest.raises(ValueError, match="at least|>= 1"):
        merge_replica_states([])
    with pytest.raises(ValueError, match="congruent"):
        merge_replica_states([{"a": np.ones(3), "b": np.ones(2)},
                              {"a": np.ones(3)}])


def test_bitwise_equal_uses_bit_patterns_not_ieee():
    nan = np.array([np.nan, 1.0], np.float32)
    assert states_bitwise_equal({"w": nan}, {"w": nan.copy()})
    assert not states_bitwise_equal({"w": nan},
                                    {"w": np.array([np.nan, 2.0],
                                                   np.float32)})
    assert not states_bitwise_equal({"w": np.ones(2, np.float32)},
                                    {"w": np.ones(2, np.float64)})
    assert not state_finite({"w": nan})
    assert state_finite({"w": np.ones(2, np.float32),
                         "idx": np.array([1, 2], np.int32)})


# ------------------------------------- broadcast-replica protocol (live) --

def test_merged_broadcast_replicas_match_single_engine_bitwise():
    """Two replica engines fed the same broadcast stream, merged, equal
    the ONE engine serving the interleaved stream — all with
    feedback_eager=False, all bit-exact."""
    spec, state0 = _net()
    xs, ys = _stream(spec, 14, seed=3)  # 3 full batches + cycled tail 2
    engines = [BCPNNService(state0, spec, online_learning=True,
                            feedback_batch=FEEDBACK_BATCH,
                            feedback_eager=False).start(warmup=False)
               for _ in range(3)]  # replica A, replica B, reference
    for svc in engines:
        for x, y in zip(xs, ys):
            svc.feedback(x, int(y))
    for svc in engines:
        svc.stop()  # drains: folds every buffered batch incl. the tail
    rep_a, rep_b, ref = (svc.state for svc in engines)
    merged = merge_replica_states([rep_a, rep_b])
    assert states_bitwise_equal(merged, ref), state_divergence(merged, ref)
    assert not states_bitwise_equal(ref, state0)  # it actually learned


# ------------------------------------------------ hypothesis property --

if given is not None:
    @settings(deadline=None, max_examples=12)
    @given(n=st.integers(1, 25), k=st.integers(1, 4),
           seed=st.integers(0, 2 ** 16 - 1))
    def test_merge_bit_identical_to_interleaved_serve_property(n, k, seed):
        """Satellite 3: for ANY feedback stream, replicas produced by
        the broadcast protocol (each serving the full stream,
        feedback_eager=False compositions) merge bit-identically to the
        single-engine serve of the interleaved stream.  Replicas are
        replayed independently — the property also witnesses that the
        fold program is a pure function of the stream prefix."""
        spec, state0 = _net()
        xs, ys = _stream(spec, n, seed)
        ref = _replay(state0, xs, ys)
        replicas = [_replay(state0, xs, ys) for _ in range(k)]
        merged = merge_replica_states(replicas)
        assert states_bitwise_equal(merged, ref), \
            state_divergence(merged, ref)
else:  # pragma: no cover - exercised only without hypothesis installed
    @pytest.mark.skip(reason="optional dep: property test needs hypothesis")
    def test_merge_bit_identical_to_interleaved_serve_property():
        pass
