"""BCPNN behaviour tests: the paper's correctness claims (§6.1) on the
offline surrogate datasets — learning works, modes behave, structural
plasticity refines receptive fields."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BCPNNConfig, Trainer, infer, init_network, mutual_information,
    supervised_step, unsupervised_step,
)
from repro.data.synthetic import encode_images, make_synthetic


def _small_task(seed=0, max_shift=1):
    ds = make_synthetic(2048, 512, 12, 5, seed=seed, max_shift=max_shift)
    return ds, encode_images(ds.x_train), encode_images(ds.x_test)


def test_learns_synthetic_classification():
    ds, xt, xe = _small_task()
    cfg = BCPNNConfig(input_hc=144, input_mc=2, hidden_hc=16, hidden_mc=32,
                      n_classes=5, nact_hi=144, alpha=1e-2,
                      support_noise=3.0, noise_steps=200)
    tr = Trainer(cfg, seed=0)
    tr.fit(xt, ds.y_train, epochs=15, batch=128)
    acc = tr.evaluate(xe, ds.y_test, batch=128)
    assert acc > 0.85, acc


def test_beats_naive_bayes_under_translation():
    """The hidden layer must add value over the direct Bayesian readout
    (the paper's premise that the hidden representation matters)."""
    ds, xt, xe = _small_task()
    cfg = BCPNNConfig(input_hc=144, input_mc=2, hidden_hc=16, hidden_mc=32,
                      n_classes=5, nact_hi=144, alpha=1e-2,
                      support_noise=3.0, noise_steps=200)
    tr = Trainer(cfg, seed=0)
    tr.fit(xt, ds.y_train, epochs=15, batch=128)
    acc = tr.evaluate(xe, ds.y_test, batch=128)
    # direct naive-Bayes readout (no hidden layer): input -> output
    cfg_nb = BCPNNConfig(input_hc=144, input_mc=2, hidden_hc=1, hidden_mc=2,
                         n_classes=5, nact_hi=144, alpha=1e-2)
    from repro.core.bcpnn_layer import ProjSpec, init_projection, learn, support
    from repro.core.hypercolumns import LayerGeom
    spec = ProjSpec(LayerGeom(144, 2), LayerGeom(1, 5), alpha=1e-2)
    proj = init_projection(spec, jax.random.PRNGKey(2))
    for i in range(0, len(xt) // 128 * 128, 128):
        proj = learn(proj, spec, jnp.asarray(xt[i:i + 128]),
                     jax.nn.one_hot(ds.y_train[i:i + 128], 5))
    pred = jnp.argmax(support(proj, spec, jnp.asarray(xe)), -1)
    nb_acc = float(jnp.mean(pred == jnp.asarray(ds.y_test)))
    assert acc > nb_acc, (acc, nb_acc)


def test_struct_plasticity_improves_mi():
    """Rewiring must increase the total mutual information captured by the
    active receptive fields (Fig. 5's 'more refined field')."""
    ds, xt, _ = _small_task()
    cfg = BCPNNConfig(input_hc=144, input_mc=2, hidden_hc=8, hidden_mc=16,
                      n_classes=5, nact_hi=48, alpha=1e-2,
                      support_noise=3.0, noise_steps=100, struct_every=0)
    state = init_network(cfg, jax.random.PRNGKey(0))
    step = jax.jit(lambda s, x: unsupervised_step(s, cfg, x))
    for epoch in range(5):
        for i in range(0, 2048, 128):
            state = step(state, jnp.asarray(xt[i:i + 128]))
    mi = mutual_information(state.ih.traces, 144, 2, 8, 16)
    mask0 = state.ih.mask
    mi_before = float(jnp.sum(mi * mask0))
    from repro.core.bcpnn_layer import rewire
    rewired = rewire(state.ih, cfg.ih_spec())
    mi_after = float(jnp.sum(mi * rewired.mask))
    assert mi_after >= mi_before, (mi_before, mi_after)
    assert float(jnp.sum(rewired.mask, 0)[0]) == cfg.nact_hi


def test_inference_mode_is_pure():
    """Inference must not mutate state (the paper's inference-only kernel)."""
    cfg = BCPNNConfig(input_hc=16, input_mc=2, hidden_hc=4, hidden_mc=8,
                      n_classes=3, nact_hi=16)
    state = init_network(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 32))
    before = jax.tree.map(lambda a: np.asarray(a).copy(), state)
    probs, pred = infer(state, cfg, x)
    assert probs.shape == (8, 3) and pred.shape == (8,)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervised_step_only_touches_readout():
    cfg = BCPNNConfig(input_hc=16, input_mc=2, hidden_hc=4, hidden_mc=8,
                      n_classes=3, nact_hi=16)
    state = init_network(cfg, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 32))
    y = jnp.array([0, 1, 2, 0, 1, 2, 0, 1])
    new = supervised_step(state, cfg, x, y)
    np.testing.assert_array_equal(np.asarray(new.ih.w), np.asarray(state.ih.w))
    assert not np.allclose(np.asarray(new.ho.w), np.asarray(state.ho.w))
