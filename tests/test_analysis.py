"""Tests for repro.analysis — the invariant linter and contract checks.

Three batteries:

* **Fixture corpus** — every rule's known-bad snippet in
  ``tests/fixtures/lint/`` produces EXACTLY its finding (rule id at the
  ``# BUG`` line, nothing else), and the CLI exits nonzero on it.
* **Engine mechanics** — suppressions (reason required, line-above
  coverage), the line-free baseline, safe idioms that must NOT fire.
* **Contract checks** — the donation guard, DP-seam, Pallas-plan, and
  recompile-sentinel sanitizers all pass on the current tree, plus unit
  coverage for the jaxpr barrier scanner itself.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.findings import (
    Finding, apply_suppressions, load_baseline, parse_suppressions,
    save_baseline, split_baselined,
)
from repro.analysis.lint import all_rules, lint_paths

ROOT = Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "lint"

pytestmark = pytest.mark.lint

# fixture file -> rule id it must (only) trigger
CORPUS = {
    "donated_reuse.py": "donated-reuse",
    "pad_fill_literal.py": "pad-fill-literal",
    "serve_lock.py": "serve-lock",
    "jit_purity.py": "jit-purity",
    "core/learning_dtype.py": "learning-dtype",
    "infer_pack_mutation.py": "infer-pack-mutation",
    "serve/except_discipline.py": "serve-except",
}


def _bug_line(path: Path) -> int:
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        if "# BUG" in line:
            return i
    raise AssertionError(f"{path} has no '# BUG' marker line")


# ------------------------------------------------------ fixture corpus ----

def test_corpus_covers_every_rule():
    assert sorted(CORPUS.values()) == sorted(all_rules())


@pytest.mark.parametrize("fname,rule", sorted(CORPUS.items()))
def test_fixture_produces_exactly_its_finding(fname, rule):
    path = FIXTURES / fname
    findings = lint_paths([path], ROOT)
    assert [f.rule for f in findings] == [rule], (
        f"{fname}: expected exactly one {rule} finding, got "
        f"{[f.format() for f in findings]}")
    f = findings[0]
    assert f.line == _bug_line(path)
    assert f.path == f"tests/fixtures/lint/{fname}"
    assert f.severity == "error"


@pytest.mark.parametrize("fname", sorted(CORPUS))
def test_cli_exits_nonzero_with_file_line_anchor(fname):
    path = FIXTURES / fname
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict",
         "--no-baseline", str(path)],
        capture_output=True, text=True, cwd=ROOT,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    anchor = f"tests/fixtures/lint/{fname}:{_bug_line(path)}:"
    assert anchor in proc.stdout


def test_repo_self_scan_clean_modulo_baseline():
    """The committed tree carries no findings beyond the baseline — the
    burn-down regression (new code must lint clean or suppress with a
    reason)."""
    roots = [ROOT / r for r in
             ("src", "scripts", "benchmarks", "examples", "tests")
             if (ROOT / r).exists()]
    findings = lint_paths(roots, ROOT)
    baseline = load_baseline(ROOT / ".analysis-baseline.json")
    new, _ = split_baselined(findings, baseline)
    assert not new, "unbaselined findings:\n" + \
        "\n".join(f.format() for f in new)


def test_cli_strict_clean_on_repo():
    from repro.analysis.__main__ import main
    assert main(["--strict"]) == 0


# --------------------------------------------------- engine mechanics ----

def _lint_source(tmp_path: Path, source: str, name: str = "snippet.py"):
    p = tmp_path / name
    p.write_text(source)
    return lint_paths([p], tmp_path)


def test_safe_rebind_idiom_not_flagged(tmp_path):
    findings = _lint_source(tmp_path, """\
import jax
step = jax.jit(lambda s: s, donate_argnums=0)
def run(state):
    for _ in range(3):
        state = step(state)
    return state
""")
    assert findings == []


def test_donated_tuple_rebind_not_flagged(tmp_path):
    findings = _lint_source(tmp_path, """\
import jax
step = jax.jit(lambda p, o: (0.0, p, o), donate_argnums=(0, 1))
def run(params, opt_state, batches):
    for _ in batches:
        loss, params, opt_state = step(params, opt_state)
        save(params, opt_state)
    return loss
""")
    assert findings == []


def test_partial_jit_decorator_donation_flagged(tmp_path):
    findings = _lint_source(tmp_path, """\
import functools
import jax
@functools.partial(jax.jit, donate_argnums=(0,))
def step(state, x):
    return state
def run(state, x):
    out = step(state, x)
    return state
""")
    assert [f.rule for f in findings] == ["donated-reuse"]


def test_suppression_requires_reason(tmp_path):
    # the marker is built by concatenation so the linter's raw-line scan
    # does not read THIS file's literal as a reasonless suppression
    findings = _lint_source(tmp_path, (
        "import jax.numpy as jnp\n"
        "FILL = -1e30  # repro" ": suppress[pad-fill-literal]\n"))
    rules = sorted(f.rule for f in findings)
    # reasonless: the original finding survives AND the suppression is
    # itself reported
    assert rules == ["pad-fill-literal", "suppress-needs-reason"]


def test_suppression_with_reason_covers_same_and_next_line(tmp_path):
    findings = _lint_source(tmp_path, """\
import jax.numpy as jnp
A = -1e30  # repro: suppress[pad-fill-literal] — test fill
# repro: suppress[pad-fill-literal] — line-above form
B = -1e30
""")
    assert findings == []


def test_suppression_only_covers_named_rule(tmp_path):
    findings = _lint_source(tmp_path, """\
import jax.numpy as jnp
A = -1e30  # repro: suppress[jit-purity] — wrong rule named
""")
    assert [f.rule for f in findings] == ["pad-fill-literal"]


def test_parse_suppressions_accepts_dash_variants():
    for dash in ("—", "–", "--", "-"):
        (s,) = parse_suppressions(["x = 1  # repro" +
                                   f": suppress[a-rule] {dash} why"])
        assert s.rules == ("a-rule",) and s.reason == "why"


def test_baseline_is_line_number_free(tmp_path):
    f1 = Finding("r", "a.py", 10, "m", snippet="x = -1e30")
    bl_path = tmp_path / "bl.json"
    save_baseline(bl_path, [f1])
    # same finding shifted 5 lines still matches its baseline entry
    shifted = Finding("r", "a.py", 15, "m", snippet="x = -1e30")
    new, old = split_baselined([shifted], load_baseline(bl_path))
    assert new == [] and old == [shifted]


def test_baseline_entry_absorbs_only_one_instance():
    baseline = [{"rule": "r", "path": "a.py", "snippet": "x = -1e30"}]
    a = Finding("r", "a.py", 1, "m", snippet="x = -1e30")
    b = Finding("r", "a.py", 9, "m", snippet="x = -1e30")
    new, old = split_baselined([a, b], baseline)
    assert old == [a] and new == [b]


def test_serve_lock_rule_respects_init_and_locked_writes(tmp_path):
    findings = _lint_source(tmp_path, """\
import threading
class M:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0          # construction precedes sharing: exempt
    def bump(self):
        with self._lock:
            self._n += 1     # guarded: fine
    def bump2(self):
        with self._lock:
            self._n += 2     # also guarded: fine
""")
    assert findings == []


def test_serve_except_rule_accepts_supervision_idioms(tmp_path):
    # path-scoped: applies only under serve/; every discharge form —
    # re-raise, future completion, supervision sink — must pass
    serve = tmp_path / "serve"
    serve.mkdir()
    (serve / "snippet.py").write_text("""\
class Engine:
    def a(self, group, infer):
        try:
            infer(group)
        except Exception as e:
            for r in group:
                r.error = e
                r.done.set()
    def b(self, e_fn):
        try:
            e_fn()
        except Exception as e:
            self._note_crash(e)
    def c(self, e_fn):
        try:
            e_fn()
        except Exception:
            raise RuntimeError("wrapped")
    def d(self, e_fn):
        try:
            e_fn()
        except ValueError:
            pass   # typed catch: out of scope for the rule
""")
    findings = lint_paths([serve / "snippet.py"], tmp_path)
    assert findings == []
    # the same swallow OUTSIDE serve/ is also out of scope
    (tmp_path / "other.py").write_text("""\
def f(g):
    try:
        g()
    except Exception:
        pass
""")
    assert lint_paths([tmp_path / "other.py"], tmp_path) == []


def test_jit_purity_flags_kernel_bodies(tmp_path):
    findings = _lint_source(tmp_path, """\
import numpy as np
from jax.experimental import pallas as pl
def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * np.random.rand()
def call(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)
""")
    assert [f.rule for f in findings] == ["jit-purity"]


def test_learning_dtype_allows_pack_boundary(tmp_path):
    # the rule is path-scoped: it only applies under core/
    core = tmp_path / "core"
    core.mkdir()
    (core / "snippet.py").write_text("""\
import jax.numpy as jnp
def pack_projection(proj, spec):
    return proj.w.astype(jnp.bfloat16)   # the one legitimate site
def learn(proj):
    return proj.w.astype(jnp.float16)    # violation
""")
    findings = lint_paths([core / "snippet.py"], tmp_path)
    assert [f.rule for f in findings] == ["learning-dtype"]
    assert findings[0].line == 5


# ----------------------------------------------------- contract checks ----

def test_donation_guard_contract_holds():
    from repro.analysis.contracts import check_donation_guard
    assert check_donation_guard() == []


def test_pallas_plans_contract_holds():
    from repro.analysis.plans import check_pallas_plans
    assert check_pallas_plans() == []


def test_dp_seams_contract_holds():
    from repro.analysis.contracts import check_dp_seams
    assert check_dp_seams() == []


def test_masked_seams_contract_holds():
    from repro.analysis.contracts import check_masked_seams
    assert check_masked_seams() == []


def test_recompile_sentinel_contract_holds():
    from repro.analysis.contracts import check_recompile_sentinel
    assert check_recompile_sentinel() == []


def test_quarantine_rollback_contract_holds():
    from repro.analysis.contracts import check_quarantine_rollback
    assert check_quarantine_rollback() == []


def test_router_exactly_once_contract_holds():
    from repro.analysis.contracts import check_router_exactly_once
    assert check_router_exactly_once() == []


def test_replica_merge_contract_holds():
    from repro.analysis.contracts import check_replica_merge
    assert check_replica_merge() == []


def test_barrier_scanner_sees_through_jit_and_scan():
    """Unit coverage for the jaxpr walker the DP-seam check rides on."""
    import jax
    import jax.numpy as jnp
    from repro.analysis.contracts import _barrier_signatures

    def inner(x):
        return jax.lax.optimization_barrier(x * 2.0)

    def outer(x):
        y = jax.jit(inner)(x)
        def body(c, _):
            return jax.lax.optimization_barrier(c + 1.0), ()
        c, _ = jax.lax.scan(body, y, None, length=3)
        return c

    sigs = _barrier_signatures(
        jax.make_jaxpr(outer)(jnp.zeros((4, 3), jnp.float32)))
    assert sigs.count(("float32[4,3]",)) == 2


def test_plan_checker_catches_bad_accumulator(tmp_path):
    """The accumulator audit actually reads dtypes: a kernels dir with a
    f64 VMEM scratch must be rejected."""
    from repro.analysis.plans import KERNEL_ACCUMULATOR_DTYPES, check_accumulators
    for fname in KERNEL_ACCUMULATOR_DTYPES:
        (tmp_path / fname).write_text(
            "import jax.numpy as jnp\n"
            "from jax.experimental.pallas import tpu as pltpu\n"
            "def f():\n"
            "    s = pltpu.VMEM((8, 128), jnp.float64)\n"
            "    return jnp.dot(s, s, preferred_element_type=jnp.float32)\n")
    problems = check_accumulators(tmp_path)
    assert len(problems) == len(KERNEL_ACCUMULATOR_DTYPES)
    assert all("accumulator contract" in p for p in problems)
