"""Serving robustness tests (DESIGN.md §10): admission control +
deadlines, typed Overloaded rejection, poison-request bisection, worker
supervision and dead-worker detection, learning-state quarantine, the
deterministic fault-injection harness itself, and a seeded chaos soak
(slow marker) that drives all four fault classes under Poisson load and
asserts zero lost/hung requests."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import infer, init_deep
from repro.serve import (
    BCPNNService, DeadlineExceeded, Fault, FaultInjected, FaultInjector,
    Overloaded, Quarantined, WorkerDied, run_open_loop,
)
from repro.serve.engine import _state_finite


def _small_net(seed=0, side=6, n_classes=3):
    spec = deep_synth_spec(side=side, depth=1, n_classes=n_classes,
                           hidden_hc=4, hidden_mc=8, backend="jnp")
    return spec, init_deep(spec, jax.random.PRNGKey(seed))


def _x(spec, seed=0, n=1):
    x = np.asarray(jax.random.uniform(jax.random.PRNGKey(seed),
                                      (n, spec.input_geom.N)), np.float32)
    return x[0] if n == 1 else x


class _Blocker(FaultInjector):
    """Test-controlled injector: the worker blocks at the slow-batch
    point until released, so a test can deterministically build a
    backlog behind an in-flight microbatch."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.entered = threading.Event()
        self.release = threading.Event()

    def maybe(self, point):
        if point == "slow-batch":
            self.entered.set()
            assert self.release.wait(30.0), "blocker never released"
        return super().maybe(point)


# ------------------------------------------------------ fault injector ----

def test_injector_rejects_unknown_points():
    with pytest.raises(ValueError):
        FaultInjector(rates={"no-such-point": 0.5})
    with pytest.raises(ValueError):
        FaultInjector(schedule={"also-bad": {0}})


def test_injector_schedule_fires_exact_invocations():
    inj = FaultInjector(seed=7, schedule={"infer-raise": {0, 2}})
    fired = [inj.maybe("infer-raise") is not None for _ in range(4)]
    assert fired == [True, False, True, False]
    assert inj.counts()["infer-raise"] == 2
    assert [f.index for f in inj.events] == [0, 2]
    with pytest.raises(FaultInjected):
        inj2 = FaultInjector(seed=7, schedule={"fold-raise": {0}})
        inj2.raise_if("fold-raise")


def test_injector_rate_stream_is_seed_deterministic():
    a = FaultInjector(seed=3, rates={"infer-raise": 0.3, "slow-batch": 0.3})
    b = FaultInjector(seed=3, rates={"infer-raise": 0.3, "slow-batch": 0.3})
    # interleave differently: per-point streams must not cross-talk
    seq_a = [a.maybe("infer-raise") is not None for _ in range(40)]
    _ = [a.maybe("slow-batch") for _ in range(11)]
    _ = [b.maybe("slow-batch") for _ in range(3)]
    seq_b = [b.maybe("infer-raise") is not None for _ in range(40)]
    assert seq_a == seq_b and any(seq_a)


def test_injector_corrupt_state_flips_sentinel():
    _, state = _small_net()
    assert _state_finite(state)
    assert not _state_finite(FaultInjector.corrupt_state(state))


def test_fault_dataclass_is_frozen():
    f = Fault(point="infer-raise", index=0)
    with pytest.raises(Exception):
        f.index = 1


# --------------------------------------------------- admission control ----

def test_overloaded_at_queue_bound():
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=4, max_queue=3,
                       fault_injector=blk).start()
    try:
        x = _x(spec)
        first = svc.submit(x)           # worker takes it and blocks
        assert blk.entered.wait(10.0)
        backlog = [svc.submit(x) for _ in range(3)]   # fills the bound
        with pytest.raises(Overloaded) as ei:
            svc.submit(x)
        assert "3/3" in str(ei.value)
        snap = svc.snapshot()
        assert snap["rejected"] == 1.0
        blk.release.set()
        for rid in [first] + backlog:   # everything admitted still serves
            svc.result(rid, timeout=30.0)
        assert svc.snapshot()["completed"] == 4.0
    finally:
        blk.release.set()
        svc.stop()


def test_deadline_expired_request_is_shed_at_dequeue():
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=4,
                       fault_injector=blk).start()
    try:
        x = _x(spec)
        first = svc.submit(x)           # occupies the worker
        assert blk.entered.wait(10.0)
        doomed = svc.submit(x, deadline_s=0.05)
        ok = svc.submit(x)              # no deadline: must still serve
        time.sleep(0.12)                # deadline passes while queued
        blk.release.set()
        svc.result(first, timeout=30.0)
        with pytest.raises(DeadlineExceeded) as ei:
            svc.result(doomed, timeout=30.0)
        assert f"request {doomed}" in str(ei.value)
        svc.result(ok, timeout=30.0)
        snap = svc.snapshot()
        assert snap["shed"] == 1.0
        assert snap["completed"] == 2.0
        # accounting closes: nothing silently dropped
        assert snap["submitted"] == snap["completed"] + snap["shed"]
    finally:
        blk.release.set()
        svc.stop()


def test_engine_default_deadline_applies_to_every_submit():
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=4, default_deadline_s=0.05,
                       fault_injector=blk).start()
    try:
        x = _x(spec)
        first = svc.submit(x)
        assert blk.entered.wait(10.0)
        doomed = svc.submit(x)          # inherits the engine default
        time.sleep(0.12)
        blk.release.set()
        svc.result(first, timeout=30.0)
        with pytest.raises(DeadlineExceeded):
            svc.result(doomed, timeout=30.0)
    finally:
        blk.release.set()
        svc.stop()


# ------------------------------------------------------------ bisection ----

def test_poison_bisection_isolates_exactly_the_bad_request():
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=8,
                       fault_injector=blk).start()
    try:
        xs = _x(spec, seed=5, n=6)
        first = svc.submit(_x(spec))    # occupies the worker
        assert blk.entered.wait(10.0)
        rids = [svc.submit(xs[i]) for i in range(6)]   # one future group
        blk.poison(rids[2])
        blk.release.set()
        svc.result(first, timeout=30.0)
        with pytest.raises(FaultInjected) as ei:
            svc.result(rids[2], timeout=30.0)
        assert str(rids[2]) in str(ei.value)
        # groupmates of the poison request still serve GENUINE results
        probs_direct, pred_direct = infer(state, spec, xs)
        for i, rid in enumerate(rids):
            if i == 2:
                continue
            res = svc.result(rid, timeout=30.0)
            assert res.pred == int(np.asarray(pred_direct)[i])
            np.testing.assert_allclose(res.probs,
                                       np.asarray(probs_direct)[i],
                                       atol=1e-6)
        snap = svc.snapshot()
        assert snap["failed"] == 1.0
        assert snap["bisects"] >= 1.0
        assert snap["crashes"] >= 1.0
        assert snap["completed"] == 6.0    # first + 5 groupmates
    finally:
        blk.release.set()
        svc.stop()


def test_transient_infer_raise_costs_a_retry_not_the_batch():
    spec, state = _small_net()
    # invocation 0 is the blocker-held single; invocation 1 hits the
    # 4-group, whose bisected halves (invocations 2, 3) then succeed —
    # a TRANSIENT group failure serves everyone after the retry
    blk = _Blocker(seed=0, schedule={"infer-raise": {1}})
    svc = BCPNNService(state, spec, max_batch=8,
                       fault_injector=blk).start()
    try:
        first = svc.submit(_x(spec))
        assert blk.entered.wait(10.0)
        rids = [svc.submit(_x(spec, seed=3 + i)) for i in range(4)]
        blk.release.set()
        svc.result(first, timeout=30.0)
        for rid in rids:
            assert svc.result(rid, timeout=30.0).pred >= 0
        snap = svc.snapshot()
        assert snap["failed"] == 0.0          # everyone served after retry
        assert snap["bisects"] >= 1.0
        assert snap["crashes"] >= 1.0
        assert snap["completed"] == 5.0
    finally:
        blk.release.set()
        svc.stop()


# ----------------------------------------------------------- quarantine ----

def test_quarantine_rolls_back_and_degrades_to_inference_only():
    spec, state = _small_net()
    inj = FaultInjector(seed=0, schedule={"nan-state": {1}})
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       feedback_batch=2, feedback_eager=False,
                       fault_injector=inj).start()
    try:
        rng = np.random.default_rng(0)
        ni = spec.input_geom.N
        fb = lambda: svc.feedback(rng.random(ni).astype(np.float32),
                                  int(rng.integers(0, spec.n_classes)))
        fb(), fb()                         # fold 0: clean
        _wait(lambda: svc.snapshot()["learn_steps"] >= 1)
        good = jax.tree_util.tree_map(np.asarray, svc.model_state())
        fb(), fb()                         # fold 1: nan-injected
        _wait(lambda: svc.snapshot()["quarantined"] == 1.0)
        # (a) bitwise rollback to the last-good state
        after = jax.tree_util.tree_map(np.asarray, svc.model_state())
        for g, a in zip(jax.tree_util.tree_leaves(good),
                        jax.tree_util.tree_leaves(after)):
            np.testing.assert_array_equal(g, a)
        # (b) inference-only degradation: serving continues from the
        # rolled-back pack, feedback is refused typed
        x = _x(spec, seed=9)
        res = svc.classify(x, timeout=30.0)
        probs_d, pred_d = infer(svc.model_state(), spec, x[None, :])
        assert res.pred == int(np.asarray(pred_d)[0])
        np.testing.assert_allclose(res.probs, np.asarray(probs_d)[0],
                                   atol=1e-6)
        with pytest.raises(Quarantined):
            fb()
        snap = svc.snapshot()
        assert snap["quarantine_events"] == 1.0
        assert snap["feedback_dropped"] >= 2.0
        assert snap["learn_steps"] == 1.0   # the corrupted fold never landed
        # (c) revalidate() re-arms learning from the last-good snapshot
        svc.revalidate()
        assert svc.snapshot()["quarantined"] == 0.0
        fb(), fb()
        _wait(lambda: svc.snapshot()["learn_steps"] >= 2)
        assert _state_finite(svc.model_state())
    finally:
        svc.stop()


def test_fold_raise_is_survived_and_counted():
    spec, state = _small_net()
    inj = FaultInjector(seed=0, schedule={"fold-raise": {0}})
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       feedback_batch=2, feedback_eager=False,
                       fault_injector=inj).start()
    try:
        rng = np.random.default_rng(0)
        ni = spec.input_geom.N
        for i in range(2):
            svc.feedback(rng.random(ni).astype(np.float32), i % 2)
        _wait(lambda: svc.snapshot()["feedback_dropped"] >= 2.0)
        snap = svc.snapshot()
        assert snap["crashes"] >= 1.0
        assert snap["learn_steps"] == 0.0
        # the worker is alive and still serves
        res = svc.classify(_x(spec), timeout=30.0)
        assert res.pred >= 0
        # the NEXT fold (injector invocation 1) lands cleanly
        for i in range(2):
            svc.feedback(rng.random(ni).astype(np.float32), i % 2)
        _wait(lambda: svc.snapshot()["learn_steps"] >= 1)
    finally:
        svc.stop()


# ------------------------------------------------------ worker death ----

@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_dead_worker_fails_futures_and_raises_everywhere():
    # the worker re-raises its killer after _die (so thread tooling sees
    # the real exception) — pytest reports that as an unhandled thread
    # exception, which is exactly the behavior under test
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=4,
                       fault_injector=blk).start()
    slot = svc._slot(None)

    def _boom(*a, **k):
        raise KeyboardInterrupt("injected terminal failure")

    x = _x(spec)
    first = svc.submit(x)               # worker blocks at slow-batch
    assert blk.entered.wait(10.0)
    pending = svc.submit(x)             # will be in flight at death
    slot.infer_fn = _boom               # next batch kills the worker
    blk.release.set()
    # every pending future completes exceptionally — nothing hangs
    with pytest.raises(WorkerDied):
        svc.result(first, timeout=30.0)
    with pytest.raises(WorkerDied):
        svc.result(pending, timeout=30.0)
    # admission, restart and stop all surface the death typed
    with pytest.raises(WorkerDied):
        svc.submit(x)
    with pytest.raises(WorkerDied) as ei:
        svc.stop()
    assert "KeyboardInterrupt" in str(ei.value)
    with pytest.raises(WorkerDied):
        svc.start()


def test_stop_timeout_raises_instead_of_hanging():
    spec, state = _small_net()
    blk = _Blocker()
    svc = BCPNNService(state, spec, max_batch=4,
                       fault_injector=blk).start()
    svc.submit(_x(spec))
    assert blk.entered.wait(10.0)       # worker wedged mid-batch
    t0 = time.perf_counter()
    with pytest.raises(RuntimeError, match="failed to drain"):
        svc.stop(timeout_s=0.3)
    assert time.perf_counter() - t0 < 10.0
    blk.release.set()                   # let the daemon thread finish


# ------------------------------------------------------------ stragglers --

def test_injected_slow_batch_surfaces_as_attributed_straggler():
    spec, state = _small_net()
    inj = FaultInjector(seed=0, schedule={"slow-batch": {10}},
                        slow_ms=150.0)
    svc = BCPNNService(state, spec, max_batch=4,
                       fault_injector=inj).start()
    try:
        x = _x(spec)
        for _ in range(14):             # serial singles: one batch each
            svc.classify(x, timeout=30.0)
        snap = svc.snapshot()
        assert snap["straggler_events"] >= 1.0
        ev = [e for e in svc.step_timer.events if e.get("tag") == "default"]
        assert ev and ev[0]["time"] >= 0.14
    finally:
        svc.stop()


# ------------------------------------------------------------ chaos soak --

@pytest.mark.slow
def test_chaos_soak_zero_lost_requests():
    """Poisson load + all four fault classes from a seeded schedule:
    every submitted id resolves (result or typed error, never a hang),
    the worker survives, a NaN-injected fold leaves the served state at
    its last-good value, and tail latency stays bounded."""
    spec, state = _small_net(side=6)
    inj = FaultInjector(seed=42, slow_ms=30.0,
                        rates={"infer-raise": 0.05, "fold-raise": 0.10,
                               "nan-state": 0.05, "slow-batch": 0.05})
    svc = BCPNNService(state, spec, max_batch=8, online_learning=True,
                       feedback_batch=8, max_queue=128,
                       fault_injector=inj).start()
    n = 400
    xs = _x(spec, seed=1, n=64)
    ys = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (64,), 0,
                                       spec.n_classes))
    rep = run_open_loop(svc, xs, ys, n_requests=n, rate_hz=400.0,
                        seed=11, feedback_frac=0.3, timeout_s=60.0,
                        deadline_s=10.0)
    assert not svc._dead.is_set(), "worker died during the soak"
    svc.stop()
    # zero lost/hung ids: every submit resolved as a result or typed error
    assert len(rep.results) + len(rep.errors) + rep.n_rejected == n
    assert "TimeoutError" not in rep.error_counts(), rep.error_counts()
    snap = svc.snapshot()
    assert snap["submitted"] == snap["completed"] + snap["shed"] + \
        snap["failed"], f"request accounting leaks: {snap}"
    # faults actually fired (the soak exercised every class)
    counts = inj.counts()
    assert counts["infer-raise"] > 0 and counts["slow-batch"] > 0
    assert counts["fold-raise"] > 0 or counts["nan-state"] > 0
    # the served state never went non-finite (quarantine rolled back any
    # poisoned fold), so post-soak inference is at last-good quality
    assert _state_finite(svc.model_state())
    probs, _ = infer(svc.model_state(), spec, xs[:8])
    assert np.isfinite(np.asarray(probs)).all()
    # bounded tail: generous CPU bound, catches only collapse
    assert snap["p99_ms"] < 30_000.0


def _wait(cond, timeout_s: float = 30.0) -> None:
    deadline = time.perf_counter() + timeout_s
    while not cond():
        assert time.perf_counter() < deadline, "condition never held"
        time.sleep(0.002)
