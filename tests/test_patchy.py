"""Patchy-sparse execution path: the compact gathered kernels must match
the masked-dense schedules exactly — through chained learning, across a
rewire (the index table is rebuilt from the new mask), and under the
serving engine — on hostile (non-power-of-two) geometries."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bcpnn_layer import (
    ProjSpec, _learn_jnp, forward, init_projection, learn, rewire,
)
from repro.core.hypercolumns import LayerGeom
from repro.kernels import active_pre_hcs, fused_forward, fused_learn
from repro.kernels.patchy import unit_gather_indices

HOSTILE = ProjSpec(LayerGeom(13, 2), LayerGeom(5, 10), alpha=0.2, nact=4,
                   backend="pallas")


def _steps(proj, spec, n, seed=1, b=19):
    for k in jax.random.split(jax.random.PRNGKey(seed), n):
        kx, ky = jax.random.split(k)
        x = jax.random.uniform(kx, (b, spec.pre.N))
        y = jax.random.uniform(ky, (b, spec.post.N))
        yield x, y


# -------------------------------------------------------- index table ----

def test_active_table_matches_mask():
    spec = HOSTILE
    proj = init_projection(spec, jax.random.PRNGKey(0))
    table = np.asarray(active_pre_hcs(proj.mask, spec.nact))
    mask = np.asarray(proj.mask)
    for j in range(spec.post.H):
        np.testing.assert_array_equal(np.sort(table[j]),
                                      np.flatnonzero(mask[:, j]))


def test_unit_gather_indices_pad_sentinel():
    table = jnp.asarray([[0, 2]], jnp.int32)
    ui = np.asarray(unit_gather_indices(table, mi=2, k_pad=3, sentinel=99))
    np.testing.assert_array_equal(ui[0], [0, 1, 4, 5, 99, 99, 99])


# -------------------------------------------- forward: exact vs dense ----

@pytest.mark.parametrize("b,hi,mi,hj,mj,nact", [
    (33, 13, 2, 5, 10, 4),     # hostile everything
    (97, 784, 2, 4, 16, 128),  # Model-1-shaped pre side, prime batch
    (16, 9, 3, 3, 12, 2),      # mi > 2, tiny nact
])
def test_patchy_forward_matches_masked_dense(b, hi, mi, hj, mj, nact):
    spec = ProjSpec(LayerGeom(hi, mi), LayerGeom(hj, mj), alpha=1e-2,
                    nact=nact, backend="pallas")
    proj = init_projection(spec, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (b, spec.pre.N))
    got = fused_forward(proj, spec, x)   # dispatches to the patchy kernel
    want = forward(proj, spec.with_backend("jnp"), x)  # masked dense
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# ------------------------------------- learn: patchy-trace semantics ----

def test_patchy_learn_matches_jnp_reference_including_rewire():
    """Compact patchy plasticity vs its jnp reference (dense compute,
    where-masked trace) — exact through 8 chained steps with a rewire in
    the middle.  Same traces on both sides -> the rewire picks the same
    mask -> the rebuilt index table keeps parity after it."""
    spec = dataclasses.replace(HOSTILE, patchy_traces=True)
    proj_j = init_projection(spec, jax.random.PRNGKey(0))
    proj_f = jax.tree.map(jnp.array, proj_j)
    for i, (x, y) in enumerate(_steps(proj_j, spec, 8)):
        proj_j = _learn_jnp(proj_j, spec, x, y)
        proj_f = fused_learn(proj_f, spec, x, y)
        np.testing.assert_allclose(np.asarray(proj_f.traces.pij),
                                   np.asarray(proj_j.traces.pij), atol=1e-6,
                                   err_msg=f"pij diverged at step {i}")
        np.testing.assert_allclose(np.asarray(proj_f.w),
                                   np.asarray(proj_j.w), atol=1e-4)
        np.testing.assert_allclose(np.asarray(proj_f.b),
                                   np.asarray(proj_j.b), atol=1e-6)
        if i == 3:
            proj_j = rewire(proj_j, spec)
            proj_f = rewire(proj_f, spec)
            np.testing.assert_array_equal(np.asarray(proj_j.mask),
                                          np.asarray(proj_f.mask))
            assert np.all(np.asarray(proj_f.mask).sum(0) == spec.nact)


def test_patchy_learn_matches_masked_dense_while_mask_static():
    """With a static mask the active joint-trace entries follow the same
    EMA recursion under both semantics, so weights, biases and forward
    outputs of the patchy path equal the masked-DENSE path exactly; only
    the silent (inactive) pij entries differ — held vs tracked."""
    spec_dense = HOSTILE
    spec_patchy = dataclasses.replace(HOSTILE, patchy_traces=True)
    proj_d = init_projection(spec_dense, jax.random.PRNGKey(0))
    proj_p = jax.tree.map(jnp.array, proj_d)
    for x, y in _steps(proj_d, spec_dense, 5):
        proj_d = fused_learn(proj_d, spec_dense, x, y)
        proj_p = fused_learn(proj_p, spec_patchy, x, y)
    np.testing.assert_allclose(np.asarray(proj_p.w), np.asarray(proj_d.w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(proj_p.b), np.asarray(proj_d.b),
                               atol=1e-6)
    x = jax.random.uniform(jax.random.PRNGKey(7), (23, spec_dense.pre.N))
    np.testing.assert_allclose(
        np.asarray(fused_forward(proj_p, spec_patchy, x)),
        np.asarray(fused_forward(proj_d, spec_dense, x)), atol=1e-5)
    # active entries agree; silent ones hold their init value in patchy
    keep = np.repeat(np.repeat(np.asarray(proj_d.mask) > 0,
                               spec_dense.pre.M, 0), spec_dense.post.M, 1)
    np.testing.assert_allclose(np.asarray(proj_p.traces.pij)[keep],
                               np.asarray(proj_d.traces.pij)[keep],
                               atol=1e-6)
    assert not np.allclose(np.asarray(proj_p.traces.pij)[~keep],
                           np.asarray(proj_d.traces.pij)[~keep])


def test_patchy_cross_backend_parity():
    """learn() dispatch: backend=jnp and backend=pallas implement the SAME
    patchy-trace semantics, so a whole train/rewire/train run stays in
    lockstep across backends."""
    spec_j = dataclasses.replace(HOSTILE, backend="jnp", patchy_traces=True,
                                 struct_every=3)
    spec_p = dataclasses.replace(spec_j, backend="pallas")
    proj_j = init_projection(spec_j, jax.random.PRNGKey(0))
    proj_p = jax.tree.map(jnp.array, proj_j)
    for x, y in _steps(proj_j, spec_j, 6):
        proj_j = learn(proj_j, spec_j, x, y)
        proj_p = learn(proj_p, spec_p, x, y)
    np.testing.assert_allclose(np.asarray(proj_p.traces.pij),
                               np.asarray(proj_j.traces.pij), atol=1e-6)
    np.testing.assert_allclose(np.asarray(proj_p.w), np.asarray(proj_j.w),
                               atol=1e-4)


# ------------------------------------------- over-budget mask guard ----

def test_over_budget_mask_rejected_at_serving_boundary():
    """Masks that violate the exactly-nact invariant (e.g. checkpoints
    predating the topk fix) would be silently truncated by the index
    table — the engine must refuse them loudly instead."""
    from repro.core.bcpnn_layer import validate_patchy_mask
    from repro.core.network import init_deep, make_network_spec
    from repro.serve import BCPNNService

    spec = make_network_spec(LayerGeom(10, 2), [(4, 8)], n_classes=3,
                             nact=[3], backend="pallas")
    state = init_deep(spec, jax.random.PRNGKey(0))
    validate_patchy_mask(state.projs[0].mask, spec.projs[0])  # clean: ok
    bad_mask = state.projs[0].mask.at[:, 0].set(1.0)  # 10 > nact=3
    bad = dataclasses.replace(
        state, projs=(dataclasses.replace(state.projs[0], mask=bad_mask),))
    with pytest.raises(ValueError, match="exceeding nact"):
        BCPNNService(bad, spec, max_batch=8)


# ----------------------------------------------- serving integration ----

def test_serving_engine_infers_through_patchy_path():
    """A checkpoint-shaped patchy network serves through BCPNNService: the
    bucketed infer path dispatches to the compact kernels and returns the
    same predictions as the jnp reference network."""
    from repro.core.network import init_deep, make_network_spec
    from repro.core.network import infer as net_infer
    from repro.serve import BCPNNService

    spec_p = make_network_spec(LayerGeom(16, 2), [(4, 8)], n_classes=3,
                               alpha=1e-2, nact=[5], backend="pallas")
    state = init_deep(spec_p, jax.random.PRNGKey(0))
    xs = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (12, 32)))
    want = np.asarray(net_infer(state, spec_p.with_backend("jnp"),
                                jnp.asarray(xs))[1])
    svc = BCPNNService(state, spec_p, max_batch=8, max_wait_ms=2.0).start()
    try:
        ids = [svc.submit(x) for x in xs]
        got = np.asarray([svc.result(i).pred for i in ids])
    finally:
        svc.stop()
    np.testing.assert_array_equal(got, want)
