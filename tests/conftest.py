import os

# Tests run on the CPU platform; only launch/dryrun.py sets the 512-device
# flag (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# A 2-device host-CPU mesh for the data-parallel shard_map tests
# (tests/test_distributed.py): the flag must be set before jax initializes.
# Single-device tests are unaffected — everything still places on device 0.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
