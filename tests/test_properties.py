"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests "
                    "need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hypercolumns import LayerGeom, encode_scalar_hcs, hc_softmax
from repro.core.traces import Traces, init_traces, update_traces, weights_from_traces
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.optim.compression import compress_grads, init_error_state
from repro.data.pipeline import batch_indices

COMMON = dict(deadline=None, max_examples=20)


@settings(**COMMON)
@given(b=st.integers(1, 16), h=st.integers(1, 8), m=st.integers(2, 16),
       scale=st.floats(0.1, 20.0))
def test_hc_softmax_is_distribution(b, h, m, scale):
    key = jax.random.PRNGKey(b * 1000 + h * 100 + m)
    geom = LayerGeom(h, m)
    s = jax.random.normal(key, (b, h * m)) * scale
    out = np.asarray(hc_softmax(s, geom)).reshape(b, h, m)
    np.testing.assert_allclose(out.sum(-1), 1.0, atol=1e-4)
    assert (out >= 0).all()


@settings(**COMMON)
@given(steps=st.integers(1, 30), alpha=st.floats(1e-4, 0.5),
       seed=st.integers(0, 100))
def test_traces_stay_probabilities(steps, alpha, seed):
    """p traces remain in [0,1] and p_ij <= min-ish marginals under any
    stream of valid rate inputs."""
    key = jax.random.PRNGKey(seed)
    tr = init_traces(8, 6, 2, 3, key=key)
    for i in range(steps):
        k1, k2, key = jax.random.split(key, 3)
        x = jax.random.dirichlet(k1, jnp.ones(2), (4, 4)).reshape(4, 8)
        y = jax.random.dirichlet(k2, jnp.ones(3), (4, 2)).reshape(4, 6)
        tr = update_traces(tr, x, y, alpha)
    pi, pj, pij = np.asarray(tr.pi), np.asarray(tr.pj), np.asarray(tr.pij)
    assert (pi >= 0).all() and (pi <= 1 + 1e-6).all()
    assert (pj >= 0).all() and (pj <= 1 + 1e-6).all()
    assert (pij >= 0).all() and (pij <= 1 + 1e-6).all()
    # marginal consistency: sum over MC pairs within (HC_i, HC_j) ~ 1
    blocked = pij.reshape(4, 2, 2, 3)
    np.testing.assert_allclose(blocked.sum((1, 3)), 1.0, atol=1e-3)


@settings(**COMMON)
@given(seed=st.integers(0, 1000))
def test_weights_zero_iff_independent(seed):
    """If p_ij == p_i p_j exactly, weights must be ~0 (no spurious info)."""
    rng = np.random.default_rng(seed)
    pi = rng.uniform(0.2, 0.8, 6).astype(np.float32)
    pj = rng.uniform(0.2, 0.8, 4).astype(np.float32)
    tr = Traces(pi=jnp.asarray(pi), pj=jnp.asarray(pj),
                pij=jnp.asarray(np.outer(pi, pj)), t=jnp.asarray(5))
    w, b = weights_from_traces(tr)
    np.testing.assert_allclose(np.asarray(w), 0.0, atol=1e-5)


@settings(**COMMON)
@given(f=st.integers(1, 32), b=st.integers(1, 8), seed=st.integers(0, 99))
def test_scalar_encoding_is_valid_hc_activity(f, b, seed):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (b, f))
    enc = np.asarray(encode_scalar_hcs(x)).reshape(b, f, 2)
    np.testing.assert_allclose(enc.sum(-1), 1.0, atol=1e-6)


@settings(**COMMON)
@given(n=st.integers(64, 4096), batch=st.integers(1, 64),
       step=st.integers(0, 500), seed=st.integers(0, 10))
def test_data_pipeline_deterministic_and_seekable(n, batch, step, seed):
    batch = min(batch, n)
    a = batch_indices(n, batch, step, seed)
    b = batch_indices(n, batch, step, seed)
    np.testing.assert_array_equal(a, b)
    assert len(a) == batch and a.max() < n
    # within an epoch, batches partition the data (no repeats)
    steps_per_epoch = n // batch
    if steps_per_epoch >= 2:
        e0 = batch_indices(n, batch, (step // steps_per_epoch) * steps_per_epoch,
                           seed)
        e1 = batch_indices(n, batch,
                           (step // steps_per_epoch) * steps_per_epoch + 1, seed)
        assert len(np.intersect1d(e0, e1)) == 0


@settings(**COMMON)
@given(seed=st.integers(0, 100), lr=st.floats(1e-5, 1e-2))
def test_adamw_moves_params_finite(seed, lr):
    key = jax.random.PRNGKey(seed)
    params = {"w": jax.random.normal(key, (8, 8)),
              "b": jnp.zeros((8,))}
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=lr, warmup_steps=1, total_steps=100)
    new, opt = apply_updates(cfg, params, grads, opt)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(params)):
        assert np.isfinite(np.asarray(a)).all()
        assert not np.allclose(np.asarray(a), np.asarray(b))


@settings(**COMMON)
@given(hi=st.integers(1, 24), hj=st.integers(1, 8), k=st.integers(1, 30),
       quant=st.sampled_from([None, 1.0, 4.0]), seed=st.integers(0, 1000))
def test_topk_mask_exact_budget(hi, hj, k, quant, seed):
    """The patchy-connectivity mask must hold exactly min(k, Hi) pre-HCs
    per post-HC for ANY score matrix — including heavily tied scores
    (quantized), the case a threshold-based mask over-admits on."""
    from repro.core.bcpnn_layer import topk_mask

    scores = jax.random.normal(jax.random.PRNGKey(seed), (hi, hj))
    if quant is not None:
        scores = jnp.round(scores * quant) / quant
    kk = min(k, hi)
    m = np.asarray(topk_mask(scores, kk))
    np.testing.assert_array_equal(m.sum(0), float(kk))
    assert set(np.unique(m)) <= {0.0, 1.0}


@pytest.mark.slow
@settings(deadline=None, max_examples=10)
@given(hi=st.integers(5, 12), mi=st.integers(1, 3), hj=st.integers(2, 5),
       mj=st.integers(2, 10), nact=st.integers(1, 4), b=st.integers(2, 17),
       backend=st.sampled_from(["jnp", "pallas"]), seed=st.integers(0, 50))
def test_compact_learn_matches_dense_reference_property(hi, mi, hj, mj,
                                                        nact, b, backend,
                                                        seed):
    """Scatter-free compact learn == the dense-trace ``_learn_jnp``
    reference of the compact semantics, for ANY geometry/batch/backend:
    7 chained steps with alpha=0.3 cross the bias-correction crossover
    (t > 1/alpha ≈ 3.3) and a rewire event fires mid-run — masks, the
    densified joint trace, biases and forward outputs must all track the
    reference through it."""
    import dataclasses

    from repro.core.bcpnn_layer import (ProjSpec, _learn_jnp, forward,
                                        init_projection, learn, rewire)
    from repro.core.compact import densify_pij

    nact = min(nact, hi - 1)
    spec = ProjSpec(LayerGeom(hi, mi), LayerGeom(hj, mj), alpha=0.3,
                    nact=nact, backend=backend, patchy_traces=True,
                    compact=True)
    key = jax.random.PRNGKey(seed)
    proj_ref = init_projection(dataclasses.replace(spec, compact=False),
                               key)
    proj_c = init_projection(spec, key)
    for i, k in enumerate(jax.random.split(jax.random.PRNGKey(seed + 1), 7)):
        kx, ky = jax.random.split(k)
        x = jax.random.uniform(kx, (b, spec.pre.N))
        y = jax.random.uniform(ky, (b, spec.post.N))
        proj_ref = _learn_jnp(proj_ref, spec, x, y)
        proj_c = learn(proj_c, spec, x, y)
        dense_view = densify_pij(proj_c.traces.pij, proj_c.traces.pi,
                                 proj_c.traces.pj, proj_c.table, mi)
        np.testing.assert_allclose(np.asarray(dense_view),
                                   np.asarray(proj_ref.traces.pij),
                                   atol=1e-6, err_msg=f"pij step {i}")
        np.testing.assert_allclose(np.asarray(proj_c.b),
                                   np.asarray(proj_ref.b), atol=1e-6)
        if i == 3:  # at the crossover: a rewire event
            proj_ref = rewire(proj_ref, spec)
            proj_c = rewire(proj_c, spec)
            np.testing.assert_array_equal(np.asarray(proj_ref.mask),
                                          np.asarray(proj_c.mask))
            assert np.all(np.asarray(proj_c.mask).sum(0) == nact)
    assert float(proj_c.traces.t) * spec.alpha > 1.0, "never crossed"
    xf = jax.random.uniform(jax.random.PRNGKey(seed + 2), (5, spec.pre.N))
    np.testing.assert_allclose(
        np.asarray(forward(proj_c, spec, xf)),
        np.asarray(forward(proj_ref, dataclasses.replace(
            spec, compact=False, backend="jnp"), xf)), atol=1e-5)


@settings(**COMMON)
@given(seed=st.integers(0, 100))
def test_grad_compression_error_feedback_bounded(seed):
    """Quantize->dequantize with error feedback: per-step error is bounded
    by one quantization bucket and the carried error never explodes."""
    key = jax.random.PRNGKey(seed)
    grads = {"w": jax.random.normal(key, (64,)) * 3.0}
    err = init_error_state(grads)
    for _ in range(5):
        deq, err = compress_grads(grads, err)
        scale = float(jnp.max(jnp.abs(grads["w"]) + jnp.abs(err["w"]))) / 127.0
        assert float(jnp.abs(err["w"]).max()) <= scale + 1e-6
