"""End-to-end system tests: training driver, checkpoint/restart, serving,
sharding machinery, MoE dispatch invariants."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.configs.base import ModelConfig
from repro.checkpoint import CheckpointManager
from repro.distributed.sharding import make_rules, sharding_context
from repro.models import lm
from repro.models.moe import init_moe, moe_ffn
from repro.optim import init_opt_state

ENV = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(cmd, timeout=600):
    return subprocess.run(cmd, cwd=ROOT, env=ENV, capture_output=True,
                          text=True, timeout=timeout)


@pytest.mark.slow
def test_train_driver_end_to_end(tmp_path):
    r = _run([sys.executable, "-m", "repro.launch.train", "--arch",
              "qwen1.5-0.5b", "--smoke", "--steps", "8", "--batch", "2",
              "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "final loss" in r.stdout
    # restart resumes from the checkpoint
    r2 = _run([sys.executable, "-m", "repro.launch.train", "--arch",
               "qwen1.5-0.5b", "--smoke", "--steps", "12", "--batch", "2",
               "--seq", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "4"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 8" in r2.stdout


@pytest.mark.slow
def test_serve_driver_end_to_end():
    r = _run([sys.executable, "-m", "repro.launch.serve", "--arch",
              "gemma2-2b", "--smoke", "--batch", "2", "--prompt-len", "16",
              "--gen", "6"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "decode" in r.stdout


@pytest.mark.slow
def test_serve_bcpnn_driver_end_to_end(tmp_path):
    """The BCPNN serving driver: train -> checkpoint -> restore -> serve ->
    online-learn -> multi-model + in-deployment rewire, with its own smoke
    assertions (latency report, no drops, measurable readout improvement,
    struct_every boundary crossed while serving)."""
    r = _run([sys.executable, "-m", "repro.launch.serve_bcpnn", "--smoke",
              "--ckpt-dir", str(tmp_path / "ckpt")])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "smoke OK" in r.stdout
    assert "p99" in r.stdout
    assert "multi-model + rewire phase OK" in r.stdout
    # a second run must RESTORE the checkpoint rather than retrain, and
    # must be able to serve it as a multi-model deployment (--ckpt mode)
    r2 = _run([sys.executable, "-m", "repro.launch.serve_bcpnn", "--smoke",
               "--ckpt-dir", str(tmp_path / "ckpt"), "--no-online",
               "--no-multi"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "no checkpoint" not in r2.stdout
    assert "restored step" in r2.stdout
    r3 = _run([sys.executable, "-m", "repro.launch.serve_bcpnn",
               "--ckpt", str(tmp_path / "ckpt"),
               "--ckpt", str(tmp_path / "ckpt"),
               "--requests", "64", "--no-online"])
    assert r3.returncode == 0, r3.stdout + r3.stderr
    assert "model 'ckpt'" in r3.stdout and "model 'ckpt#2'" in r3.stdout
    assert "aggregate" in r3.stdout


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree), blocking=True)
    assert mgr.all_steps() == [2, 3]  # retention
    out = mgr.restore(3, tree)
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(tree["a"]) * 3)
    assert out["nested"]["b"].dtype == jnp.bfloat16


def test_sharding_context_noop_without_mesh():
    from repro.distributed.sharding import shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "embed") is x


def test_moe_capacity_drop_keeps_residual_scale():
    """Dropped tokens must produce zero update (residual carries them)."""
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16,
                      n_experts=4, n_experts_active=4, capacity_factor=0.26)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y = moe_ffn(p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    cfg2 = cfg.with_(capacity_factor=8.0)
    y2 = moe_ffn(p, cfg2, x)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y2)) + 1e-3


def test_moe_group_invariance_matches_single_group():
    """Dispatch groups are a parallelization detail: results must match the
    single-group reference when capacity is ample."""
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16,
                      n_experts=4, n_experts_active=2, capacity_factor=8.0,
                      moe_groups=1)
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
    y1 = moe_ffn(p, cfg, x)
    y4 = moe_ffn(p, cfg.with_(moe_groups=4), x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_elastic_restore_across_meshes(tmp_path):
    """A checkpoint saved under one sharding restores under another."""
    cfg = smoke(get_config("qwen1.5-0.5b"))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, params, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models.params import param_shardings
    with sharding_context(mesh, make_rules(mesh)), mesh:
        shardings = param_shardings(params)
        restored = mgr.restore(1, params, shardings)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_detection():
    import time
    from repro.distributed.fault import StepTimer
    t = StepTimer(window=50, threshold=3.0)
    for i in range(12):
        t.start()
        time.sleep(0.001)
        t.stop(i)
    t.start()
    time.sleep(0.05)
    t.stop(99)
    assert any(e["step"] == 99 for e in t.events)


def test_hlo_roofline_analyzer_on_known_program():
    """The HLO analyzer must recover while-loop trip counts and dot FLOPs."""
    from repro.launch.roofline import HloAnalyzer

    def f(ws, x):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jnp.ones((12, 64, 64))
    x = jnp.ones((32, 64))
    compiled = jax.jit(f).lower(ws, x).compile()
    cost = HloAnalyzer(compiled.as_text()).cost()
    expected = 2 * 12 * 32 * 64 * 64
    assert abs(cost.flops - expected) / expected < 0.05, (cost.flops, expected)
