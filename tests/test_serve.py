"""Serving-engine tests: bucketed microbatching, padded-batch parity with
direct inference, async submit/result, online learning from the feedback
stream, multi-model routing/fairness/adaptive buckets, bit-exact
served-learning parity (incl. in-deployment rewire), and the
padded-evaluation / masked-infer mechanics it rides on."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_model, load_models
from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import (
    Trainer, infer, init_deep, init_projection, online_learn_step,
    spec_from_dict, spec_to_dict, supervised_readout_step,
)
from repro.serve import (
    BCPNNService, ServeMetrics, StreamSpec, cycle_batch, default_buckets,
    pad_group, pick_bucket, run_multi_open_loop, run_open_loop,
)
from repro.data.synthetic import encode_images, make_synthetic


def _small_net(depth=1, backend="jnp", seed=0, side=6, n_classes=3):
    spec = deep_synth_spec(side=side, depth=depth, n_classes=n_classes,
                           hidden_hc=4, hidden_mc=8, backend=backend)
    return spec, init_deep(spec, jax.random.PRNGKey(seed))


# ------------------------------------------------------------- batching --

def test_default_buckets_and_pick():
    assert default_buckets(16) == (1, 2, 4, 8, 16)
    assert default_buckets(12) == (1, 2, 4, 8, 12)
    assert pick_bucket(3, (1, 2, 4, 8)) == 4
    assert pick_bucket(8, (1, 2, 4, 8)) == 8
    with pytest.raises(ValueError):
        pick_bucket(9, (1, 2, 4, 8))


def test_pad_group_shapes_and_mask():
    xs = [np.full((5,), i, np.float32) for i in range(3)]
    x, valid = pad_group(xs, 8)
    assert x.shape == (8, 5) and valid.shape == (8,)
    np.testing.assert_array_equal(valid, [1, 1, 1, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(x[3:], 0.0)
    np.testing.assert_array_equal(x[1], 1.0)


def test_infer_valid_mask_makes_pad_rows_inert():
    spec, state = _small_net()
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, spec.input_geom.N))
    valid = jnp.array([1, 1, 1, 1, 1, 0, 0, 0], jnp.float32)
    probs_m, pred_m = infer(state, spec, x, valid=valid)
    probs, pred = infer(state, spec, x[:5])
    # genuine rows unchanged vs the unpadded call...
    np.testing.assert_allclose(np.asarray(probs_m)[:5], np.asarray(probs),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(pred_m)[:5], np.asarray(pred))
    # ...pad rows visibly inert
    np.testing.assert_array_equal(np.asarray(pred_m)[5:], -1)
    np.testing.assert_array_equal(np.asarray(probs_m)[5:], 0.0)


# --------------------------------------------------------------- engine --

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_served_results_match_direct_infer(backend):
    """A request served through a padded shape bucket must equal the
    direct unbatched infer — padding can never leak into results."""
    spec, state = _small_net(backend=backend)
    xs = np.asarray(jax.random.uniform(jax.random.PRNGKey(2),
                                       (5, spec.input_geom.N)))
    svc = BCPNNService(state, spec, max_batch=8).start()
    try:
        got = [svc.classify(x) for x in xs]  # singles -> bucket 1 or padded
        ids = [svc.submit(x) for x in xs]    # burst -> one padded bucket
        got += [svc.result(i, timeout=30) for i in ids]
    finally:
        svc.stop()
    probs_ref, pred_ref = infer(state, spec, jnp.asarray(xs))
    for k, r in enumerate(got):
        i = k % 5
        assert r.pred == int(pred_ref[i])
        np.testing.assert_allclose(r.probs, np.asarray(probs_ref)[i],
                                   atol=1e-5)
        assert r.latency_ms >= 0.0


def test_async_submit_from_many_threads_all_complete():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=8).start()
    ids = []
    lock = threading.Lock()
    x = np.ones((spec.input_geom.N,), np.float32)

    def client(n):
        for _ in range(n):
            rid = svc.submit(x)
            with lock:
                ids.append(rid)

    threads = [threading.Thread(target=client, args=(10,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    results = [svc.result(rid, timeout=30) for rid in ids]
    svc.stop()
    assert len(results) == 40
    assert len({r.request_id for r in results}) == 40
    snap = svc.snapshot()
    assert snap["completed"] == snap["submitted"] == 40
    assert snap["queue_depth"] == 0
    assert 0 < snap["p50_ms"] <= snap["p99_ms"]
    assert 0 < snap["batch_occupancy"] <= 1


def test_feedback_requires_online_mode():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4)
    with pytest.raises(RuntimeError, match="online_learning"):
        svc.feedback(np.zeros((spec.input_geom.N,), np.float32), 0)
    with pytest.raises(RuntimeError, match="not running"):
        svc.submit(np.zeros((spec.input_geom.N,), np.float32))


def test_stop_racing_submits_never_strands_a_request():
    """Admissions racing stop() must either raise or be served — a
    successfully-submitted id always resolves (no forever-pending slot)."""
    spec, state = _small_net()
    x = np.ones((spec.input_geom.N,), np.float32)
    for trial in range(3):
        # retention high enough that eviction can't race the collection
        # loop below on a loaded machine (clients can admit thousands of
        # cheap submits in the window); eviction has its own test.
        svc = BCPNNService(state, spec, max_batch=4, max_wait_ms=0.5,
                           result_retention=1_000_000)
        svc.start(warmup=(trial == 0))
        ids, done = [], threading.Event()
        lock = threading.Lock()

        def client():
            while not done.is_set():
                try:
                    rid = svc.submit(x)
                except RuntimeError:
                    return  # stopped: admission correctly refused
                with lock:
                    ids.append(rid)

        threads = [threading.Thread(target=client) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.02)
        svc.stop()
        done.set()
        for t in threads:
            t.join()
        for rid in ids:  # every admitted request must have completed
            r = svc.result(rid, timeout=10)
            assert r.pred >= 0
        assert len(svc._requests) == 0  # registry fully drained


def test_stop_drains_entire_feedback_buffer():
    """Regression: stop() must flush ALL buffered feedback (one learn
    batch at a time), not just one fold — a bursty label stream must not
    lose its tail at shutdown."""
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       feedback_batch=16).start()
    x = np.ones((spec.input_geom.N,), np.float32)
    for i in range(100):
        svc.feedback(x, i % 3)
    svc.stop()
    snap = svc.snapshot()
    assert snap["learn_samples"] == 100, snap
    assert snap["learn_steps"] >= 100 // 16
    assert len(svc._feedback) == 0
    with pytest.raises(RuntimeError, match="not running"):
        svc.feedback(x, 0)


def test_online_learning_improves_readout_under_traffic():
    """Cold readout + feedback stream: served accuracy and eval accuracy
    must rise while every inference request still completes."""
    ds = make_synthetic(768, 256, 8, 4, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=8, depth=2, n_classes=4, hidden_hc=8,
                           hidden_mc=16)
    tr = Trainer(spec, seed=0)
    tr.fit(xt, ds.y_train, epochs=6, batch=64)
    assert tr.evaluate(xe, ds.y_test, batch=64) > 0.4  # sane baseline
    acc_trained = tr.evaluate(xe, ds.y_test, batch=64)
    cold = dataclasses.replace(
        tr.state, readout=init_projection(spec.readout,
                                          jax.random.PRNGKey(7)))
    svc = BCPNNService(cold, spec, max_batch=8, online_learning=True,
                       feedback_batch=16).start()
    rep = run_open_loop(svc, xe, ds.y_test, n_requests=160, rate_hz=800,
                        seed=2, feedback_frac=1.0, fb_x=xt, fb_y=ds.y_train)
    svc.stop()
    snap = svc.snapshot()
    assert snap["completed"] == 160, "online learning dropped requests"
    assert snap["learn_steps"] > 0
    tr.state = svc.state
    acc_online = tr.evaluate(xe, ds.y_test, batch=64)
    tr.state = cold
    acc_cold = tr.evaluate(xe, ds.y_test, batch=64)
    assert acc_online > acc_cold + 0.1, (acc_cold, acc_online)
    # the relearned readout should approach the offline-trained baseline
    assert acc_online > acc_trained - 0.25, (acc_trained, acc_online)
    assert len(rep.results) == 160


# ------------------------------------------------- padded eval + ckpt ----

def test_trainer_evaluate_covers_full_eval_set():
    """evaluate() must score every sample: a tail smaller than the batch
    is padded + masked, not dropped, and matches a predict()-based count."""
    ds = make_synthetic(256, 100, 6, 3, seed=1)  # 100 % 64 != 0
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                           hidden_mc=8)
    tr = Trainer(spec, seed=0)
    tr.fit(xt, ds.y_train, epochs=1, batch=64)
    acc = tr.evaluate(xe, ds.y_test, batch=64)
    ref = float(np.mean(tr.predict(xe) == ds.y_test))
    assert acc == pytest.approx(ref, abs=1e-6)
    # smaller-than-one-batch eval sets must work too
    acc_small = tr.evaluate(xe[:10], ds.y_test[:10], batch=64)
    ref_small = float(np.mean(tr.predict(xe[:10]) == ds.y_test[:10]))
    assert acc_small == pytest.approx(ref_small, abs=1e-6)


def test_spec_roundtrip_and_checkpoint_extra(tmp_path):
    spec = deep_synth_spec(side=6, depth=2, n_classes=3, hidden_hc=4,
                           hidden_mc=8, nact=[9, None], backend="pallas")
    assert spec_from_dict(spec_to_dict(spec)) == spec
    state = init_deep(spec, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True, extra={"spec": spec_to_dict(spec)})
    spec2 = spec_from_dict(mgr.read_extra(3)["spec"])
    assert spec2 == spec
    restored = mgr.restore(3, init_deep(spec2, jax.random.PRNGKey(1)))
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.read_extra(3) is not None
    mgr.save(4, state, blocking=True)
    assert mgr.read_extra(4) is None


# ------------------------------------------------- multi-model routing ----

def test_multi_model_routing_matches_each_direct_infer():
    """Two models with DIFFERENT geometries behind one admission front:
    every request routes to its own model's compiled buckets and matches
    that model's direct infer; results carry the model name."""
    spec_a, state_a = _small_net(depth=1, seed=0, side=6, n_classes=3)
    spec_b, state_b = _small_net(depth=2, seed=1, side=5, n_classes=4)
    xa = np.asarray(jax.random.uniform(jax.random.PRNGKey(2),
                                       (6, spec_a.input_geom.N)))
    xb = np.asarray(jax.random.uniform(jax.random.PRNGKey(3),
                                       (6, spec_b.input_geom.N)))
    svc = BCPNNService.multi({"a": (state_a, spec_a),
                              "b": (state_b, spec_b)}, max_batch=4).start()
    try:
        assert svc.models() == ("a", "b")
        ids_a = [svc.submit(x, model="a") for x in xa]
        ids_b = [svc.submit(x, model="b") for x in xb]
        got_a = [svc.result(i, timeout=30) for i in ids_a]
        got_b = [svc.result(i, timeout=30) for i in ids_b]
        one = svc.classify(xb[0], timeout=30, model="b")
    finally:
        svc.stop()
    pa, ra = infer(state_a, spec_a, jnp.asarray(xa))
    pb, rb = infer(state_b, spec_b, jnp.asarray(xb))
    for i, r in enumerate(got_a):
        assert r.model == "a" and r.pred == int(ra[i])
        np.testing.assert_allclose(r.probs, np.asarray(pa)[i], atol=1e-5)
    for i, r in enumerate(got_b):
        assert r.model == "b" and r.pred == int(rb[i])
        np.testing.assert_allclose(r.probs, np.asarray(pb)[i], atol=1e-5)
    assert one.pred == int(rb[0])


def test_multi_model_requires_and_validates_model_names():
    spec, state = _small_net()
    svc = BCPNNService.multi({"a": (state, spec), "b": (state, spec)},
                             max_batch=4, online_learning=True)
    x = np.zeros((spec.input_geom.N,), np.float32)
    with pytest.raises(ValueError, match="pass model="):
        svc.submit(x)
    with pytest.raises(KeyError, match="unknown model"):
        svc.submit(x, model="nope")
    with pytest.raises(KeyError, match="unknown model"):
        svc.feedback(x, 0, model="nope")
    with pytest.raises(ValueError, match="pass model"):
        _ = svc.state
    # single-model services keep the no-name convenience
    svc1 = BCPNNService(state, spec, max_batch=4)
    assert svc1.model_state() is state
    assert svc1.spec == spec


def test_model_registration_rules():
    spec, state = _small_net()
    with pytest.raises(ValueError, match="at least one"):
        BCPNNService.multi({})
    svc = BCPNNService(state, spec, max_batch=4, name="a")
    with pytest.raises(ValueError, match="already registered"):
        svc.add_model("a", state, spec)
    svc.start()
    try:
        with pytest.raises(RuntimeError, match="running"):
            svc.add_model("late", state, spec)
    finally:
        svc.stop()


def test_per_model_metrics_and_aggregate_snapshot():
    spec_a, state_a = _small_net(seed=0)
    spec_b, state_b = _small_net(seed=1)
    svc = BCPNNService.multi({"a": (state_a, spec_a),
                              "b": (state_b, spec_b)}, max_batch=4).start()
    x = np.ones((spec_a.input_geom.N,), np.float32)
    try:
        ids = [svc.submit(x, model="a") for _ in range(7)]
        ids += [svc.submit(x, model="b") for _ in range(3)]
        for rid in ids:
            svc.result(rid, timeout=30)
    finally:
        svc.stop()
    snap = svc.snapshot()
    assert snap["completed"] == snap["submitted"] == 10
    assert snap["per_model"]["a"]["completed"] == 7
    assert snap["per_model"]["b"]["completed"] == 3
    assert svc.snapshot(model="a")["submitted"] == 7
    assert 0 < snap["p50_ms"] <= snap["p99_ms"]
    for name in ("a", "b"):
        per = snap["per_model"][name]
        assert 0 < per["batch_occupancy"] <= 1
        assert per["target_bucket"] >= 1


def test_round_robin_scheduler_never_starves_minority():
    """Deterministic scheduler-level fairness: with a 12-vs-2 backlog the
    minority model's group is scheduled within the first two picks, not
    behind the majority's whole backlog (what a shared FIFO would do)."""
    from repro.serve import Request

    spec, state = _small_net()
    svc = BCPNNService.multi({"a": (state, spec), "b": (state, spec)},
                             max_batch=4, max_wait_ms=0.0, poll_ms=1.0)
    x = np.zeros((spec.input_geom.N,), np.float32)
    for i in range(12):
        svc._slots["a"].batcher.put(Request(id=i, x=x, enqueue_t=0.0,
                                            model="a"))
    for i in range(2):
        svc._slots["b"].batcher.put(Request(id=100 + i, x=x, enqueue_t=0.0,
                                            model="b"))
    order = []
    while True:
        group, slot = svc._next_work()
        if not group:
            break
        order.append((slot.name, len(group)))
    names = [n for n, _ in order]
    assert names.index("b") <= 1, names
    assert sum(k for n, k in order if n == "a") == 12
    assert sum(k for n, k in order if n == "b") == 2


def test_fairness_under_skewed_load_fast():
    """10:1 skewed Poisson mix through a live engine: the minority model
    completes everything, promptly (small smoke-scale sibling of the
    slow soak in test_serve_soak.py)."""
    spec_a, state_a = _small_net(seed=0)
    spec_b, state_b = _small_net(seed=1)
    xe = np.asarray(jax.random.uniform(jax.random.PRNGKey(5),
                                       (32, spec_a.input_geom.N)))
    ye = np.zeros((32,), np.int64)
    svc = BCPNNService.multi({"major": (state_a, spec_a),
                              "minor": (state_b, spec_b)},
                             max_batch=8, max_wait_ms=2.0).start()
    try:
        reports = run_multi_open_loop(
            svc,
            {"major": StreamSpec(xe, ye, rate_hz=400.0),
             "minor": StreamSpec(xe, ye, rate_hz=40.0)},
            n_requests=120, seed=0)
    finally:
        svc.stop()
    snap = svc.snapshot()
    assert snap["completed"] == snap["submitted"] == 120
    n_minor = len(reports["minor"].results)
    assert n_minor > 0
    assert snap["per_model"]["minor"]["completed"] == n_minor
    assert reports["minor"].max_latency_ms < 5000.0


def test_run_multi_open_loop_validates_streams():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4)
    with pytest.raises(ValueError, match="at least one"):
        run_multi_open_loop(svc, {}, n_requests=1)
    with pytest.raises(ValueError, match="rate_hz > 0"):
        run_multi_open_loop(
            svc, {"a": StreamSpec(np.zeros((1, 4)), np.zeros(1),
                                  rate_hz=0.0)}, n_requests=1)


# ------------------------------------------------- adaptive buckets ------

def test_adaptive_target_bucket_tracks_arrival_rate():
    """The active bucket follows the observed windows: no history -> the
    smallest bucket (don't dawdle), moderate rate -> a matching middle
    bucket, saturation or a large recent group -> the largest."""
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=16, max_wait_ms=10.0,
                       poll_ms=10.0)
    slot = svc._slots["default"]
    svc._adapt(slot)
    assert slot.target_bucket == 1          # no arrivals observed yet
    assert svc.active_buckets() == (1,)
    for k in range(64):                     # ~100 Hz arrival window
        slot.metrics.record_submit(now=k * 0.01)
    svc._adapt(slot)
    # 100 Hz * 20 ms window * 1.5 headroom = 3 -> bucket 4
    assert slot.target_bucket == 4
    assert svc.active_buckets() == (1, 2, 4)
    burst = ServeMetrics()
    for k in range(64):                     # saturating ~100 kHz burst
        burst.record_submit(now=k * 1e-5)
    slot.metrics = burst
    svc._adapt(slot)
    assert slot.target_bucket == 16
    # occupancy floor: a rate lull must not shrink below recent groups
    slow = ServeMetrics()
    for k in range(8):
        slow.record_submit(now=k * 1.0)     # 1 Hz
        slow.record_batch(n_valid=8, bucket=8)
    slot.metrics = slow
    svc._adapt(slot)
    assert slot.target_bucket == 8


def test_adaptive_buckets_can_be_disabled():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=16, adaptive_buckets=False)
    slot = svc._slots["default"]
    svc._adapt(slot)
    assert slot.target_bucket == 16
    assert svc.active_buckets() == (1, 2, 4, 8, 16)


def test_adaptive_serving_still_completes_bursts():
    """End-to-end with adaptation on (the default): a cold burst larger
    than the startup target bucket is still served completely and
    correctly (backlog overrides the cap)."""
    spec, state = _small_net()
    xs = np.asarray(jax.random.uniform(jax.random.PRNGKey(4),
                                       (24, spec.input_geom.N)))
    svc = BCPNNService(state, spec, max_batch=8).start()
    try:
        ids = [svc.submit(x) for x in xs]
        got = [svc.result(i, timeout=30) for i in ids]
    finally:
        svc.stop()
    _, ref = infer(state, spec, jnp.asarray(xs))
    assert [r.pred for r in got] == [int(p) for p in np.asarray(ref)]
    snap = svc.snapshot()
    assert snap["completed"] == 24


# ------------------------------------- served-learning parity (bitwise) --

def _feedback_stream(spec, n, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.random((n, spec.input_geom.N)).astype(np.float32)
    ys = rng.integers(0, spec.n_classes, size=n).astype(np.int32)
    return xs, ys


def _replay_offline(state, spec, xs, ys, batch, learn_stack):
    """Offline reference: the same jitted learn program the engine runs,
    applied to the same feedback stream in the same batch compositions
    (full batches, then one cycled tail — feedback_eager=False)."""
    if learn_stack:
        fn = jax.jit(lambda st, x, y: online_learn_step(
            st, spec, x, y, learn_stack=True))
    else:
        fn = jax.jit(lambda st, x, y: supervised_readout_step(
            st, spec, x, y))
    ref = state
    items = list(zip(xs, ys))
    while items:
        chunk, items = items[:batch], items[batch:]
        x, y = cycle_batch(chunk, batch)
        ref = fn(ref, jnp.asarray(x), jnp.asarray(y))
    return ref


def _assert_states_bitwise_equal(got, want):
    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(got)[0],
            jax.tree_util.tree_flatten_with_path(want)[0]):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"leaf {jax.tree_util.keystr(ka)} diverged")


def test_readout_online_learning_parity_bitwise():
    """Served readout-only learning == offline supervised_readout_step
    replay, bit for bit."""
    spec, state = _small_net(depth=1)
    xs, ys = _feedback_stream(spec, 24, seed=1)
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       feedback_batch=8, feedback_eager=False).start()
    for x, y in zip(xs, ys):
        svc.feedback(x, int(y))
    svc.stop()
    ref = _replay_offline(state, spec, xs, ys, 8, learn_stack=False)
    _assert_states_bitwise_equal(svc.state, ref)


def test_stack_online_learning_parity_dense_bitwise():
    """Served stack+readout learning (learn_stack=True) on a dense
    depth-2 network == offline online_learn_step replay, bit for bit —
    including a cycled short tail batch."""
    spec, state = _small_net(depth=2)
    xs, ys = _feedback_stream(spec, 21, seed=2)  # 2 full batches + tail 5
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       learn_stack=True, feedback_batch=8,
                       feedback_eager=False).start()
    for x, y in zip(xs, ys):
        svc.feedback(x, int(y))
    svc.stop()
    ref = _replay_offline(state, spec, xs, ys, 8, learn_stack=True)
    _assert_states_bitwise_equal(svc.state, ref)
    # the stack actually learned (not a frozen-stack false positive)
    assert int(svc.state.projs[0].traces.t) == 3
    assert not np.array_equal(np.asarray(svc.state.projs[0].w),
                              np.asarray(state.projs[0].w))


@pytest.mark.parametrize("compact", [False, True],
                         ids=["patchy-held", "compact"])
def test_stack_online_learning_parity_with_rewire_bitwise(compact):
    """The acceptance bar: a multi-model engine run with stack learning
    AND triggered struct_every rewires matches the offline replay bit
    for bit, for a dense model and a patchy model (dense-resident held
    traces / compact-resident) served side by side."""
    spec_d, state_d = _small_net(depth=1, seed=3)
    spec_p = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                             hidden_mc=8, nact=[9], patchy_traces=True,
                             compact=compact, struct_every=2)
    state_p = init_deep(spec_p, jax.random.PRNGKey(4))
    fb = 8
    xs_d, ys_d = _feedback_stream(spec_d, 2 * fb, seed=5)
    xs_p, ys_p = _feedback_stream(spec_p, 3 * fb, seed=6)  # t crosses 2
    svc = BCPNNService.multi(
        {"dense": (state_d, spec_d), "patchy": (state_p, spec_p)},
        max_batch=4, online_learning=True, learn_stack=True,
        feedback_batch=fb, feedback_eager=False).start()
    # interleave the two models' label streams through the shared front
    for i in range(max(len(xs_d), len(xs_p))):
        if i < len(xs_d):
            svc.feedback(xs_d[i], int(ys_d[i]), model="dense")
        if i < len(xs_p):
            svc.feedback(xs_p[i], int(ys_p[i]), model="patchy")
    svc.stop()
    svc.revalidate()  # mask/table invariants survived the served rewires
    got_p = svc.model_state("patchy")
    assert int(got_p.projs[0].traces.t) == 3  # crossed the t=2 boundary
    ref_d = _replay_offline(state_d, spec_d, xs_d, ys_d, fb,
                            learn_stack=True)
    ref_p = _replay_offline(state_p, spec_p, xs_p, ys_p, fb,
                            learn_stack=True)
    _assert_states_bitwise_equal(svc.model_state("dense"), ref_d)
    _assert_states_bitwise_equal(got_p, ref_p)
    if compact:
        assert got_p.projs[0].table is not None
        assert got_p.projs[0].traces.pij.ndim == 3


def test_cycle_batch_composition():
    items = [(np.full((2,), i, np.float32), i) for i in range(3)]
    x, y = cycle_batch(items, 8)
    assert x.shape == (8, 2) and y.shape == (8,)
    np.testing.assert_array_equal(y, [0, 1, 2, 0, 1, 2, 0, 1])
    np.testing.assert_array_equal(x[:, 0], y.astype(np.float32))


# ------------------------------------------- concurrency + retention ------

def test_concurrency_stress_no_lost_or_double_completed_ids():
    """N producers + a feedback client + a metrics poller hammering a
    live engine with a racing stop: every admitted id resolves exactly
    once, none double-complete, counters reconcile."""
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=8, max_wait_ms=0.5,
                       online_learning=True, feedback_batch=4,
                       result_retention=1 << 20).start()
    x = np.ones((spec.input_geom.N,), np.float32)
    ids = [[] for _ in range(4)]
    errs = []
    done = threading.Event()

    def producer(k):
        while not done.is_set():
            try:
                ids[k].append(svc.submit(x))
            except RuntimeError:
                return

    def fb_client():
        while not done.is_set():
            try:
                svc.feedback(x, 1)
            except RuntimeError:
                return
            time.sleep(0.001)

    def poller():
        while not done.is_set():
            try:
                snap = svc.snapshot()
                if snap["completed"] > snap["submitted"]:
                    errs.append(snap)
            except Exception as e:  # pragma: no cover - should not happen
                errs.append(e)
            time.sleep(0.002)

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(4)]
    threads += [threading.Thread(target=fb_client),
                threading.Thread(target=poller)]
    for t in threads:
        t.start()
    time.sleep(0.15)
    svc.stop()          # races the producers' submits
    done.set()
    for t in threads:
        t.join()
    all_ids = [rid for sub in ids for rid in sub]
    assert len(all_ids) == len(set(all_ids)), "duplicate request ids"
    results = [svc.result(rid, timeout=10) for rid in all_ids]
    assert sorted(r.request_id for r in results) == sorted(all_ids)
    assert all(r.pred >= 0 for r in results)
    assert len(svc._requests) == 0, "registry not drained"
    assert not errs, errs
    snap = svc.snapshot()
    assert snap["completed"] == snap["submitted"] == len(all_ids)


def test_result_retention_evicts_oldest_uncollected():
    """Fire-and-forget submitters cannot grow the registry: only the most
    recent ``result_retention`` completed-but-uncollected results stay
    collectable; older ids are forgotten."""
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4, result_retention=8).start()
    x = np.ones((spec.input_geom.N,), np.float32)
    ids = [svc.submit(x) for _ in range(30)]
    svc.stop()  # drains: everything completed
    assert len(svc._requests) <= 8
    for rid in ids[-4:]:        # newest still collectable
        assert svc.result(rid, timeout=5).pred >= 0
    with pytest.raises(KeyError):
        svc.result(ids[0], timeout=5)


# ------------------------------------------------- multi-model loading ----

def test_load_model_from_checkpoint_dir_alone(tmp_path):
    spec = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                           hidden_mc=8)
    tr = Trainer(spec, seed=0)
    d = str(tmp_path / "m0")
    tr.save(d)
    state, spec2, step = load_model(d)
    assert spec2 == spec
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(tr.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(FileNotFoundError):
        load_model(str(tmp_path / "missing"))
    # spec-less manifests are refused, not silently misloaded
    bare = str(tmp_path / "bare")
    CheckpointManager(bare).save(1, tr.state, blocking=True)
    with pytest.raises(ValueError, match="no spec metadata"):
        load_model(bare)


def test_load_models_names_and_dedup(tmp_path):
    spec = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                           hidden_mc=8)
    tr = Trainer(spec, seed=0)
    d = str(tmp_path / "modelA")
    tr.save(d)
    models = load_models([d, d])
    assert set(models) == {"modelA", "modelA#2"}
    svc = BCPNNService.multi(models, max_batch=4).start()
    try:
        r = svc.classify(np.zeros((spec.input_geom.N,), np.float32),
                         timeout=30, model="modelA#2")
        assert r.model == "modelA#2"
    finally:
        svc.stop()
