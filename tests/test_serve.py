"""Serving-engine tests: bucketed microbatching, padded-batch parity with
direct inference, async submit/result, online learning from the feedback
stream, and the padded-evaluation / masked-infer mechanics it rides on."""
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import (
    Trainer, infer, init_deep, init_projection, spec_from_dict, spec_to_dict,
)
from repro.data.synthetic import encode_images, make_synthetic
from repro.serve import (
    BCPNNService, default_buckets, pad_group, pick_bucket, run_open_loop,
)


def _small_net(depth=1, backend="jnp", seed=0, side=6, n_classes=3):
    spec = deep_synth_spec(side=side, depth=depth, n_classes=n_classes,
                           hidden_hc=4, hidden_mc=8, backend=backend)
    return spec, init_deep(spec, jax.random.PRNGKey(seed))


# ------------------------------------------------------------- batching --

def test_default_buckets_and_pick():
    assert default_buckets(16) == (1, 2, 4, 8, 16)
    assert default_buckets(12) == (1, 2, 4, 8, 12)
    assert pick_bucket(3, (1, 2, 4, 8)) == 4
    assert pick_bucket(8, (1, 2, 4, 8)) == 8
    with pytest.raises(ValueError):
        pick_bucket(9, (1, 2, 4, 8))


def test_pad_group_shapes_and_mask():
    xs = [np.full((5,), i, np.float32) for i in range(3)]
    x, valid = pad_group(xs, 8)
    assert x.shape == (8, 5) and valid.shape == (8,)
    np.testing.assert_array_equal(valid, [1, 1, 1, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(x[3:], 0.0)
    np.testing.assert_array_equal(x[1], 1.0)


def test_infer_valid_mask_makes_pad_rows_inert():
    spec, state = _small_net()
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, spec.input_geom.N))
    valid = jnp.array([1, 1, 1, 1, 1, 0, 0, 0], jnp.float32)
    probs_m, pred_m = infer(state, spec, x, valid=valid)
    probs, pred = infer(state, spec, x[:5])
    # genuine rows unchanged vs the unpadded call...
    np.testing.assert_allclose(np.asarray(probs_m)[:5], np.asarray(probs),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(pred_m)[:5], np.asarray(pred))
    # ...pad rows visibly inert
    np.testing.assert_array_equal(np.asarray(pred_m)[5:], -1)
    np.testing.assert_array_equal(np.asarray(probs_m)[5:], 0.0)


# --------------------------------------------------------------- engine --

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_served_results_match_direct_infer(backend):
    """A request served through a padded shape bucket must equal the
    direct unbatched infer — padding can never leak into results."""
    spec, state = _small_net(backend=backend)
    xs = np.asarray(jax.random.uniform(jax.random.PRNGKey(2),
                                       (5, spec.input_geom.N)))
    svc = BCPNNService(state, spec, max_batch=8).start()
    try:
        got = [svc.classify(x) for x in xs]  # singles -> bucket 1 or padded
        ids = [svc.submit(x) for x in xs]    # burst -> one padded bucket
        got += [svc.result(i, timeout=30) for i in ids]
    finally:
        svc.stop()
    probs_ref, pred_ref = infer(state, spec, jnp.asarray(xs))
    for k, r in enumerate(got):
        i = k % 5
        assert r.pred == int(pred_ref[i])
        np.testing.assert_allclose(r.probs, np.asarray(probs_ref)[i],
                                   atol=1e-5)
        assert r.latency_ms >= 0.0


def test_async_submit_from_many_threads_all_complete():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=8).start()
    ids = []
    lock = threading.Lock()
    x = np.ones((spec.input_geom.N,), np.float32)

    def client(n):
        for _ in range(n):
            rid = svc.submit(x)
            with lock:
                ids.append(rid)

    threads = [threading.Thread(target=client, args=(10,)) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    results = [svc.result(rid, timeout=30) for rid in ids]
    svc.stop()
    assert len(results) == 40
    assert len({r.request_id for r in results}) == 40
    snap = svc.snapshot()
    assert snap["completed"] == snap["submitted"] == 40
    assert snap["queue_depth"] == 0
    assert 0 < snap["p50_ms"] <= snap["p99_ms"]
    assert 0 < snap["batch_occupancy"] <= 1


def test_feedback_requires_online_mode():
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4)
    with pytest.raises(RuntimeError, match="online_learning"):
        svc.feedback(np.zeros((spec.input_geom.N,), np.float32), 0)
    with pytest.raises(RuntimeError, match="not running"):
        svc.submit(np.zeros((spec.input_geom.N,), np.float32))


def test_stop_racing_submits_never_strands_a_request():
    """Admissions racing stop() must either raise or be served — a
    successfully-submitted id always resolves (no forever-pending slot)."""
    spec, state = _small_net()
    x = np.ones((spec.input_geom.N,), np.float32)
    for trial in range(3):
        svc = BCPNNService(state, spec, max_batch=4, max_wait_ms=0.5)
        svc.start(warmup=(trial == 0))
        ids, done = [], threading.Event()
        lock = threading.Lock()

        def client():
            while not done.is_set():
                try:
                    rid = svc.submit(x)
                except RuntimeError:
                    return  # stopped: admission correctly refused
                with lock:
                    ids.append(rid)

        threads = [threading.Thread(target=client) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.02)
        svc.stop()
        done.set()
        for t in threads:
            t.join()
        for rid in ids:  # every admitted request must have completed
            r = svc.result(rid, timeout=10)
            assert r.pred >= 0
        assert len(svc._requests) == 0  # registry fully drained


def test_stop_drains_entire_feedback_buffer():
    """Regression: stop() must flush ALL buffered feedback (one learn
    batch at a time), not just one fold — a bursty label stream must not
    lose its tail at shutdown."""
    spec, state = _small_net()
    svc = BCPNNService(state, spec, max_batch=4, online_learning=True,
                       feedback_batch=16).start()
    x = np.ones((spec.input_geom.N,), np.float32)
    for i in range(100):
        svc.feedback(x, i % 3)
    svc.stop()
    snap = svc.snapshot()
    assert snap["learn_samples"] == 100, snap
    assert snap["learn_steps"] >= 100 // 16
    assert len(svc._feedback) == 0
    with pytest.raises(RuntimeError, match="not running"):
        svc.feedback(x, 0)


def test_online_learning_improves_readout_under_traffic():
    """Cold readout + feedback stream: served accuracy and eval accuracy
    must rise while every inference request still completes."""
    ds = make_synthetic(768, 256, 8, 4, seed=3, max_shift=1)
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=8, depth=2, n_classes=4, hidden_hc=8,
                           hidden_mc=16)
    tr = Trainer(spec, seed=0)
    tr.fit(xt, ds.y_train, epochs=6, batch=64)
    assert tr.evaluate(xe, ds.y_test, batch=64) > 0.4  # sane baseline
    acc_trained = tr.evaluate(xe, ds.y_test, batch=64)
    cold = dataclasses.replace(
        tr.state, readout=init_projection(spec.readout,
                                          jax.random.PRNGKey(7)))
    svc = BCPNNService(cold, spec, max_batch=8, online_learning=True,
                       feedback_batch=16).start()
    rep = run_open_loop(svc, xe, ds.y_test, n_requests=160, rate_hz=800,
                        seed=2, feedback_frac=1.0, fb_x=xt, fb_y=ds.y_train)
    svc.stop()
    snap = svc.snapshot()
    assert snap["completed"] == 160, "online learning dropped requests"
    assert snap["learn_steps"] > 0
    tr.state = svc.state
    acc_online = tr.evaluate(xe, ds.y_test, batch=64)
    tr.state = cold
    acc_cold = tr.evaluate(xe, ds.y_test, batch=64)
    assert acc_online > acc_cold + 0.1, (acc_cold, acc_online)
    # the relearned readout should approach the offline-trained baseline
    assert acc_online > acc_trained - 0.25, (acc_trained, acc_online)
    assert len(rep.results) == 160


# ------------------------------------------------- padded eval + ckpt ----

def test_trainer_evaluate_covers_full_eval_set():
    """evaluate() must score every sample: a tail smaller than the batch
    is padded + masked, not dropped, and matches a predict()-based count."""
    ds = make_synthetic(256, 100, 6, 3, seed=1)  # 100 % 64 != 0
    xt, xe = encode_images(ds.x_train), encode_images(ds.x_test)
    spec = deep_synth_spec(side=6, depth=1, n_classes=3, hidden_hc=4,
                           hidden_mc=8)
    tr = Trainer(spec, seed=0)
    tr.fit(xt, ds.y_train, epochs=1, batch=64)
    acc = tr.evaluate(xe, ds.y_test, batch=64)
    ref = float(np.mean(tr.predict(xe) == ds.y_test))
    assert acc == pytest.approx(ref, abs=1e-6)
    # smaller-than-one-batch eval sets must work too
    acc_small = tr.evaluate(xe[:10], ds.y_test[:10], batch=64)
    ref_small = float(np.mean(tr.predict(xe[:10]) == ds.y_test[:10]))
    assert acc_small == pytest.approx(ref_small, abs=1e-6)


def test_spec_roundtrip_and_checkpoint_extra(tmp_path):
    spec = deep_synth_spec(side=6, depth=2, n_classes=3, hidden_hc=4,
                           hidden_mc=8, nact=[9, None], backend="pallas")
    assert spec_from_dict(spec_to_dict(spec)) == spec
    state = init_deep(spec, jax.random.PRNGKey(0))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, state, blocking=True, extra={"spec": spec_to_dict(spec)})
    spec2 = spec_from_dict(mgr.read_extra(3)["spec"])
    assert spec2 == spec
    restored = mgr.restore(3, init_deep(spec2, jax.random.PRNGKey(1)))
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.read_extra(3) is not None
    mgr.save(4, state, blocking=True)
    assert mgr.read_extra(4) is None
