"""Data-parallel shard_map train steps: the trace all-reduce must be
EXACT — the multi-device step reproduces the single-device streaming step
bit-for-bit (np.array_equal on every state leaf, no tolerance), for
dense, patchy-held and compact-resident projections.  The decomposition
that makes this possible (full-batch contraction on disjoint post-column
shards, so the psum adds one real partial and zeros per element) is
documented in distributed/data_parallel.py.  Both sides run under jit
(the trainer always jits the step); the canonical step pins its stat and
noise seams with optimization_barrier so the two programs compile the
identical per-element arithmetic.  Runs on the 2-device host CPU mesh set
up by conftest.py."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hypercolumns import LayerGeom
from repro.core.network import (
    init_deep,
    make_network_spec,
    supervised_readout_step,
    unsupervised_layer_step,
)
from repro.distributed import (
    make_data_parallel_supervised_step,
    make_data_parallel_unsupervised_step,
)

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs the 2-device CPU mesh (conftest "
    "sets --xla_force_host_platform_device_count=2)")


def _mesh():
    return jax.make_mesh((2,), ("data",))


_single_unsup = jax.jit(functools.partial(unsupervised_layer_step, layer=0),
                        static_argnames=("spec",))
_single_sup = jax.jit(supervised_readout_step, static_argnames=("spec",))


def _spec(kind: str, struct_every: int = 0):
    """Depth-1 network with Hj divisible by the 2-way data axis."""
    kwargs = dict(alpha=1e-2, backend="jnp", support_noise=2.0,
                  noise_steps=50, struct_every=struct_every)
    if kind == "dense":
        return make_network_spec(LayerGeom(12, 2), [(6, 8)], n_classes=3,
                                 **kwargs)
    if kind == "patchy":
        return make_network_spec(LayerGeom(12, 2), [(6, 8)], n_classes=3,
                                 nact=[4], patchy_traces=True, **kwargs)
    assert kind == "compact"
    return make_network_spec(LayerGeom(12, 2), [(6, 8)], n_classes=3,
                             nact=[4], patchy_traces=True, compact=True,
                             **kwargs)


def _assert_states_equal(got, want, context=""):
    flat_g, _ = jax.tree_util.tree_flatten_with_path(got)
    flat_w, _ = jax.tree_util.tree_flatten_with_path(want)
    assert len(flat_g) == len(flat_w)
    for (path, g), (_, w) in zip(flat_g, flat_w):
        name = jax.tree_util.keystr(path)
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"{context}: leaf {name} diverged (max abs diff "
            f"{np.max(np.abs(np.asarray(g, np.float64) - np.asarray(w, np.float64)))})")


@pytest.mark.parametrize("kind", ["dense", "patchy", "compact"])
def test_dp_unsupervised_matches_single_device_bitwise(kind):
    spec = _spec(kind)
    state = init_deep(spec, jax.random.PRNGKey(0))
    dp_step = make_data_parallel_unsupervised_step(spec, _mesh(), layer=0)
    state_dp = jax.tree.map(jnp.array, state)
    for i, k in enumerate(jax.random.split(jax.random.PRNGKey(1), 4)):
        x = jax.random.uniform(k, (16, spec.input_geom.N))
        state = _single_unsup(state, spec, x)
        state_dp = dp_step(state_dp, x)
        _assert_states_equal(state_dp, state, context=f"{kind} step {i}")


@pytest.mark.parametrize("kind", ["patchy", "compact"])
def test_dp_step_exact_across_rewire(kind):
    """The struct_every cold path (rewire under lax.cond) replicates
    inside the shard_map step: masks, tables and re-gathered traces stay
    bit-identical through a rewire event."""
    spec = _spec(kind, struct_every=2)
    state = init_deep(spec, jax.random.PRNGKey(0))
    dp_step = make_data_parallel_unsupervised_step(spec, _mesh(), layer=0)
    state_dp = jax.tree.map(jnp.array, state)
    for i, k in enumerate(jax.random.split(jax.random.PRNGKey(2), 5)):
        x = jax.random.uniform(k, (16, spec.input_geom.N))
        state = _single_unsup(state, spec, x)
        state_dp = dp_step(state_dp, x)
        _assert_states_equal(state_dp, state, context=f"{kind} step {i}")
    assert int(state.projs[0].traces.t) >= 4  # crossed ≥2 rewire events


@pytest.mark.parametrize("kind", ["dense", "compact"])
def test_dp_supervised_matches_single_device_bitwise(kind):
    spec = _spec(kind)
    state = init_deep(spec, jax.random.PRNGKey(0))
    dp_step = make_data_parallel_supervised_step(spec, _mesh())
    state_dp = jax.tree.map(jnp.array, state)
    for i, k in enumerate(jax.random.split(jax.random.PRNGKey(3), 3)):
        kx, ky = jax.random.split(k)
        x = jax.random.uniform(kx, (16, spec.input_geom.N))
        labels = jax.random.randint(ky, (16,), 0, spec.n_classes)
        state = _single_sup(state, spec, x, labels)
        state_dp = dp_step(state_dp, x, labels)
        _assert_states_equal(state_dp, state, context=f"{kind} sup step {i}")


def test_dp_step_rejects_unshardable_geometry():
    spec = make_network_spec(LayerGeom(12, 2), [(5, 8)], n_classes=3,
                             backend="jnp")  # 5 post-HCs on a 2-way axis
    with pytest.raises(ValueError, match="not divisible"):
        make_data_parallel_unsupervised_step(spec, _mesh(), layer=0)


def test_compact_projection_shardings_use_hj_axis():
    """Compact (Hj, K, Mj) leaves and the integer index table shard along
    the post-HC axis; dense 2-D leaves keep the proj_pre rule."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed import make_rules, projection_shardings
    from repro.distributed.sharding import sharding_context

    spec = _spec("compact")
    state = init_deep(spec, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    with sharding_context(mesh, make_rules(mesh)):
        sh = projection_shardings(state)
    assert sh.projs[0].traces.pij.spec == P("model", None, None)
    assert sh.projs[0].w.spec == P("model", None, None)
    assert sh.projs[0].table.spec == P("model", None)
    assert sh.readout.w.spec == P("model", None)  # dense: proj_pre rule


# ---------------------------------------------------------- step timer --

def test_step_timer_stop_without_start_is_a_clear_error():
    """Regression: StepTimer.stop() with no open window used to crash
    with a bare TypeError from ``None`` arithmetic; it must name the
    misuse instead.  A stop also CLOSES the window, so a double stop is
    the same caller bug."""
    from repro.distributed.fault import StepTimer

    t = StepTimer()
    with pytest.raises(RuntimeError, match="without a prior start"):
        t.stop(step=0)
    with pytest.raises(RuntimeError, match="stop\\(step=7, tag='fold'\\)"):
        t.stop(step=7, tag="fold")
    t.start()
    dt = t.stop(step=1, tag="a")
    assert dt >= 0.0 and t._t0 is None
    with pytest.raises(RuntimeError, match="without a prior start"):
        t.stop(step=1)  # double stop: the window is already closed
    t.start()
    assert t.stop(step=2) >= 0.0  # normal pairing keeps working
