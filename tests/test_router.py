"""Multi-engine router tests (DESIGN.md §11): sticky placement with
replica fan-out, bounded reroute-on-overload carrying the ORIGINAL
absolute deadline across hops, engine-loss recovery with exactly-once
typed failure of in-flight requests, replica quarantine drain + heal,
weighted fairness, replica reconciliation, and the engine-loss chaos
soak (fast mini here; the full randomized soak is slow/nightly)."""
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
import pytest

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import infer, init_deep
from repro.serve import (
    BCPNNRouter, BCPNNService, EngineHandle, FaultInjector, NoHealthyReplica,
    Overloaded, Quarantined, ServeError, WorkerDied, merge_replica_states,
    run_open_loop, states_bitwise_equal,
)


def _small_net(seed=0, side=6, n_classes=3):
    spec = deep_synth_spec(side=side, depth=1, n_classes=n_classes,
                           hidden_hc=4, hidden_mc=8, backend="jnp")
    return spec, init_deep(spec, jax.random.PRNGKey(seed))


def _stream(spec, n, seed=0):
    rng = np.random.default_rng(seed)
    xs = rng.random((n, spec.input_geom.N)).astype(np.float32)
    ys = rng.integers(0, spec.n_classes, size=n).astype(np.int64)
    return xs, ys


# ------------------------------------------------------- stub engines --
# The router is EngineHandle-typed, so the admission/reroute/deadline
# ladder is unit-testable against scripted engines — no worker threads,
# no timing, every hop observable.

class _StubEngine(EngineHandle):
    """Scripted EngineHandle: raises what it is told at submit, records
    every hop's deadline_t (the satellite-2 evidence)."""

    def __init__(self, name: str, fail=()):
        self.name = name
        self.fail = list(fail)        # exceptions to raise, in order
        self.seen_deadlines = []      # deadline_t of every submit hop
        self.submits = 0
        self._models: Dict[str, Tuple[Any, Any]] = {}
        self._depth = 0
        self._alive = True

    # placement / lifecycle
    def models(self):
        return tuple(self._models)

    def add_model(self, model, state, spec, weight=1.0, live=False):
        self._models[model] = (state, spec)

    def start(self, warmup=True):
        pass

    def stop(self, timeout_s=60.0):
        pass

    def alive(self):
        return self._alive

    # data plane
    def submit(self, x, model, deadline_t=None):
        self.seen_deadlines.append(deadline_t)
        self.submits += 1
        if self.fail:
            raise self.fail.pop(0)
        return self.submits

    def result(self, request_id, timeout=None):
        raise NotImplementedError

    # telemetry
    def queue_depth(self, model=None):
        return self._depth

    def feedback_depth(self, model=None):
        return 0

    def quarantined(self, model):
        return False

    def model_spec(self, model):
        return self._models[model][1]

    def model_state_sync(self, model, timeout_s=60.0):
        return self._models[model][0]


def _stub_router(*stubs, **kw):
    r = BCPNNRouter(stubs, **kw)
    state = {"w": np.ones((4,), np.float32)}
    r.add_model("m", state, spec=None, replicas=len(stubs))
    return r


def test_reroute_on_overload_reaches_healthy_replica():
    a = _StubEngine("a", fail=[Overloaded("m", 8, 8)])
    b = _StubEngine("b")
    r = _stub_router(a, b)
    rid = r.submit(np.zeros(4, np.float32))
    assert rid == 0 and b.submits == 1
    snap = r.metrics.snapshot()
    assert snap["reroutes"] == 1 and snap["submitted"] == 1
    assert snap["rejected"] == 0


def test_reroute_exhaustion_raises_no_healthy_replica():
    stubs = [_StubEngine(n, fail=[Overloaded("m", 8, 8)])
             for n in ("a", "b", "c")]
    r = _stub_router(*stubs, max_reroutes=2)
    with pytest.raises(NoHealthyReplica) as ei:
        r.submit(np.zeros(4, np.float32))
    assert ei.value.attempts == 3
    assert isinstance(ei.value, Overloaded)  # open-loop clients need no
    #                                          router-specific branch
    assert isinstance(ei.value.last_error, Overloaded)
    snap = r.metrics.snapshot()
    assert snap["rejected"] == 1 and snap["submitted"] == 0
    assert snap["reroutes"] == 2  # the bound held: 1 + max_reroutes hops


def test_reroute_budget_bound_each_hop_distinct_replica():
    """max_reroutes bounds EXTRA attempts, and no replica is retried —
    an immediately-retried full queue is still full."""
    stubs = [_StubEngine(n, fail=[Overloaded("m", 8, 8)] * 5)
             for n in ("a", "b", "c", "d", "e")]
    r = _stub_router(*stubs, max_reroutes=3)
    with pytest.raises(NoHealthyReplica):
        r.submit(np.zeros(4, np.float32))
    assert sum(s.submits for s in stubs) == 4  # 1 + max_reroutes
    assert max(s.submits for s in stubs) == 1  # all distinct replicas


def test_worker_died_at_submit_triggers_loss_and_reroute():
    a = _StubEngine("a", fail=[WorkerDied("boom")])
    b = _StubEngine("b")
    r = _stub_router(a, b)
    rid = r.submit(np.zeros(4, np.float32))
    assert rid == 0 and b.submits >= 1
    snap = r.metrics.snapshot()
    assert snap["engine_losses"] == 1
    assert "a" not in r.snapshot()["live_engines"]
    # the model stays served: b still hosts it (a's replica slot is gone)
    assert "b" in r.placement("m")["replicas"]


def test_rerouted_request_carries_original_deadline():
    """Satellite 2: the ABSOLUTE deadline stamped at router admission is
    what every hop sees — a reroute does not refresh the budget."""
    a = _StubEngine("a", fail=[Overloaded("m", 8, 8)])
    b = _StubEngine("b")
    r = _stub_router(a, b)
    t0 = time.perf_counter()
    r.submit(np.zeros(4, np.float32), deadline_s=5.0)
    assert len(a.seen_deadlines) == 1 and len(b.seen_deadlines) == 1
    # both hops saw the SAME absolute instant, ~t0 + 5s
    assert a.seen_deadlines[0] == b.seen_deadlines[0]
    assert abs(a.seen_deadlines[0] - (t0 + 5.0)) < 0.5


def test_expired_budget_is_never_resurrected_by_reroute():
    """A request whose original budget expired while the first hop was
    failing is SHED at the router — the healthy replica never sees it."""

    class _SlowOverload(_StubEngine):
        def submit(self, x, model, deadline_t=None):
            self.seen_deadlines.append(deadline_t)
            self.submits += 1
            time.sleep(0.06)  # hop latency eats the whole budget
            raise Overloaded("m", 8, 8)

    a = _SlowOverload("a")
    b = _StubEngine("b")
    r = _stub_router(a, b)
    with pytest.raises(NoHealthyReplica) as ei:
        r.submit(np.zeros(4, np.float32), deadline_s=0.03)
    assert b.submits == 0  # not resurrected on the healthy replica
    assert ei.value.attempts == 1
    assert r.metrics.snapshot()["rejected"] == 1


def test_router_rejects_bad_construction():
    with pytest.raises(ValueError, match="at least one"):
        BCPNNRouter([])
    with pytest.raises(ValueError, match="unique"):
        BCPNNRouter([_StubEngine("a"), _StubEngine("a")])
    r = BCPNNRouter([_StubEngine("a")])
    with pytest.raises(ValueError, match="replicas"):
        r.add_model("m", {}, None, replicas=0)
    r.add_model("m", {"w": np.ones(2, np.float32)}, None)
    with pytest.raises(ValueError, match="already placed"):
        r.add_model("m", {}, None)
    with pytest.raises(KeyError, match="unknown model"):
        r.submit(np.zeros(2, np.float32), model="nope")


def test_placement_spreads_least_loaded_and_replicates_distinct():
    stubs = [_StubEngine(n) for n in ("a", "b", "c")]
    r = BCPNNRouter(stubs)
    st = {"w": np.ones(2, np.float32)}
    assert r.add_model("m0", st, None) == ("a",)
    assert r.add_model("m1", st, None) == ("b",)   # least-loaded next
    assert r.add_model("m2", st, None) == ("c",)
    got = r.add_model("m3", st, None, replicas=2)
    assert len(set(got)) == 2                      # distinct engines
    with pytest.raises(ValueError, match="pass model"):
        r.submit(np.zeros(2, np.float32))          # ambiguous: 4 models


# ----------------------------------------------------- live integration --

def test_routed_classify_matches_direct_infer_across_replicas():
    spec, state = _small_net()
    r = BCPNNRouter.local(3, max_batch=4)
    r.add_model("m", state, spec, replicas=2)
    r.start()
    xs, _ = _stream(spec, 8, seed=2)
    try:
        got = [r.classify(x, timeout=30) for x in xs]
        ids = [r.submit(x) for x in xs]
        got += [r.result(i, timeout=30) for i in ids]
    finally:
        r.stop()
    _, pred_ref = infer(state, spec, xs)
    ref = [int(p) for p in np.asarray(pred_ref)]
    assert [g.pred for g in got] == ref + ref
    snap = r.metrics.snapshot()
    assert snap["completed"] == snap["submitted"] == 16
    assert snap["failed"] == snap["rejected"] == 0


def test_feedback_broadcast_keeps_replicas_bitwise_identical():
    """One admission order + feedback_eager=False => quiescent replicas
    are bit-identical, and the disjoint-support merge equals both."""
    spec, state = _small_net()
    r = BCPNNRouter.local(2, max_batch=4, online_learning=True,
                          feedback_batch=4, feedback_eager=False)
    r.add_model("m", state, spec, replicas=2, online=True)
    r.start()
    xs, ys = _stream(spec, 12, seed=3)
    try:
        for x, y in zip(xs, ys):
            r.feedback(x, int(y), model="m")
        deadline = time.perf_counter() + 30
        while any(r._engines[e].feedback_depth("m")
                  for e in r.placement("m")["replicas"]):
            assert time.perf_counter() < deadline, "feedback never folded"
            time.sleep(0.01)
        rep = r.reconcile()
    finally:
        r.stop()
    assert rep["m"]["consistent"], rep
    states = [r._engines[e].model_state_sync("m")
              for e in r.placement("m")["replicas"]]
    assert states_bitwise_equal(states[0], states[1])
    assert states_bitwise_equal(merge_replica_states(states), states[0])
    # the replicas actually learned (not frozen-state trivia)
    assert not states_bitwise_equal(states[0], state)


def test_reconcile_repairs_diverged_replica():
    """A replica whose state drifts (here: forced via set_model_state)
    is detected by the merge contract and repaired from the replica with
    the most folded samples."""
    spec, state = _small_net()
    r = BCPNNRouter.local(2, max_batch=4, online_learning=True,
                          feedback_batch=4, feedback_eager=False)
    r.add_model("m", state, spec, replicas=2, online=True)
    r.start()
    xs, ys = _stream(spec, 8, seed=4)
    try:
        for x, y in zip(xs, ys):
            r.feedback(x, int(y), model="m")
        deadline = time.perf_counter() + 30
        while any(r._engines[e].feedback_depth("m")
                  for e in r.placement("m")["replicas"]):
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        lagger = r.placement("m")["replicas"][1]
        r._engines[lagger].set_model_state("m", state)  # stale restore
        rep = r.reconcile()["m"]
        assert rep["consistent"] is False
        assert rep["repaired"] == [lagger]
        assert rep["authoritative"] != lagger
        assert rep["divergence"]  # names the drifted leaves
        # after repair the replicas agree again
        rep2 = r.reconcile()["m"]
        assert rep2["consistent"] is True
    finally:
        r.stop()
    snap = r.metrics.snapshot()
    assert snap["mismatches"] == 1 and snap["repairs"] == 1
    assert snap["reconciliations"] == 1


def test_reconcile_skips_non_quiescent_replicas():
    spec, state = _small_net()
    r = BCPNNRouter.local(2, online_learning=True, feedback_batch=64,
                          feedback_eager=False)
    r.add_model("m", state, spec, replicas=2, online=True)
    r.start()
    xs, ys = _stream(spec, 3, seed=5)
    try:
        for x, y in zip(xs, ys):
            r.feedback(x, int(y), model="m")  # buffers, never folds (64)
        rep = r.reconcile()["m"]
        assert "skipped" in rep and "quiescent" in rep["skipped"]
    finally:
        r.stop()


def test_engine_loss_recovery_fails_inflight_typed_and_replaces():
    """Kill a hosting engine mid-flight: every in-flight request on it
    resolves WorkerDied exactly once (never lost, never double), the
    model re-places onto a survivor, and serving resumes."""
    spec, state = _small_net()
    r = BCPNNRouter.local(3, max_batch=4)
    r.add_model("m", state, spec, replicas=2)
    r.start()
    xs, _ = _stream(spec, 40, seed=6)
    try:
        ids = [r.submit(x) for x in xs]
        victim = r.placement("m")["replicas"][0]
        r._engines[victim].kill("chaos")
        outcomes: Dict[int, Any] = {}
        for rid in ids:
            try:
                outcomes[rid] = r.result(rid, timeout=30)
            except ServeError as e:
                outcomes[rid] = e
        # exactly-once: every id resolved, one way, exactly one entry
        assert len(outcomes) == len(ids) == len(set(ids))
        died = [v for v in outcomes.values() if isinstance(v, WorkerDied)]
        ok = [v for v in outcomes.values() if not isinstance(v, Exception)]
        assert len(died) + len(ok) == len(ids)
        # a second result() for a resolved id is a KeyError, not a dupe
        with pytest.raises(KeyError):
            r.result(ids[0], timeout=1)
        # loss observed + model re-placed onto a survivor
        deadline = time.perf_counter() + 30
        while victim in r.snapshot()["live_engines"]:
            r.check_engines()
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        place = r.placement("m")
        assert victim not in place["replicas"]
        assert len(place["replicas"]) == 2  # back at desired fan-out
        res = r.classify(xs[0], timeout=30)  # serving resumed
        assert res.pred >= 0
    finally:
        r.stop()
    snap = r.metrics.snapshot()
    assert snap["engine_losses"] == 1 and snap["replacements"] >= 1
    assert snap["submitted"] == snap["completed"] + snap["failed"]


def test_engine_loss_recovers_online_model_from_peer_folds():
    """Recovery prefers a live peer's fold-boundary state over the
    registration checkpoint: the re-placed replica carries every fold,
    bit-for-bit."""
    spec, state = _small_net()
    r = BCPNNRouter.local(3, max_batch=4, online_learning=True,
                          feedback_batch=4, feedback_eager=False)
    r.add_model("m", state, spec, replicas=2, online=True)
    r.start()
    xs, ys = _stream(spec, 8, seed=7)
    try:
        for x, y in zip(xs, ys):
            r.feedback(x, int(y), model="m")
        deadline = time.perf_counter() + 30
        while any(r._engines[e].feedback_depth("m")
                  for e in r.placement("m")["replicas"]):
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        survivor = r.placement("m")["replicas"][1]
        want = r._engines[survivor].model_state_sync("m")
        victim = r.placement("m")["replicas"][0]
        r._engines[victim].kill("chaos")
        deadline = time.perf_counter() + 30
        while not r.check_engines():
            assert time.perf_counter() < deadline
            time.sleep(0.01)
        place = r.placement("m")
        newcomer = [e for e in place["replicas"] if e != survivor][0]
        got = r._engines[newcomer].model_state_sync("m")
        assert states_bitwise_equal(got, want)  # folds carried over
        assert not states_bitwise_equal(got, state)  # not the checkpoint
    finally:
        r.stop()


def test_quarantine_drain_and_heal_repairs_from_peer():
    """An injected NaN fold quarantines ONE replica; its share drains to
    the healthy peer, heal() revalidates + repairs it from the peer, and
    it rejoins the rotation with a bit-identical state."""
    spec, state = _small_net()
    inj = FaultInjector(seed=0, schedule={"nan-state": {0}})
    r = BCPNNRouter.local(2, max_batch=4, online_learning=True,
                          feedback_batch=4, feedback_eager=False,
                          fault_injectors=[inj, None])
    r.add_model("m", state, spec, replicas=2, online=True)
    r.start()
    xs, ys = _stream(spec, 8, seed=8)
    sick, healthy = r.placement("m")["replicas"]
    assert sick == "engine0"
    try:
        for x, y in zip(xs, ys):
            r.feedback(x, int(y), model="m")
        deadline = time.perf_counter() + 30
        while not r._engines[sick].quarantined("m"):
            assert time.perf_counter() < deadline, "quarantine never hit"
            time.sleep(0.01)
        # the next broadcast marks the quarantined replica draining but
        # still lands on the healthy peer (no Quarantined to the caller)
        r.feedback(xs[0], int(ys[0]), model="m")
        assert sick in r.placement("m")["draining"]
        # new inference sheds the draining replica's share to the peer
        for x in xs:
            r.classify(x, timeout=30)
        assert r._engines[sick].snapshot(model="m")["completed"] == 0.0
        healed = r.heal()
        assert healed == {"m": [sick]}
        assert r.placement("m")["draining"] == ()
        assert not r._engines[sick].quarantined("m")
        # heal repaired the quarantined replica from the healthy peer's
        # fold-boundary state: bit-identical, and carrying the folds the
        # sick replica's rollback dropped (i.e. not the original state)
        a = r._engines[sick].model_state_sync("m")
        b = r._engines[healthy].model_state_sync("m")
        assert states_bitwise_equal(a, b)
        assert not states_bitwise_equal(a, state)
    finally:
        r.stop()
    assert r.metrics.snapshot()["quarantine_drains"] == 1


def test_weighted_fairness_vft_schedule():
    """White-box scheduler fairness: with weights 3:1 and equal costs,
    the weight-3 model is served ~3 samples per 1 of the other — the
    start-time-fair virtual clock, not round-robin."""
    from repro.serve import Request

    spec, state = _small_net()
    svc = BCPNNService(max_batch=4, max_wait_ms=0.0, poll_ms=1.0)
    svc.add_model("heavy", state, spec, weight=3.0)
    svc.add_model("light", state, spec, weight=1.0)
    x = np.zeros((spec.input_geom.N,), np.float32)
    for i in range(24):
        svc._slots["heavy"].batcher.put(
            Request(id=i, x=x, enqueue_t=0.0, model="heavy"))
    for i in range(24):
        svc._slots["light"].batcher.put(
            Request(id=100 + i, x=x, enqueue_t=0.0, model="light"))
    order = []
    while True:
        group, slot = svc._next_work()
        if not group:
            break
        order.append((slot.name, len(group)))
    served = {"heavy": 0, "light": 0}
    prefix = []
    for name, n in order:
        served[name] += n
        prefix.append(dict(served))
    # everything drains eventually...
    assert served == {"heavy": 24, "light": 24}
    # ...but while BOTH backlogs compete (first 8 groups cover 32
    # samples), heavy holds a ~3x share under the virtual clock
    mid = prefix[7]
    assert mid["heavy"] == 24 and mid["light"] == 8


def test_router_mini_engine_loss_soak_accounting_closes():
    """Fast chaos mini-soak: open-loop Poisson into 3 engines with one
    engine killed mid-run.  Every submitted id completes, sheds, or
    fails TYPED — zero lost, zero hung — and rerouted requests respect
    the original deadline (no DeadlineExceeded can out-live its budget,
    which the engine's shed path enforces from the routed deadline_t)."""
    spec, state = _small_net()
    r = BCPNNRouter.local(3, max_batch=8, max_queue=64)
    r.add_model("m", state, spec, replicas=2)
    r.start()
    xs, ys = _stream(spec, 32, seed=9)
    victim = r.placement("m")["replicas"][0]
    killer = threading.Timer(0.25, lambda: r._engines[victim].kill("soak"))
    killer.start()
    try:
        rep = run_open_loop(r, xs, ys, n_requests=150, rate_hz=400.0,
                            seed=10, timeout_s=60.0, deadline_s=5.0,
                            model="m")
    finally:
        killer.cancel()
        r.stop()
    # accounting closes at the router: offered = served + typed errors
    # + rejected; nothing lost or hung (a TimeoutError would be a hang)
    assert len(rep.results) + len(rep.errors) + rep.n_rejected == 150
    for e in rep.errors:
        assert isinstance(e, ServeError), repr(e)
    snap = r.metrics.snapshot()
    assert snap["submitted"] == snap["completed"] + snap["failed"]
    assert snap["engine_losses"] == 1
    assert len(rep.results) > 0  # the tier kept serving through the kill


@pytest.mark.slow
def test_router_engine_loss_chaos_soak():
    """Nightly chaos soak (ISSUE 9 acceptance): randomized engine kills
    AND the PR 8 fault points under Poisson overload across a replicated
    router.  Accounting closes at the router; post-soak the reconciled
    replica states are finite and bit-identical across replicas."""
    from repro.serve import state_finite

    spec, state = _small_net(side=8)
    rng = np.random.default_rng(123)
    injectors = [FaultInjector(seed=int(rng.integers(1 << 30)),
                               rates={"infer-raise": 0.02,
                                      "fold-raise": 0.02,
                                      "nan-state": 0.01,
                                      "slow-batch": 0.02})
                 for _ in range(4)]
    r = BCPNNRouter.local(4, max_batch=8, max_queue=32,
                          online_learning=True, feedback_batch=8,
                          feedback_eager=False, fault_injectors=injectors)
    r.add_model("m", state, spec, replicas=3, online=True)
    r.start()
    xs, ys = _stream(spec, 64, seed=11)

    # randomized mid-run kill of one hosting replica
    def chaos():
        time.sleep(float(rng.uniform(0.3, 0.8)))
        victim = r.placement("m")["replicas"][int(rng.integers(0, 3))]
        r._engines[victim].kill("chaos-soak")

    t = threading.Thread(target=chaos)
    t.start()
    try:
        rep = run_open_loop(r, xs, ys, n_requests=600, rate_hz=500.0,
                            seed=12, timeout_s=120.0, deadline_s=2.0,
                            feedback_frac=0.2, model="m")
    finally:
        t.join()
        r.heal()
        # stop drains: every engine flushes its buffered feedback tail,
        # so the post-stop reconcile compares fully-folded settled states
        # (live control ops fall back to direct reads on stopped engines)
        r.stop()
        rec = r.reconcile()["m"]
    # every submitted id resolved typed; zero lost/hung (a TimeoutError
    # would be a hang)
    assert len(rep.results) + len(rep.errors) + rep.n_rejected == 600
    for e in rep.errors:
        assert isinstance(e, ServeError), repr(e)
    snap = r.metrics.snapshot()
    assert snap["submitted"] == snap["completed"] + snap["failed"]
    assert snap["engine_losses"] >= 1
    assert len(rep.results) > 0
    # post-soak replica agreement: finite + bit-identical — directly, or
    # via the reconcile repair the report then records
    assert "skipped" not in rec, rec
    states = [r._engines[e].model_state_sync("m")
              for e in r.placement("m")["replicas"]]
    for s in states:
        assert state_finite(s)
    for s in states[1:]:
        assert states_bitwise_equal(states[0], s)
