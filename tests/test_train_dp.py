"""Trainer-driven fault-tolerant data-parallel training (DESIGN.md §12).

Three bug classes are pinned here:
  * the tail-batch fix — ``Trainer.fit`` used to silently DROP up to
    ``batch - 1`` trailing samples; now they are zero-padded and masked,
    with stats divided by the REAL row count (``learn_masked``);
  * DP-fit exactness — a fit driven through the shard_map
    scan-over-batches epoch programs must be bit-for-bit what the
    single-device fit produces, for dense, patchy-held and
    compact-resident projections, on whole-batch AND padded-tail data;
  * elastic kill-resume — a fit interrupted by ``WorkerLost`` resumes
    from its checkpoint cursor on a rebuilt (possibly smaller) mesh and
    lands bit-identical to the uninterrupted run.

Runs on the 2-device host CPU mesh set up by conftest.py.
"""
import numpy as np
import pytest

import jax

from repro.core import FitCursor, Trainer, learn
from repro.core.bcpnn_layer import learn_masked
from repro.core.hypercolumns import LayerGeom
from repro.core.network import init_deep, make_network_spec
from repro.distributed.fault import (StepTimer, WorkerLost, elastic_mesh,
                                     fit_mesh_shape, order_devices_host_major)

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs the 2-device CPU mesh (conftest "
    "sets --xla_force_host_platform_device_count=2)")


def _spec(kind="dense", depth1=True):
    kw = dict(alpha=1e-2, backend="jnp", support_noise=2.0, noise_steps=50)
    layers = [(6, 8)] if depth1 else [(6, 8), (4, 4)]
    if kind == "dense":
        return make_network_spec(LayerGeom(12, 2), layers, 3, **kw)
    if kind == "patchy":
        return make_network_spec(LayerGeom(12, 2), layers, 3,
                                 nact=[4] * len(layers), patchy_traces=True,
                                 **kw)
    assert kind == "compact"
    return make_network_spec(LayerGeom(12, 2), layers, 3,
                             nact=[4] * len(layers), patchy_traces=True,
                             compact=True, **kw)


def _data(n, seed=0, n_classes=3, dim=24):
    rng = np.random.default_rng(seed)
    return (rng.random((n, dim)).astype(np.float32),
            rng.integers(0, n_classes, n).astype(np.int32))


def _assert_states_equal(got, want, context=""):
    flat_g, _ = jax.tree_util.tree_flatten_with_path(got)
    flat_w, _ = jax.tree_util.tree_flatten_with_path(want)
    assert len(flat_g) == len(flat_w)
    for (path, g), (_, w) in zip(flat_g, flat_w):
        assert np.array_equal(np.asarray(g), np.asarray(w)), (
            f"{context}: leaf {jax.tree_util.keystr(path)} diverged")


def _states_differ(a, b):
    return any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ------------------------------------------------------ tail-batch fix --

def test_tail_samples_now_train_the_network():
    """Regression: 41 samples at batch=16 used to fit on only 32 — the
    fit was bit-identical to one that never saw the last 9 samples."""
    spec = _spec("dense")
    x, y = _data(41)
    t_all = Trainer(spec, seed=0)
    t_all.fit(x, y, epochs=2, batch=16)
    t_trim = Trainer(spec, seed=0)
    t_trim.fit(x[:32], y[:32], epochs=2, batch=16)
    assert _states_differ(t_all.state, t_trim.state), (
        "the 9 tail samples left no trace in the learned state — they "
        "are still being dropped")


def test_learn_masked_divides_by_real_row_count():
    """The masked learner on a zero-padded batch must match the unmasked
    learner on just the genuine rows: stats divide by the REAL count, not
    the padded batch size (which would dilute every trace)."""
    spec = _spec("dense")
    state = init_deep(spec, jax.random.PRNGKey(0))
    proj, pspec = state.projs[0], spec.projs[0]
    rng = np.random.default_rng(7)
    n, b = 11, 16
    x = np.zeros((b, pspec.pre.N), np.float32)
    y = np.zeros((b, pspec.post.N), np.float32)
    x[:n] = rng.random((n, pspec.pre.N))
    y[:n] = rng.random((n, pspec.post.N))
    valid = (np.arange(b) < n).astype(np.float32)
    got = learn_masked(proj, pspec, x, y, valid)
    want = learn(proj, pspec, x[:n], y[:n])
    # Tolerances absorb fp reduction-order noise only (~1e-7); the bug
    # this pins — dividing by the padded batch size — would shrink every
    # stat by the factor n/b = 11/16, far outside any of these bounds.
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-5)


def test_whole_batch_fit_keeps_the_unmasked_program():
    """Data that divides the batch takes the exact pre-fix epoch program:
    masked and unmasked fits on whole-batch data are bit-identical, i.e.
    the masked path only ever engages when a pad exists."""
    spec = _spec("dense")
    x, y = _data(32)
    t = Trainer(spec, seed=0)
    t.fit(x, y, epochs=2, batch=16)
    assert ("unsup", 0, True) not in t._epoch_cache
    assert ("unsup", 0, False) in t._epoch_cache


# ------------------------------------------- DP fit vs single-device --

@needs_mesh
@pytest.mark.parametrize("kind", ["dense", "patchy", "compact"])
@pytest.mark.parametrize("n", [48, 41], ids=["whole-batch", "padded-tail"])
def test_dp_fit_matches_single_device_bitwise(kind, n):
    spec = _spec(kind)
    x, y = _data(n)
    t1 = Trainer(spec, seed=0)
    t1.fit(x, y, epochs=2, batch=16)
    t2 = Trainer(spec, seed=0, mesh=jax.make_mesh((2,), ("data",)))
    t2.fit(x, y, epochs=2, batch=16)
    _assert_states_equal(t2.state, t1.state, context=f"{kind} n={n}")


@needs_mesh
def test_dp_fit_rejects_unshardable_batch():
    t = Trainer(_spec("dense"), seed=0, mesh=jax.make_mesh((2,), ("data",)))
    x, y = _data(34)
    with pytest.raises(ValueError, match="cannot shard"):
        t.fit(x, y, epochs=1, batch=17)


# ------------------------------------------------- elastic kill-resume --

@needs_mesh
def test_kill_resume_is_bit_exact_across_mesh_sizes(tmp_path):
    """The full recovery ladder: chunked+checkpointed DP fit equals the
    unchunked single-device fit; a fit killed mid-schedule by WorkerLost
    resumes from its cursor — on the SAME mesh and on a SHRUNK 1-way
    elastic mesh — and both land bit-identical to the uninterrupted run."""
    spec = _spec("dense", depth1=False)
    x, y = _data(41, seed=1)
    mesh2 = jax.make_mesh((2,), ("data",))

    t_ref = Trainer(spec, seed=0)
    t_ref.fit(x, y, epochs=2, batch=16)

    d_full = tmp_path / "full"
    t_a = Trainer(spec, seed=0, mesh=mesh2)
    stats = t_a.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d_full),
                    ckpt_every_batches=2)
    _assert_states_equal(t_a.state, t_ref.state, context="chunked DP")
    assert "straggler_events" in stats

    def interrupted_dir(name, kill_at):
        d = tmp_path / name
        calls = {"n": 0}

        def killer(cur):
            calls["n"] += 1
            if calls["n"] == kill_at:
                raise WorkerLost(f"simulated loss at {cur}")

        t = Trainer(spec, seed=0, mesh=mesh2)
        with pytest.raises(WorkerLost):
            t.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d),
                  ckpt_every_batches=2, on_chunk=killer)
        return d

    # Same-mesh resume.
    d1 = interrupted_dir("same", kill_at=3)
    t_same = Trainer(spec, seed=0, mesh=mesh2)
    t_same.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d1),
               ckpt_every_batches=2, resume=True)
    _assert_states_equal(t_same.state, t_a.state, context="same-mesh resume")

    # Worker lost: rebuild the largest mesh from one surviving device.
    d2 = interrupted_dir("elastic", kill_at=3)
    mesh1 = elastic_mesh((2,), ("data",), devices=jax.devices()[:1])
    assert dict(mesh1.shape) == {"data": 1}
    t_el = Trainer(spec, seed=0, mesh=mesh1)
    t_el.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d2),
             ckpt_every_batches=2, resume=True)
    _assert_states_equal(t_el.state, t_a.state, context="1-way elastic resume")
    assert t_el.evaluate(x, y, batch=16) == t_ref.evaluate(x, y, batch=16)


def test_resume_requires_a_cursor_checkpoint(tmp_path):
    """A final artifact saved by ``Trainer.save`` has no schedule cursor;
    ``fit(resume=True)`` must refuse it with a pointed error instead of
    silently restarting the schedule from zero on a trained state."""
    spec = _spec("dense")
    x, y = _data(32)
    t = Trainer(spec, seed=0)
    t.save(str(tmp_path))
    with pytest.raises(ValueError, match="no fit cursor"):
        t.fit(x, y, epochs=1, batch=16, ckpt_dir=str(tmp_path), resume=True)
    with pytest.raises(ValueError, match="requires ckpt_dir"):
        t.fit(x, y, epochs=1, batch=16, resume=True)


def test_fit_cursor_roundtrip():
    c = FitCursor("supervised", layer=2, epoch=1, batch=5)
    assert FitCursor.from_dict(c.to_dict()) == c


@pytest.mark.slow
@needs_mesh
def test_chaos_kill_resume_soak(tmp_path):
    """Nightly chaos soak: random kill points and data seeds; every
    interrupted fit, resumed on a randomly shrunk-or-same mesh, must land
    bit-identical to its uninterrupted run with equal eval accuracy."""
    rng = np.random.default_rng(0)
    spec = _spec("dense", depth1=False)
    mesh2 = jax.make_mesh((2,), ("data",))
    for trial in range(3):
        x, y = _data(41, seed=int(rng.integers(1 << 30)))
        t_ref = Trainer(spec, seed=0, mesh=mesh2)
        t_ref.fit(x, y, epochs=2, batch=16)

        kill_at = int(rng.integers(1, 9))
        d = tmp_path / f"trial{trial}"
        calls = {"n": 0}

        def killer(cur):
            calls["n"] += 1
            if calls["n"] == kill_at:
                raise WorkerLost(f"chaos kill at {cur}")

        t_k = Trainer(spec, seed=0, mesh=mesh2)
        with pytest.raises(WorkerLost):
            t_k.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d),
                    ckpt_every_batches=2, on_chunk=killer)

        n_dev = int(rng.integers(1, 3))
        mesh_r = elastic_mesh((2,), ("data",),
                              devices=jax.devices()[:n_dev])
        t_r = Trainer(spec, seed=0, mesh=mesh_r)
        t_r.fit(x, y, epochs=2, batch=16, ckpt_dir=str(d),
                ckpt_every_batches=2, resume=True)
        _assert_states_equal(
            t_r.state, t_ref.state,
            context=f"trial {trial} kill@{kill_at} resume@{n_dev}dev")
        assert t_r.evaluate(x, y) == t_ref.evaluate(x, y)


# ------------------------------------------------------------ fault.py --

def test_step_timer_memory_is_bounded_by_window():
    """Regression: ``_times`` grew one entry per step forever (the window
    was only applied at read time) — a leak on multi-day fits.  It must
    stay trimmed, with ``median`` computed over exactly the retained
    window."""
    t = StepTimer(window=10)
    recorded = []
    for i in range(100):
        t.start()
        recorded.append(t.stop(step=i))
    assert len(t._times) == 10
    assert t._times == recorded[-10:]
    assert t.median == float(np.median(recorded[-10:]))


def test_step_timer_attributes_injected_straggler():
    t = StepTimer(window=20, threshold=3.0)
    t._times = [0.01] * 19
    t._t0 = -1e9  # forces a huge dt for this stop
    t.stop(step=42, tag="unsup/L0/e1")
    assert t.events and t.events[-1]["step"] == 42
    assert t.events[-1]["tag"] == "unsup/L0/e1"
    assert len(t._times) == 20  # trimmed even across the event path


class _StubDev:
    def __init__(self, pid, did):
        self.process_index, self.id = pid, did

    def __repr__(self):
        return f"dev(p{self.process_index},d{self.id})"


def test_order_devices_host_major():
    devs = [_StubDev(1, 0), _StubDev(0, 3), _StubDev(1, 2), _StubDev(0, 1)]
    got = order_devices_host_major(devs)
    assert [(d.process_index, d.id) for d in got] == [
        (0, 1), (0, 3), (1, 0), (1, 2)]


def test_fit_mesh_shape_shrinks_data_axis_only():
    assert fit_mesh_shape((4,), 4) == [4]
    assert fit_mesh_shape((4,), 3) == [3]      # lost one device
    assert fit_mesh_shape((2, 4), 4) == [1, 4]  # lost a whole host row
    with pytest.raises(RuntimeError, match="cannot build mesh"):
        fit_mesh_shape((1, 8), 4)  # model axis never shrinks


@needs_mesh
def test_elastic_mesh_shrinks_and_reports_domains():
    from repro.distributed.fault import describe_failure_domains

    m = elastic_mesh((4,), ("data",))  # only 2 devices exist
    assert dict(m.shape) == {"data": 2}
    m1 = elastic_mesh((4,), ("data",), devices=jax.devices()[:1])
    assert dict(m1.shape) == {"data": 1}
    dom = describe_failure_domains(m)
    assert dom["n_devices"] == 2 and dom["axis_names"] == ["data"]
    m2 = elastic_mesh((2, 2), ("data", "model"))  # 4 wanted, 2 exist
    assert dict(m2.shape) == {"data": 1, "model": 2}  # data axis shrank
    with pytest.raises(RuntimeError, match="cannot build mesh"):
        elastic_mesh((1, 4), ("data", "model"))  # model axis never shrinks
