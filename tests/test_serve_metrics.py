"""Deterministic ServeMetrics unit tests: percentiles on hand-built
latency sequences, ring eviction at ``metrics_window``, occupancy math on
partially-valid batches, the adaptive-policy windows (arrival rate, group
p90) on injected clocks, and multi-model aggregation — previously these
were only exercised incidentally through the engine."""
import numpy as np
import pytest

from repro.serve import ServeMetrics


def test_percentiles_on_hand_built_sequence():
    m = ServeMetrics(window=128)
    lats = [0.010, 0.020, 0.030, 0.040, 0.100]  # seconds
    for l in lats:
        m.record_complete(l)
    snap = m.snapshot()
    ref = np.asarray(lats) * 1e3
    assert snap["p50_ms"] == pytest.approx(np.percentile(ref, 50))
    assert snap["p90_ms"] == pytest.approx(np.percentile(ref, 90))
    assert snap["p99_ms"] == pytest.approx(np.percentile(ref, 99))
    assert snap["mean_ms"] == pytest.approx(ref.mean())
    assert snap["completed"] == 5.0


def test_percentiles_ordering_invariant():
    m = ServeMetrics()
    for l in (0.5, 0.001, 0.25, 0.003, 0.9, 0.004):
        m.record_complete(l)
    snap = m.snapshot()
    assert snap["p50_ms"] <= snap["p90_ms"] <= snap["p99_ms"]
    assert snap["p99_ms"] <= 900.0 + 1e-9


def test_latency_window_eviction():
    """The ring keeps exactly the last ``window`` latencies: older ones
    stop influencing the percentiles."""
    m = ServeMetrics(window=8)
    for _ in range(5):
        m.record_complete(10.0)  # absurd 10s outliers, soon evicted
    for _ in range(8):
        m.record_complete(0.010)
    snap = m.snapshot()
    assert snap["p99_ms"] == pytest.approx(10.0)   # ms — outliers gone
    assert snap["mean_ms"] == pytest.approx(10.0)
    assert snap["completed"] == 13.0  # counters are lifetime, not windowed


def test_occupancy_partially_valid_batches():
    m = ServeMetrics()
    m.record_batch(n_valid=3, bucket=4)
    m.record_batch(n_valid=1, bucket=4)
    snap = m.snapshot()
    assert snap["batches"] == 2.0
    assert snap["batch_occupancy"] == pytest.approx(4 / 8)
    m.record_batch(n_valid=8, bucket=8)
    assert m.snapshot()["batch_occupancy"] == pytest.approx(12 / 16)


def test_occupancy_empty_is_zero_not_nan():
    snap = ServeMetrics().snapshot()
    assert snap["batch_occupancy"] == 0.0
    assert snap["p50_ms"] == snap["p99_ms"] == snap["mean_ms"] == 0.0
    assert snap["images_per_s"] == 0.0
    assert snap["arrival_rate_hz"] == 0.0


def test_arrival_rate_from_injected_clock():
    m = ServeMetrics()
    assert m.arrival_rate_hz() == 0.0
    m.record_submit(now=0.0)
    assert m.arrival_rate_hz() == 0.0  # one arrival: no rate yet
    for t in (0.1, 0.2, 0.3, 0.4):
        m.record_submit(now=t)
    assert m.arrival_rate_hz() == pytest.approx(10.0)  # 4 gaps / 0.4 s
    assert m.snapshot()["arrival_rate_hz"] == pytest.approx(10.0)


def test_arrival_rate_windowed():
    """The rate reflects the RECENT window, not lifetime: a long-ago
    burst falls out of the bounded arrival deque."""
    m = ServeMetrics(rate_window=4)
    for t in (0.0, 0.001, 0.002, 0.003):   # 1000 Hz burst
        m.record_submit(now=t)
    for t in (10.0, 11.0, 12.0, 13.0):     # then 1 Hz trickle
        m.record_submit(now=t)
    assert m.arrival_rate_hz() == pytest.approx(1.0)


def test_group_p90_window():
    m = ServeMetrics()
    assert m.group_p90() == 0.0
    for n in (1, 1, 1, 1, 1, 1, 1, 1, 1, 8):
        m.record_batch(n_valid=n, bucket=8)
    assert m.group_p90() == pytest.approx(
        np.percentile([1] * 9 + [8], 90))


def test_throughput_on_injected_clock():
    m = ServeMetrics()
    m.record_submit(now=100.0)
    for i in range(20):
        m.record_complete(0.005, now=100.0 + (i + 1) * 0.5)
    snap = m.snapshot()
    assert snap["images_per_s"] == pytest.approx(20 / 10.0)


def test_learn_counters():
    m = ServeMetrics()
    m.record_learn(16)
    m.record_learn(3)
    snap = m.snapshot()
    assert snap["learn_steps"] == 2.0
    assert snap["learn_samples"] == 19.0


def test_aggregate_across_models():
    """Engine-wide aggregation: counters sum, occupancy pools slots,
    percentiles cover the concatenated rings, throughput spans the
    earliest start to the latest completion."""
    a, b = ServeMetrics(), ServeMetrics()
    a.record_submit(now=0.0)
    b.record_submit(now=1.0)
    a.record_batch(n_valid=2, bucket=4)
    b.record_batch(n_valid=4, bucket=4)
    for l in (0.010, 0.020):
        a.record_complete(l, now=2.0)
    for l in (0.030, 0.040):
        b.record_complete(l, now=4.0)
    a.record_learn(8)
    agg = ServeMetrics.aggregate([a, b], queue_depth=3)
    assert agg["submitted"] == 2.0 and agg["completed"] == 4.0
    assert agg["batches"] == 2.0
    assert agg["batch_occupancy"] == pytest.approx(6 / 8)
    assert agg["learn_steps"] == 1.0 and agg["learn_samples"] == 8.0
    assert agg["queue_depth"] == 3.0
    assert agg["images_per_s"] == pytest.approx(4 / 4.0)  # span 0 -> 4 s
    ref = np.asarray([10.0, 20.0, 30.0, 40.0])
    assert agg["p50_ms"] == pytest.approx(np.percentile(ref, 50))
    assert agg["p99_ms"] == pytest.approx(np.percentile(ref, 99))


def test_aggregate_of_empty_registries():
    agg = ServeMetrics.aggregate([ServeMetrics(), ServeMetrics()])
    assert agg["completed"] == 0.0
    assert agg["p99_ms"] == 0.0 and agg["images_per_s"] == 0.0
    assert agg["batch_occupancy"] == 0.0
