"""Slow multi-model soak: an open-loop Poisson mix with a 10:1 per-model
rate skew through a live engine, asserting fairness (the minority model's
completion share tracks its arrival share THROUGHOUT the run, not just at
drain) and no starvation past a latency ceiling."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.bcpnn_models import deep_synth_spec
from repro.core import init_deep
from repro.serve import BCPNNService, StreamSpec, run_multi_open_loop


@pytest.mark.slow
def test_skewed_poisson_fairness_soak():
    spec_a = deep_synth_spec(side=8, depth=2, n_classes=4, hidden_hc=8,
                             hidden_mc=16)
    spec_b = deep_synth_spec(side=8, depth=1, n_classes=4, hidden_hc=4,
                             hidden_mc=8)
    state_a = init_deep(spec_a, jax.random.PRNGKey(0))
    state_b = init_deep(spec_b, jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    xe = rng.random((64, spec_a.input_geom.N)).astype(np.float32)
    ye = rng.integers(0, 4, size=64).astype(np.int64)
    svc = BCPNNService.multi({"major": (state_a, spec_a),
                              "minor": (state_b, spec_b)},
                             max_batch=16, max_wait_ms=2.0).start()

    # Mid-run sampler: per-model completion counts while load is flowing
    # (post-drain shares are trivially proportional — the fairness claim
    # is about DURING the run).
    samples = []
    stop_sampling = threading.Event()

    def sampler():
        while not stop_sampling.is_set():
            snap = svc.snapshot()
            samples.append((snap["per_model"]["major"]["completed"],
                            snap["per_model"]["minor"]["completed"]))
            time.sleep(0.025)

    st = threading.Thread(target=sampler)
    st.start()
    try:
        reports = run_multi_open_loop(
            svc,
            {"major": StreamSpec(xe, ye, rate_hz=500.0),
             "minor": StreamSpec(xe, ye, rate_hz=50.0)},
            n_requests=600, seed=3)
    finally:
        stop_sampling.set()
        st.join()
        svc.stop()
    snap = svc.snapshot()

    # zero loss, both models served
    assert snap["completed"] == snap["submitted"] == 600
    n_major = len(reports["major"].results)
    n_minor = len(reports["minor"].results)
    assert n_major + n_minor == 600 and n_minor > 0
    arrival_share = n_minor / 600.0          # ~1/11 under the 10:1 skew

    # fairness THROUGHOUT: once a meaningful number of requests has
    # completed, the minority's completion share stays within 2x of its
    # arrival share (acceptance bar) at every sample
    checked = 0
    for c_major, c_minor in samples:
        total = c_major + c_minor
        if total < 100 or total >= 590:      # warmup / drained tails
            continue
        share = c_minor / total
        assert share >= arrival_share / 2.0, (
            f"minority starved mid-run: share {share:.3f} vs arrival "
            f"share {arrival_share:.3f} at {total:.0f} completed")
        assert share <= min(1.0, arrival_share * 2.0 + 0.05), (
            f"minority over-served mid-run: {share:.3f}")
        checked += 1
    assert checked > 0, "sampler caught no mid-run window; slow machine?"

    # no starvation past the latency ceiling, for EVERY request
    for name, rep in reports.items():
        assert rep.max_latency_ms < 2000.0, (
            f"model {name!r} request starved: {rep.max_latency_ms:.0f}ms")
    # and the minority's tail latency is not inflated by the skew
    assert snap["per_model"]["minor"]["p99_ms"] < 1000.0
