"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke
from repro.models import lm
from repro.optim import AdamWConfig, apply_updates, init_opt_state

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, seed=0):
    k = jax.random.PRNGKey(seed)
    out = {"tokens": jax.random.randint(k, (b, s), 0, cfg.vocab)}
    if cfg.vision_patches:
        out["patches"] = jax.random.normal(
            jax.random.fold_in(k, 1), (b, cfg.vision_patches, cfg.d_model))
    if cfg.enc_layers:
        out["frames"] = jax.random.normal(
            jax.random.fold_in(k, 2), (b, cfg.enc_seq, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = smoke(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    h = lm.forward(params, cfg, batch["tokens"],
                   patches=batch.get("patches"), frames=batch.get("frames"))
    assert h.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.isnan(h).any()), arch
    logits = lm.logits_for(params, cfg, h[:, -1])
    assert logits.shape == (2, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss_no_nans(arch):
    cfg = smoke(get_config(arch))
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o):
        loss, grads = jax.value_and_grad(
            lambda q: lm.lm_loss(q, cfg, batch["tokens"],
                                 patches=batch.get("patches"),
                                 frames=batch.get("frames")))(p)
        p, o = apply_updates(opt_cfg, p, grads, o)
        return loss, p, o

    losses = []
    for _ in range(3):
        loss, params, opt = step(params, opt)
        assert not bool(jnp.isnan(loss)), arch
        losses.append(float(loss))
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = smoke(get_config(arch)).with_(dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg, s=24)
    toks = b["tokens"]
    _, cache = lm.prefill(params, cfg, toks[:, :12], seq_len=24,
                          patches=b.get("patches"), frames=b.get("frames"))
    ld, cache = lm.decode_step(params, cfg, cache, toks[:, 12])
    h = lm.forward(params, cfg, toks[:, :13],
                   patches=b.get("patches"), frames=b.get("frames"))
    ref = lm.logits_for(params, cfg, h[:, 12])
    np.testing.assert_allclose(np.asarray(ld), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_exact_configs_match_brief():
    """Guard: the full configs carry the exact dims from the assignment."""
    c = get_config("qwen3-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 5120, 64, 8, 25600, 151936)
    c = get_config("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (26, 2304, 8, 4, 9216, 256000)
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (64, 4096, 65024, 16)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.n_experts_active, c.d_ff) == (128, 8, 768)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.n_experts_active, c.vocab) == (64, 6, 163840)
    c = get_config("internvl2-26b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (48, 6144, 48, 8)
    c = get_config("recurrentgemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (26, 2560, 10, 1)
    c = get_config("whisper-tiny")
    assert (c.n_layers, c.enc_layers, c.d_model, c.vocab) == (4, 4, 384, 51865)
    c = get_config("mistral-nemo-12b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (40, 5120, 14336, 131072)
    c = get_config("qwen1.5-0.5b")
    assert (c.n_layers, c.d_model, c.d_ff, c.qkv_bias) == (24, 1024, 2816, True)
