"""Known-bad: a low-precision dtype on a learning-state leaf inside a
core/ module (trace increments alpha*x underflow in bf16 — DESIGN.md §8;
only the pack_*/packed_* serving boundary may name these dtypes)."""
import jax.numpy as jnp


def update_trace(pi, x, alpha):
    return ((1 - alpha) * pi + alpha * x).astype(jnp.bfloat16)  # BUG
