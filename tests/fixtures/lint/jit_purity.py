"""Known-bad: host state read inside a jitted body — the value freezes
at trace time and silently replays forever."""
import time

import jax


@jax.jit
def step(x):
    t0 = time.time()  # BUG: wall-clock inside a jitted trace
    return x * t0
