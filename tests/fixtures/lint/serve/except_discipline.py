"""Known-bad serving snippet for the serve-except rule: a worker loop
that swallows Exception without re-raising, completing the affected
request futures, or recording the crash — callers blocked in result()
hang forever on the requests this batch owned."""


def drain(batcher, infer):
    while True:
        group = batcher.next_group()
        if not group:
            return
        try:
            infer(group)
        except Exception:  # BUG
            continue
