"""Known-bad: an attribute the class mutates under ``self._lock`` is
also written lock-free — the serve-telemetry race class."""
import threading


class Metrics:
    def __init__(self):
        self._lock = threading.Lock()
        self._count = 0

    def record(self):
        with self._lock:
            self._count += 1

    def reset(self):
        self._count = 0  # BUG: lock-guarded attribute written without it
