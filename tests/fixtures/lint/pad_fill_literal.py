"""Known-bad: hand-rolled softmax-lane fill (overflows to -inf on a
bf16 cast; all-pad hypercolumns then softmax to NaN)."""
import jax.numpy as jnp


def masked_support(scores, mask):
    return jnp.where(mask, scores, -1e30)  # BUG: use kernels.tiling.NEG
