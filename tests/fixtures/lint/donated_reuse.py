"""Known-bad: the PR 6 bug class — a buffer is read after being passed
through ``donate_argnums`` (XLA owns it; ``.is_deleted()`` at best)."""
import jax


def train_step(state, batch):
    return state


step = jax.jit(train_step, donate_argnums=(0,))


def train(state, batches):
    for batch in batches:
        new_state = step(state, batch)
        loss = state.loss  # BUG: `state` was donated to `step` above
        state = new_state
    return state, loss
