"""Known-bad: mutating a packed serving view in place — served weights
desynchronize from the fp32 state (stale int8 scales, dead tables)."""


def refresh_scale(proj, pspec, new_scale):
    pack = pack_projection(proj, pspec)  # noqa: F821 — AST fixture only
    pack.scale = new_scale  # BUG: packs are immutable derived views
    return pack
