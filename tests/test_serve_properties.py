"""Hypothesis property tests on the serving batcher's invariants
(serve/batching.py): bucket admission, minimality, pad masking and
request-order preservation across adversarial sizes."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dep: property tests "
                    "need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serve import MicroBatcher, Request, default_buckets, pad_group, pick_bucket

COMMON = dict(deadline=None, max_examples=50)


@settings(**COMMON)
@given(max_batch=st.integers(1, 4096))
def test_default_buckets_cover_and_terminate(max_batch):
    """Powers of two strictly below max_batch, then max_batch itself:
    sorted, unique, 1 admits singles, the top admits a full burst."""
    buckets = default_buckets(max_batch)
    assert buckets[0] == 1 and buckets[-1] == max_batch
    assert list(buckets) == sorted(set(buckets))
    body = buckets[:-1]
    assert all(b == 2 ** i for i, b in enumerate(body))
    assert all(b < max_batch for b in body)


@settings(**COMMON)
@given(max_batch=st.integers(1, 1024), data=st.data())
def test_pick_bucket_admits_and_is_minimal(max_batch, data):
    """The picked bucket fits the group AND is the smallest that does —
    the two invariants padding cost rests on."""
    buckets = default_buckets(max_batch)
    n = data.draw(st.integers(1, max_batch))
    b = pick_bucket(n, buckets)
    assert b in buckets
    assert b >= n
    assert all(other < n for other in buckets if other < b)


@settings(**COMMON)
@given(max_batch=st.integers(1, 256), over=st.integers(1, 64))
def test_pick_bucket_rejects_oversize(max_batch, over):
    with pytest.raises(ValueError):
        pick_bucket(max_batch + over, default_buckets(max_batch))


@settings(**COMMON)
@given(n=st.integers(1, 64), pad_to=st.integers(0, 64),
       dim=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_pad_group_mask_and_order(n, pad_to, dim, seed):
    """Rows [0, n) are the samples in submission order; rows [n, bucket)
    are zero with a 0 validity mask — nothing else."""
    bucket = n + pad_to
    rng = np.random.default_rng(seed)
    xs = [rng.random(dim).astype(np.float32) for _ in range(n)]
    x, valid = pad_group(xs, bucket)
    assert x.shape == (bucket, dim) and valid.shape == (bucket,)
    assert x.dtype == np.float32 and valid.dtype == np.float32
    np.testing.assert_array_equal(valid[:n], 1.0)
    np.testing.assert_array_equal(valid[n:], 0.0)
    for i, xi in enumerate(xs):  # order preserved, values untouched
        np.testing.assert_array_equal(x[i], xi)
    np.testing.assert_array_equal(x[n:], 0.0)


@settings(**COMMON)
@given(max_batch=st.integers(1, 64), n=st.integers(1, 128))
def test_batcher_groups_preserve_fifo_order_and_lose_nothing(max_batch, n):
    """Draining any queue through next_group yields every request exactly
    once, in submission order, in groups no larger than max_batch."""
    mb = MicroBatcher(default_buckets(max_batch), max_wait_s=0.0)
    for i in range(n):
        mb.put(Request(id=i, x=np.zeros(1, np.float32), enqueue_t=0.0))
    seen = []
    while True:
        group = mb.next_group(timeout_s=0.0)
        if not group:
            break
        assert 1 <= len(group) <= max_batch
        seen += [r.id for r in group]
    assert seen == list(range(n))
    assert mb.depth() == 0


@settings(**COMMON)
@given(max_batch=st.integers(2, 64), n=st.integers(1, 128),
       target=st.integers(1, 64))
def test_batcher_target_cap_never_splits_backlog(max_batch, n, target):
    """The adaptive target caps how long a group WAITS, never how much
    already-queued backlog it admits: with everything pre-queued, groups
    still come out max_batch-bounded FIFO and nothing is lost."""
    mb = MicroBatcher(default_buckets(max_batch), max_wait_s=0.0)
    for i in range(n):
        mb.put(Request(id=i, x=np.zeros(1, np.float32), enqueue_t=0.0))
    seen = []
    while True:
        group = mb.next_group(timeout_s=0.0, target=target)
        if not group:
            break
        assert len(group) <= max_batch
        seen += [r.id for r in group]
    assert seen == list(range(n))
