"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles (interpret mode on CPU; identical calls compile to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    bcpnn_fwd, bcpnn_update, fused_forward, fused_learn, hc_softmax,
    ref_bcpnn_fwd, ref_bcpnn_update, ref_hc_softmax,
)
from repro.core.bcpnn_layer import ProjSpec, forward, init_projection, learn
from repro.core.hypercolumns import LayerGeom


@pytest.mark.parametrize("b,h,m", [(8, 4, 8), (128, 16, 128), (64, 32, 64),
                                   (256, 8, 256),
                                   # hostile: prime batch, odd minicolumn
                                   # counts, single-HC readout shapes
                                   (97, 7, 10), (13, 1, 10), (64, 784, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hc_softmax_sweep(b, h, m, dtype):
    s = (jax.random.normal(jax.random.PRNGKey(0), (b, h * m)) * 4).astype(dtype)
    got = hc_softmax(s, h, m)
    want = ref_hc_softmax(s, h, m)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("b,ni,hj,mj", [
    (8, 32, 4, 16), (64, 256, 8, 64), (128, 1024, 16, 128), (32, 512, 4, 128),
    # hostile: Model-1's 1568-unit pre side, prime batch, n_mc not a
    # multiple of 8 — the geometries the divisor-fitting layer degraded on
    (97, 1568, 4, 10), (64, 251, 3, 12),
])
def test_bcpnn_fwd_sweep(b, ni, hj, mj):
    k = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.uniform(k[0], (b, ni))
    w = jax.random.normal(k[1], (ni, hj * mj)) * 0.1
    bias = jax.random.normal(k[2], (hj * mj,))
    got = bcpnn_fwd(x, w, bias, hj, mj)
    want = ref_bcpnn_fwd(x, w, bias, hj, mj)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("b,ni,nj", [(8, 32, 64), (64, 256, 512),
                                     (128, 1024, 512), (256, 512, 2048),
                                     # hostile: prime batch/pre, odd post
                                     (97, 251, 40), (31, 1568, 96)])
def test_bcpnn_update_sweep(b, ni, nj):
    k = jax.random.split(jax.random.PRNGKey(2), 6)
    pij = jax.random.uniform(k[0], (ni, nj)) * 0.01 + 1e-5
    lpi = jnp.log(jax.random.uniform(k[1], (ni,)) * 0.5 + 1e-4)
    lpj = jnp.log(jax.random.uniform(k[2], (nj,)) * 0.5 + 1e-4)
    x = jax.random.uniform(k[3], (b, ni))
    y = jax.random.uniform(k[4], (b, nj))
    mask = (jax.random.uniform(k[5], (ni, nj)) > 0.3).astype(jnp.float32)
    alpha = jnp.asarray(0.02)
    gp, gw = bcpnn_update(pij, lpi, lpj, x, y, mask, alpha)
    wp, ww = ref_bcpnn_update(pij, lpi, lpj, x, y, mask, alpha)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ww), atol=1e-4)


def test_fused_stages_match_core():
    """The fused Pallas path must be a drop-in for the core's jnp path."""
    spec = ProjSpec(LayerGeom(64, 2), LayerGeom(4, 32), alpha=1e-2)
    proj = init_projection(spec, jax.random.PRNGKey(0))
    x = jax.random.uniform(jax.random.PRNGKey(1), (64, spec.pre.N))
    h_ref = forward(proj, spec, x)
    h_fused = fused_forward(proj, spec, x)
    np.testing.assert_allclose(np.asarray(h_fused), np.asarray(h_ref), atol=1e-5)

    y = h_ref
    p_ref = learn(proj, spec, x, y)
    p_fused = fused_learn(proj, spec, x, y)
    np.testing.assert_allclose(np.asarray(p_fused.traces.pij),
                               np.asarray(p_ref.traces.pij), atol=1e-6)
    np.testing.assert_allclose(np.asarray(p_fused.w), np.asarray(p_ref.w),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(p_fused.b), np.asarray(p_ref.b),
                               atol=1e-6)


def test_kernel_odd_tile_boundaries():
    """Shapes that don't align to the default blocks (block clamping)."""
    got = hc_softmax(jnp.ones((4, 6 * 10)), 6, 10, block_b=128, block_h=8)
    np.testing.assert_allclose(np.asarray(got), 0.1, atol=1e-6)


@pytest.mark.parametrize("nact", [None, 4])
def test_learn_parity_across_bias_correction_crossover(nact):
    """fused_learn must match _learn_jnp on BOTH sides of the effective-
    smoothing crossover: while young the trace update is a running mean
    (a = 1/(t+1) > alpha), past t > 1/alpha it is the fixed-alpha EMA.
    With alpha=0.25 the crossover sits at t=4, so 10 chained steps cross
    it mid-run; every step is compared on traces, weights and bias."""
    from repro.core.bcpnn_layer import _learn_jnp

    spec = ProjSpec(LayerGeom(12, 2), LayerGeom(4, 8), alpha=0.25, nact=nact)
    proj_j = init_projection(spec, jax.random.PRNGKey(0))
    proj_f = jax.tree.map(jnp.array, proj_j)
    keys = jax.random.split(jax.random.PRNGKey(1), 10)
    crossed = False
    for k in keys:
        kx, ky = jax.random.split(k)
        x = jax.random.uniform(kx, (16, spec.pre.N))
        y = jax.random.uniform(ky, (16, spec.post.N))
        proj_j = _learn_jnp(proj_j, spec, x, y)
        proj_f = fused_learn(proj_f, spec, x, y)
        t = int(proj_j.traces.t)
        crossed = crossed or (1.0 / t < spec.alpha if t else False)
        np.testing.assert_allclose(np.asarray(proj_f.traces.pij),
                                   np.asarray(proj_j.traces.pij),
                                   atol=1e-6, err_msg=f"pij diverged at t={t}")
        np.testing.assert_allclose(np.asarray(proj_f.traces.pi),
                                   np.asarray(proj_j.traces.pi), atol=1e-6)
        np.testing.assert_allclose(np.asarray(proj_f.traces.pj),
                                   np.asarray(proj_j.traces.pj), atol=1e-6)
        np.testing.assert_allclose(np.asarray(proj_f.w), np.asarray(proj_j.w),
                                   atol=1e-4, err_msg=f"w diverged at t={t}")
        np.testing.assert_allclose(np.asarray(proj_f.b), np.asarray(proj_j.b),
                                   atol=1e-6)
    assert crossed, "sweep never left the bias-correction regime"
    if nact is not None:  # patchy invariant holds through both regimes
        for p in (proj_j, proj_f):
            assert np.all(np.asarray(p.mask).sum(0) == nact)


# ------------------------------------------------ pad-to-aligned tiling --

@pytest.mark.parametrize("dim", [1, 5, 10, 97, 100, 251, 784, 1568, 4096])
@pytest.mark.parametrize("block", [8, 100, 128, 512])
def test_pad_spec_invariants(dim, block):
    """Every planned axis: aligned block, block divides padded size, and
    padding never exceeds one block."""
    from repro.kernels.tiling import SUBLANE, pad_spec

    ps = pad_spec(dim, block, SUBLANE)
    assert ps.block % SUBLANE == 0
    assert ps.padded % ps.block == 0
    assert ps.padded >= dim and ps.padded - dim < ps.block
    assert ps.grid == ps.padded // ps.block


@pytest.mark.parametrize("n_hc,n_mc", [(1, 10), (7, 10), (32, 128), (784, 2),
                                       (32, 100), (5, 200)])
def test_pad_hc_spec_lane_aligned(n_hc, n_mc):
    """Hypercolumnar blocks span whole HCs and a whole number of 128-lane
    tiles (or the whole padded axis for sub-lane-sized toys)."""
    from repro.kernels.tiling import LANE, pad_hc_spec

    hs = pad_hc_spec(n_hc, n_mc, 512)
    assert hs.mc_padded >= n_mc
    assert hs.block_units % hs.mc_padded == 0          # whole HCs per block
    assert hs.padded_units % hs.block_units == 0
    if hs.padded_units >= LANE:
        assert hs.block_units % LANE == 0


def test_no_misalignment_warnings_at_model1_scale():
    """Model 1's geometry (Ni=1568, Nj=4096, b=256) must plan aligned
    blocks end-to-end: no warnings from any kernel wrapper."""
    import warnings

    b, ni, hj, mj = 256, 1568, 32, 128
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.uniform(k[0], (b, ni))
    w = jax.random.normal(k[1], (ni, hj * mj)) * 0.1
    bias = jax.random.normal(k[2], (hj * mj,))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = bcpnn_fwd(x, w, bias, hj, mj)
        jax.block_until_ready(out)
    assert out.shape == (b, hj * mj)


# ------------------------------------------------- low-precision pads ----

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_pad_fill_clamped_to_dtype_range(dtype):
    """The softmax pad sentinel must stay FINITE after casting into the
    operand dtype (bf16 cast-on-fold serving, ROADMAP §bf16): an -inf
    fill makes an all-pad HC compute -inf - (-inf) = NaN.  clamp_fill
    pins it at finfo(dtype).min."""
    from repro.kernels.padding import clamp_fill, pad_axis, pad_hc_axis
    from repro.kernels.tiling import NEG, pad_hc_spec

    fill = clamp_fill(NEG, dtype)
    # In range (no -inf on cast: bf16 holds -1e30 as-is, f16 clamps to
    # its finfo.min) but still negative enough that exp underflows to 0.
    assert np.isfinite(fill) and fill >= float(jnp.finfo(dtype).min)
    assert np.asarray(jnp.asarray(fill, dtype), np.float32) < -1e4
    assert np.isfinite(np.asarray(jnp.asarray(fill, dtype), np.float32))
    padded = pad_axis(jnp.zeros((2, 3), dtype), 1, 5, value=NEG)
    assert np.isfinite(np.asarray(padded, np.float32)).all()
    hs = pad_hc_spec(3, 10, 512)  # mc pads 10 -> 16 with NEG lanes
    hc_padded = pad_hc_axis(jnp.zeros((4, 30), dtype), 1, hs, value=NEG)
    assert np.isfinite(np.asarray(hc_padded, np.float32)).all()


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_hc_softmax_low_precision_pad_semantics(dtype):
    """Padded softmax lanes stay inert — zero probability, no NaN even
    through all-pad HCs — for the narrow serving dtypes, on a hostile
    geometry (odd minicolumn count -> NEG-filled lanes, prime batch ->
    pad rows)."""
    b, h, m = 13, 7, 10
    s = (jax.random.normal(jax.random.PRNGKey(3), (b, h * m)) * 4).astype(dtype)
    got = hc_softmax(s, h, m)
    assert got.dtype == dtype
    got32 = np.asarray(got, np.float32)
    assert np.isfinite(got32).all(), "pad lanes leaked NaN/inf"
    np.testing.assert_allclose(got32.reshape(b, h, m).sum(-1), 1.0,
                               atol=2e-2)
    want = np.asarray(ref_hc_softmax(s, h, m), np.float32)
    np.testing.assert_allclose(got32, want, atol=2e-2)


# ------------------------------------------------------- autotune cache --

def test_tuned_blocks_consulted(tmp_path, monkeypatch):
    """kernels/ops.py must pass cached winners through to the kernel (and
    explicit caller kwargs must still win over the cache)."""
    import json

    from repro.kernels import ops, tuning

    dims = dict(b=16, ni=48, n_hc=4, n_mc=8)
    cache = {"version": 1, "entries": {
        tuning.entry_key("bcpnn_fwd", **dims): {"block_b": 16, "block_j": 16}}}
    path = tmp_path / "autotune.json"
    path.write_text(json.dumps(cache))
    monkeypatch.setenv(tuning.ENV_CACHE, str(path))

    seen = {}
    real = ops.bcpnn_fwd_pallas

    def spy(*a, **kw):
        seen.update(kw)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "bcpnn_fwd_pallas", spy)
    k = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.uniform(k[0], (16, 48))
    w = jax.random.normal(k[1], (48, 32)) * 0.1
    bias = jax.random.normal(k[2], (32,))
    got = ops.bcpnn_fwd(x, w, bias, 4, 8)
    assert seen["block_b"] == 16 and seen["block_j"] == 16
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref_bcpnn_fwd(x, w, bias, 4, 8)),
                               atol=1e-5)
    seen.clear()
    ops.bcpnn_fwd(x, w, bias, 4, 8, block_b=8)  # explicit kwarg wins
    assert seen["block_b"] == 8 and "block_j" not in seen


def test_interpret_env_override(monkeypatch):
    from repro.kernels import ops

    monkeypatch.setenv(ops.ENV_INTERPRET, "1")
    assert ops._interpret() is True
    monkeypatch.setenv(ops.ENV_INTERPRET, "0")
    assert ops._interpret() is False
    monkeypatch.delenv(ops.ENV_INTERPRET)
    # memoized backend probe: same answer, no re-detection
    assert ops._interpret() == (ops._default_backend() != "tpu")
